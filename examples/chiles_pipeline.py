"""CHILES case study analogue (paper §5) on synthetic visibilities.

The paper's production pipeline:
  1. split each day's measurement set into frequency chunks   (Scatter x2)
  2. subtract the local sky model per chunk
  3. CLEAN each frequency band across all days                (GroupBy!)
  4. convert to image products
  5. concatenate bands into the final cube                    (Gather)

Here each CASA task is a numpy stand-in over synthetic complex
visibilities; the *graph shape* is the paper's, including the corner-turn
from day-major to frequency-major order.

Run:  PYTHONPATH=src python examples/chiles_pipeline.py
"""
import numpy as np

from repro.core import EngineConfig, Pipeline, register_app
from repro.dsl import GraphBuilder

DAYS = 4
BANDS = 6
CHANNELS_PER_BAND = 16
BASELINES = 35


def synthetic_day(day: int) -> np.ndarray:
    rng = np.random.default_rng(day)
    vis = (rng.normal(size=(BANDS * CHANNELS_PER_BAND, BASELINES))
           + 1j * rng.normal(size=(BANDS * CHANNELS_PER_BAND, BASELINES)))
    # inject a "source" in band 2: a fringe pattern across baselines
    # (zero-median, so the sky-model subtraction doesn't remove it —
    # exactly why interferometers see fringes, not DC offsets)
    fringe = 5.0 * np.exp(1j * np.linspace(0, 6 * np.pi, BASELINES))
    vis[2 * CHANNELS_PER_BAND:3 * CHANNELS_PER_BAND] += fringe[None, :]
    return vis.astype(np.complex64)


@register_app("chiles_split")
def split(inputs, outputs, app):
    """Split one day's MS into frequency chunks (paper step 1)."""
    day, band = app.meta["oid"]
    vis = synthetic_day(day)
    chunk = vis[band * CHANNELS_PER_BAND:(band + 1) * CHANNELS_PER_BAND]
    for o in outputs:
        o.write(chunk)


@register_app("chiles_subtract")
def subtract(inputs, outputs, app):
    """Subtract the local sky model (here: median over baselines)."""
    chunk = inputs[0].read()
    model = np.median(chunk.real, axis=1, keepdims=True)
    for o in outputs:
        o.write(chunk - model)


@register_app("chiles_clean")
def clean(inputs, outputs, app):
    """'CLEAN' one frequency band across ALL days (paper step 3 — this is
    the corner turn: inputs arrive day-major, grouped by band)."""
    stacked = np.stack([i.read() for i in inputs])      # (days, ch, bl)
    dirty = np.abs(stacked.mean(axis=0))                # integrate days
    peak = dirty.max()
    cleaned = np.where(dirty > 0.5 * peak, dirty, 0.0)  # Hogbom-ish
    for o in outputs:
        o.write(cleaned.astype(np.float32))


@register_app("chiles_concat")
def concat(inputs, outputs, app):
    cube = np.stack([i.read() for i in inputs])
    for o in outputs:
        o.write(cube)


def main() -> None:
    # stage 2: the released LGT lives in configs (versioned repository);
    # stage 3: the PI binds this observation's parameters.
    from repro.configs.daliuge_chiles import build_template
    lgt = build_template()
    lg = lgt.parametrise(days=DAYS, bands=BANDS)

    with Pipeline(EngineConfig(num_nodes=4, num_islands=2, dop=8)) as p:
        pgt = p.translate(lg)
        print(f"PGT: {len(pgt)} drops, {len(pgt.edges)} edges")
        p.deploy()
        rep = p.execute(inputs={"obs": "chiles-semester-1"}, timeout=120)
        print("status:", rep.state, rep.status_counts)
        assert rep.ok, rep.errors[:3]
        cube = p.session.drops["final"].read()
        print("final cube:", cube.shape, cube.dtype,
              "| per-band peak:", np.round(cube.max(axis=(1, 2)), 2))
        # the injected source lives in band 2 and must dominate
        assert cube.max(axis=(1, 2)).argmax() == 2
        print("source recovered in band 2 — OK")


if __name__ == "__main__":
    main()
