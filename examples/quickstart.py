"""Quickstart: compose -> parametrise -> translate -> deploy -> execute.

The six-stage DALiuGE pipeline (paper Fig. 1) on a toy reduction:
  scatter a dataset into 8 partitions, square each, gather the sum.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import EngineConfig, Pipeline, register_app
from repro.dsl import GraphBuilder


@register_app("square")
def square(inputs, outputs, app):
    v = inputs[0].read()
    for o in outputs:
        o.write(v * v)


@register_app("sum")
def add(inputs, outputs, app):
    for o in outputs:
        o.write(sum(i.read() for i in inputs))


@register_app("pick")
def pick(inputs, outputs, app):
    """Each scatter branch picks its slice by instance coordinate."""
    data = inputs[0].read()
    (i,) = app.meta["oid"]
    for o in outputs:
        o.write(data[i])


def main() -> None:
    # Stage 1-2: components (above) + logical graph template
    g = GraphBuilder("quickstart", parameters={"width": 4})
    g.data("dataset")
    with g.scatter("part", 4) as sc:
        sc.params["$num_of_copies"] = "width"
        g.component("slice", app="pick", time=0.001)
        g.data("piece")
        g.component("sq", app="square", time=0.001)
        g.data("squared")
    with g.gather("all", 4) as ga:
        ga.params["$num_of_inputs"] = "width"
        g.component("reduce", app="sum", time=0.001)
    g.data("result")
    g.chain("dataset", "slice", "piece", "sq", "squared", "reduce", "result")

    # Stage 3: select & parametrise (PI fills parameters)
    lg = g.lgt.parametrise(width=8)

    # Stages 4-6: translate -> deploy -> execute
    with Pipeline(EngineConfig(num_nodes=2, num_islands=1, dop=4)) as p:
        pgt = p.translate(lg)
        print(f"unrolled {len(pgt)} drops / {len(pgt.edges)} edges "
              f"into {len({s.partition for s in pgt.drops.values()})} "
              "partitions")
        p.deploy()
        report = p.execute(inputs={"dataset": list(range(8))})
        print("status:", report.state, report.status_counts)
        print("events:", report.events_published,
              f"wall: {report.wall_time*1e3:.1f} ms")
        result = p.session.drops["result"].read()
        print("sum of squares 0..7 =", result)
        assert result == sum(i * i for i in range(8))


if __name__ == "__main__":
    main()
