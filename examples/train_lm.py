"""Train an LM end-to-end through the graph engine (deliverable b).

Default: the ~20M-param preset for a few hundred steps (CPU-feasible);
``--preset lm100m`` selects the ~100M-class config (TPU-sized — expect
minutes/step on this 1-CPU container, identical code path).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import PRESETS, run_training  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="lm20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    print(f"[example] training {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params) for {args.steps} steps")
    res = run_training(cfg, steps=args.steps, shards=2, batch_per_shard=4,
                       seq=128, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                       resume=args.resume, peak_lr=1e-3)
    assert res["last_loss"] < res["first_loss"], "loss must decrease"
    print("[example] OK — loss decreased "
          f"{res['first_loss']:.3f} -> {res['last_loss']:.3f}")


if __name__ == "__main__":
    main()
