"""Serve a small model with batched requests through the graph engine.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.serve import run_serving  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen15_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch)
    res = run_serving(cfg, num_requests=args.requests,
                      decode_steps=args.decode)
    assert res["responses_shape"] == (args.requests, args.decode)
    print("[example] OK —", res["responses_shape"], "tokens generated")


if __name__ == "__main__":
    main()
