"""Sessions — isolated physical-graph executions (paper §3.5).

"Sessions are completely isolated from one another. ... Sessions have a simple
lifecycle: they are first created, then a complete or a partial PG is attached
to them, after which the graph can be deployed.  This leaves the session in a
running state until the graph has finished its execution."

Two session flavours share the same monitoring/checkpoint API:

* :class:`Session` — one Python :class:`~repro.core.drop.Drop` object per
  graph node, event-driven (the paper's object engine; the semantic oracle),
* :class:`CompiledSession` — drop state held in flat numpy arrays over a
  :class:`~repro.core.pgt.CompiledPGT`, executed wave-by-wave by the
  frontier scheduler in :mod:`repro.core.exec_compiled`.  No per-drop
  Python objects exist; payload values live in one dense table.
"""
from __future__ import annotations

import enum
import json
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from .drop import AppDrop, DataDrop, Drop, DropState, MemoryPayload
from .events import Event, EventBus
from .pgt import KIND_DATA, CompiledPGT
from .util import safe_uid as _safe


class SessionState(str, enum.Enum):
    PRISTINE = "PRISTINE"
    BUILDING = "BUILDING"
    DEPLOYING = "DEPLOYING"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    CANCELLED = "CANCELLED"
    FAILED = "FAILED"


#: session states no lifecycle transition may leave
_TERMINAL_SESSION = {SessionState.FINISHED, SessionState.CANCELLED,
                     SessionState.FAILED}


_TERMINAL_DROP = {DropState.COMPLETED, DropState.ERROR, DropState.CANCELLED,
                  DropState.SKIPPED, DropState.EXPIRED, DropState.DELETED}


class Session:
    def __init__(self, session_id: str, bus: Optional[EventBus] = None) -> None:
        self.session_id = session_id
        self.bus = bus or EventBus()
        self.state = SessionState.PRISTINE
        self.drops: Dict[str, Drop] = {}
        self._finished = threading.Event()
        self._terminal: set = set()     # incremental completion tracking
        self._lock = threading.Lock()
        self.created_at = time.monotonic()
        self.bus.subscribe_all(self._on_event)

    # -- graph attachment --------------------------------------------------------
    def add_drop(self, drop: Drop) -> None:
        self.state = SessionState.BUILDING
        self.drops[drop.uid] = drop

    # -- execution ----------------------------------------------------------------
    def deploy(self) -> None:
        self.state = SessionState.DEPLOYING

    def start(self) -> None:
        """Trigger root drops (paper §3.6)."""
        self.state = SessionState.RUNNING
        roots_data: List[DataDrop] = []
        roots_app: List[AppDrop] = []
        for d in self.drops.values():
            if isinstance(d, DataDrop) and not d.producers:
                roots_data.append(d)
            elif isinstance(d, AppDrop) and not d.inputs \
                    and not d.streaming_inputs:
                roots_app.append(d)
        # root data: "their data is considered to be present and therefore
        # they are marked as completed"
        for d in roots_data:
            if d.state in (DropState.INITIALIZED, DropState.WRITING):
                d.set_completed()
        for a in roots_app:
            if a.state is DropState.INITIALIZED:
                a.trigger_root()
        self._check_finished()

    def _on_event(self, event: Any) -> None:
        # incremental completion tracking: O(1) per event, not O(N) —
        # the decentralised engine must stay flat-overhead as graphs grow
        # (paper Fig. 8)
        if event.type != "status":
            return
        uid = event.source_uid
        d = self.drops.get(uid)
        if d is None:
            return
        with self._lock:
            if d.state in _TERMINAL_DROP:
                self._terminal.add(uid)
            else:
                self._terminal.discard(uid)   # fault recovery resets drops
            done = (self.state is SessionState.RUNNING
                    and len(self._terminal) == len(self.drops))
        if done:
            self._check_finished()

    def _check_finished(self) -> None:
        if self.state is not SessionState.RUNNING:
            return
        if all(d.state in _TERMINAL_DROP for d in self.drops.values()):
            self.state = SessionState.FINISHED
            self._finished.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        self._check_finished()
        return self._finished.wait(timeout)

    def reopen(self) -> None:
        """Back to RUNNING after drops were reset (fault recovery)."""
        self.state = SessionState.RUNNING
        self._rebuild_terminal()
        self._finished.clear()

    def _rebuild_terminal(self) -> None:
        """Resync the incremental tracker after out-of-band state changes
        (checkpoint restore / fault recovery set states without events)."""
        with self._lock:
            self._terminal = {u for u, d in self.drops.items()
                              if d.state in _TERMINAL_DROP}

    def cancel(self) -> None:
        for d in self.drops.values():
            d.cancel()
        self.state = SessionState.CANCELLED
        self._finished.set()

    def fail(self, reason: str) -> None:
        """Mark the session FAILED (node shutdown abandoned in-flight work,
        lost worker, ...).  No-op once terminal."""
        if self.state in _TERMINAL_SESSION:
            return
        self.error_reason = reason
        self.state = SessionState.FAILED
        self.bus.publish(Event("sessionFailed", self.session_id,
                               {"reason": reason}))
        self._finished.set()

    # -- monitoring (paper: DMs "allow users to query and monitor graph
    # execution status") -----------------------------------------------------------
    def status(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for d in self.drops.values():
            counts[d.state.value] = counts.get(d.state.value, 0) + 1
        return counts

    def errors(self) -> List[Drop]:
        return [d for d in self.drops.values()
                if d.state is DropState.ERROR]

    # -- checkpoint / restart ---------------------------------------------------------
    def checkpoint(self, directory: str,
                   spill_payloads: bool = True) -> str:
        """Persist all drop states (+ completed in-memory payloads)."""
        path = Path(directory)
        path.mkdir(parents=True, exist_ok=True)
        records = {uid: d.to_record() for uid, d in self.drops.items()}
        if spill_payloads:
            pdir = path / "payloads"
            pdir.mkdir(exist_ok=True)
            for uid, d in self.drops.items():
                if (isinstance(d, DataDrop)
                        and d.state is DropState.COMPLETED
                        and isinstance(d.payload, MemoryPayload)
                        and d.payload.exists()):
                    with open(pdir / f"{_safe(uid)}.pkl", "wb") as fh:
                        pickle.dump(d.payload.read(), fh,
                                    protocol=pickle.HIGHEST_PROTOCOL)
                    records[uid]["spilled"] = True
        manifest = path / "session.json"
        with open(manifest, "w") as fh:
            json.dump({"session_id": self.session_id,
                       "records": records}, fh)
        return str(manifest)

    def restore(self, directory: str) -> None:
        """Restore drop states from a checkpoint into an already-built graph."""
        path = Path(directory)
        with open(path / "session.json") as fh:
            data = json.load(fh)
        records = data["records"]
        for uid, rec in records.items():
            d = self.drops.get(uid)
            if d is None:
                continue
            if rec.get("spilled") and isinstance(d, DataDrop):
                with open(path / "payloads" / f"{_safe(uid)}.pkl", "rb") as fh:
                    d.payload.write(pickle.load(fh))
            d.restore_record(rec)

    def resume(self) -> None:
        """Continue a restored session: re-fire completions for COMPLETED
        data drops so not-yet-run consumers get triggered; reset apps that
        were mid-flight."""
        self.state = SessionState.RUNNING
        self._rebuild_terminal()
        from .drop import AppState
        for d in self.drops.values():
            if isinstance(d, AppDrop) and d.exec_state is AppState.RUNNING:
                # was mid-flight at checkpoint time: re-run
                d.exec_state = AppState.NOT_RUN
                d._state = DropState.INITIALIZED
        for d in list(self.drops.values()):
            if isinstance(d, DataDrop) and d.state is DropState.COMPLETED:
                for c in d.consumers:
                    if (isinstance(c, AppDrop)
                            and c.exec_state is AppState.NOT_RUN):
                        c.on_input_completed(d)
        # restart roots that never ran
        for d in self.drops.values():
            if (isinstance(d, AppDrop) and not d.inputs
                    and d.exec_state is AppState.NOT_RUN):
                d.trigger_root()
            if (isinstance(d, DataDrop) and not d.producers
                    and d.state is DropState.INITIALIZED):
                d.set_completed()
        self._check_finished()


# ---------------------------------------------------------------------------
# Compiled sessions — array-native drop state (no per-drop Python objects)
# ---------------------------------------------------------------------------

# int8 drop-state codes used by CompiledSession / the frontier scheduler
ST_INIT = 0
ST_COMPLETED = 1
ST_ERROR = 2
ST_CANCELLED = 3
ST_SKIPPED = 4

_ST_NAMES = (DropState.INITIALIZED.value, DropState.COMPLETED.value,
             DropState.ERROR.value, DropState.CANCELLED.value,
             DropState.SKIPPED.value)

# payload-kind codes (per data drop)
PK_MEMORY = 0
PK_FILE = 1
PK_NULL = 2
_PK_CODE_OF = {"memory": PK_MEMORY, "file": PK_FILE, "null": PK_NULL}


class CompiledDropRef:
    """Tiny uid/state/error view over one row of a CompiledSession
    (what ``errors()`` returns; duck-types the bits of ``Drop`` that the
    engine and monitoring consume).  Also the base for the app-function
    shims in :mod:`repro.core.exec_compiled`."""

    __slots__ = ("s", "idx")

    def __init__(self, session: "CompiledSession", idx: int) -> None:
        self.s = session
        self.idx = idx

    @property
    def session(self) -> "CompiledSession":
        return self.s

    @property
    def uid(self) -> str:
        return self.s.pgt.uid_of(self.idx)

    @property
    def state(self) -> DropState:
        return DropState(_ST_NAMES[self.s.drop_state[self.idx]])

    @property
    def error_info(self) -> Optional[str]:
        return self.s.error_info.get(self.idx)

    @property
    def node(self) -> Optional[str]:
        nid = self.s.pgt.node_ids[self.idx]
        return None if nid < 0 else self.s.pgt.node_names[nid]

    def read(self) -> Any:
        return self.s._read_idx(self.idx)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__} {self.uid} {self.state.value}>"


class CompiledSession:
    """A session executing directly on ``CompiledPGT`` arrays.

    Shares the :class:`Session` monitoring/lifecycle API — ``status()``,
    ``wait()``, ``errors()``, ``checkpoint()``/``restore()``, ``cancel()``
    — but holds *all* drop state in flat arrays:

    * ``drop_state``  — int8 state codes (``ST_*``),
    * ``payloads`` / ``payload_present`` — dense value table for data
      drops (the vectorised equivalent of per-drop ``MemoryPayload``),
    * ``error_info`` — sparse ``{drop id: message}`` map,
    * ``node_slices`` — per-node drop-id index arrays, filled by the
      batched deploy (``MasterDropManager.deploy_compiled``).

    Execution is driven by :func:`repro.core.exec_compiled.execute_frontier`
    — the session itself is pure state + bookkeeping.
    """

    def __init__(self, session_id: str, pgt: CompiledPGT,
                 bus: Optional[EventBus] = None) -> None:
        self.session_id = session_id
        self.pgt = pgt
        self.bus = bus or EventBus()
        self.state = SessionState.PRISTINE
        n = pgt.num_drops
        self.num_drops = n
        self.drop_state = np.zeros(n, dtype=np.int8)
        self.payloads = np.full(n, None, dtype=object)   # dense value table
        self.payload_present = np.zeros(n, dtype=bool)
        self.error_info: Dict[int, str] = {}
        self.node_slices: Dict[str, np.ndarray] = {}
        self.cross_node_edges = 0          # stat recorded at deploy
        self.closed = False                # close() frees the payload table
        # telemetry (both None unless enabled — TelemetryConfig default
        # must allocate nothing): per-drop Timeline arrays + the shared
        # MetricsRegistry the scheduler/resilience layers update
        self.timeline = None               # .telemetry.Timeline | None
        self.metrics = None                # .telemetry.MetricsRegistry | None
        # streaming chunk rings (None unless the graph has active
        # streaming edges AND enable_streaming ran — batch graphs pay
        # nothing; see .streaming.StreamTable)
        self.stream = None                 # .streaming.StreamTable | None
        # resilience counters (maintained by core.resilience; always
        # present so monitoring code can read them unconditionally)
        self.recoveries = 0                # node-failure recovery passes
        self.recovered_drops = 0           # drops reset + remapped, total
        self.speculative_wins = 0          # straggler duplicates that won
        self.retries = 0                   # dispatch-layer re-attempts
        self._finished = threading.Event()
        self.created_at = time.monotonic()
        # payload-kind code per drop (PK_*; apps carry PK_MEMORY, unused)
        gidx = pgt.group_idx_arr()
        gpk = np.fromiter(
            (_PK_CODE_OF.get(g.payload_kind, PK_MEMORY) for g in pgt.groups),
            dtype=np.int8, count=len(pgt.groups))
        self.payload_kind = gpk[gidx] if len(pgt.groups) else \
            np.zeros(n, dtype=np.int8)

    # -- telemetry ---------------------------------------------------------
    def enable_timeline(self) -> None:
        """Allocate the per-drop timeline arrays (idempotent).  Kept as
        an explicit opt-in so default sessions pay nothing — 4 extra
        arrays is 280 MB at the 10M-drop tier."""
        if self.timeline is None:
            from .telemetry import Timeline
            self.timeline = Timeline(self)

    # -- streaming ---------------------------------------------------------
    def enable_streaming(self, config=None):
        """Build the per-streaming-edge chunk-ring table (idempotent).

        Returns the :class:`repro.core.streaming.StreamTable`, or None
        when the graph has no *active* streaming edges (streaming flag +
        data→app + streaming-marked consumer func) — pure-batch sessions
        allocate nothing.  Seeds written before this call are pushed as
        first chunks (see ``StreamTable.build``)."""
        if self.stream is None and not self.closed:
            from .streaming import StreamTable
            self.stream = StreamTable.build(self, config)
        return self.stream

    def record_error(self, idx: int, msg: str) -> None:
        """Record a drop failure: error_info + a ``dropFailed`` event on
        the session bus (traceback last line as summary) — the compiled
        engine's bridge to ``RecordingListener``-style tooling."""
        i = int(idx)
        self.error_info[i] = msg
        lines = [ln for ln in msg.strip().splitlines() if ln.strip()]
        summary = lines[-1][:200] if lines else ""
        self.bus.publish(Event("dropFailed", self.pgt.uid_of(i),
                               {"session": self.session_id,
                                "summary": summary}))

    # -- lifecycle ---------------------------------------------------------
    def deploy(self) -> None:
        self.state = SessionState.DEPLOYING

    def start(self) -> None:
        # publish only on the *first* transition to RUNNING — fault
        # recovery resumes via reopen()+execute_frontier and must not
        # produce duplicate sessionStarted events
        if self.state is not SessionState.RUNNING:
            self.bus.publish(Event("sessionStarted", self.session_id,
                                   {"num_drops": self.num_drops}))
        self.state = SessionState.RUNNING

    def finish(self) -> None:
        n_err = len(self.error_info)
        self.bus.publish(Event(
            "sessionFailed" if n_err else "sessionFinished",
            self.session_id,
            {"num_drops": self.num_drops, "errors": n_err}))
        self.state = SessionState.FINISHED
        self._finished.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._finished.wait(timeout)

    def reopen(self) -> None:
        """Back to RUNNING after state rows were reset (fault recovery) —
        the array-native mirror of :meth:`Session.reopen`.  The frontier
        scheduler re-derives its readiness counters from the state array,
        so execution resumes mid-wave with ``execute_frontier``."""
        self.state = SessionState.RUNNING
        self._finished.clear()

    def cancel(self) -> None:
        self.drop_state[self.drop_state == ST_INIT] = ST_CANCELLED
        self.state = SessionState.CANCELLED
        self._finished.set()

    def fail(self, reason: str) -> None:
        """Mark the session FAILED (node shutdown abandoned in-flight work,
        lost worker, ...).  No-op once terminal."""
        if self.state in _TERMINAL_SESSION:
            return
        self.error_reason = reason
        self.state = SessionState.FAILED
        self.bus.publish(Event("sessionFailed", self.session_id,
                               {"reason": reason}))
        self._finished.set()

    def close(self) -> None:
        """Release the session's mutable storage (resident-manager
        eviction).  The dense payload table is the dominant per-session
        allocation — dropping it is what makes closing a session under
        :class:`repro.core.manager.EngineManager` actually free memory;
        the shared template ``CompiledPGT`` is untouched.  Subsequent
        reads/writes raise ``PayloadError``."""
        self.closed = True
        self.payloads = np.empty(0, dtype=object)
        self.payload_present = np.empty(0, dtype=bool)
        self.error_info = {}
        self.node_slices = {}
        self.stream = None
        self._finished.set()

    # -- data access (input seeding / result readout) ----------------------
    def index_of(self, uid: str) -> int:
        return self.pgt.index_of(uid)

    def write(self, uid: str, value: Any) -> None:
        """Seed an input payload (root data drops, pre-execution).

        State guard matches the object oracle: ``Drop.write`` only
        accepts writes before the drop is terminal."""
        from .drop import PayloadError
        if self.closed:
            raise PayloadError(f"session {self.session_id} is closed")
        idx = self.index_of(uid)
        if self.pgt.kind_arr[idx] != KIND_DATA:
            raise ValueError(f"cannot write app drop {uid!r}")
        if self.drop_state[idx] != ST_INIT:
            raise PayloadError(f"cannot write drop {uid} in state "
                               f"{_ST_NAMES[self.drop_state[idx]]}")
        self.payloads[idx] = value
        self.payload_present[idx] = True
        if self.stream is not None and self.stream.is_src[idx]:
            self.stream.push(idx, value)

    def read(self, uid: str) -> Any:
        return self._read_idx(self.index_of(uid))

    def _read_idx(self, idx: int) -> Any:
        from .drop import PayloadError
        if self.closed:
            raise PayloadError(f"session {self.session_id} is closed")
        if self.payload_kind[idx] == PK_NULL:
            return None
        if not self.payload_present[idx]:
            if self.payload_kind[idx] == PK_FILE:
                path = self._file_path(idx)
                if Path(path).exists():
                    with open(path, "rb") as fh:
                        return pickle.load(fh)
            raise PayloadError("payload not present")
        return self.payloads[idx]

    def _write_idx(self, idx: int, value: Any) -> None:
        """Payload write from a producing app (registry shim path)."""
        self.payloads[idx] = value
        self.payload_present[idx] = True
        if self.stream is not None and self.stream.is_src[idx]:
            self.stream.push(idx, value)
        if self.payload_kind[idx] == PK_FILE:
            path = Path(self._file_path(idx))
            path.parent.mkdir(parents=True, exist_ok=True)
            with open(path, "wb") as fh:
                pickle.dump(value, fh, protocol=pickle.HIGHEST_PROTOCOL)

    def state_of(self, uid: str) -> DropState:
        return DropState(_ST_NAMES[self.drop_state[self.index_of(uid)]])

    def _file_path(self, idx: int) -> str:
        params = self.pgt.params_of(idx)
        return params.get(
            "path", f"/tmp/repro_drops/{_safe(self.pgt.uid_of(idx))}.pkl")

    # -- monitoring ----------------------------------------------------------
    def status(self) -> Dict[str, int]:
        counts = np.bincount(self.drop_state, minlength=len(_ST_NAMES))
        return {_ST_NAMES[c]: int(v)
                for c, v in enumerate(counts) if v}

    def errors(self) -> List[CompiledDropRef]:
        return [CompiledDropRef(self, int(i))
                for i in np.flatnonzero(self.drop_state == ST_ERROR)]

    # -- checkpoint / restart ------------------------------------------------
    def checkpoint(self, directory: str,
                   spill_payloads: bool = True) -> str:
        """Persist the state arrays (+ present payload values) — the
        array-native analogue of ``Session.checkpoint``."""
        path = Path(directory)
        path.mkdir(parents=True, exist_ok=True)
        np.save(path / "drop_state.npy", self.drop_state)
        if spill_payloads:
            present = np.flatnonzero(self.payload_present)
            values = {int(i): self.payloads[int(i)] for i in present}
            with open(path / "payloads.pkl", "wb") as fh:
                pickle.dump(values, fh, protocol=pickle.HIGHEST_PROTOCOL)
        manifest = path / "compiled_session.json"
        with open(manifest, "w") as fh:
            json.dump({"session_id": self.session_id,
                       "num_drops": self.num_drops,
                       "format": "compiled-v1",
                       "spill_payloads": bool(spill_payloads),
                       "errors": {str(i): msg
                                  for i, msg in self.error_info.items()}},
                      fh)
        return str(manifest)

    def restore(self, directory: str) -> None:
        """Restore state arrays from a checkpoint into this session.
        Execution can then continue with ``execute_frontier`` (the
        scheduler derives ``pending_inputs`` from terminal states)."""
        path = Path(directory)
        with open(path / "compiled_session.json") as fh:
            data = json.load(fh)
        if data.get("num_drops") != self.num_drops:
            raise ValueError(
                f"checkpoint has {data.get('num_drops')} drops, session "
                f"graph has {self.num_drops}")
        self.drop_state = np.load(path / "drop_state.npy")
        self.error_info = {int(i): msg
                           for i, msg in data.get("errors", {}).items()}
        ppath = path / "payloads.pkl"
        if data.get("spill_payloads") and ppath.exists():
            with open(ppath, "rb") as fh:
                values = pickle.load(fh)
            self.payloads = np.full(self.num_drops, None, dtype=object)
            self.payload_present = np.zeros(self.num_drops, dtype=bool)
            for i, v in values.items():
                self.payloads[i] = v
                self.payload_present[i] = True
        self._finished.clear()
        # in-flight stream chunks are not checkpointed (checkpoint at
        # stream boundaries); drop the table so the next execute rebuilds
        # it and re-seeds rings from restored payloads
        self.stream = None
        if bool((self.drop_state != ST_INIT).all()):
            self.finish()
