"""Sessions — isolated physical-graph executions (paper §3.5).

"Sessions are completely isolated from one another. ... Sessions have a simple
lifecycle: they are first created, then a complete or a partial PG is attached
to them, after which the graph can be deployed.  This leaves the session in a
running state until the graph has finished its execution."
"""
from __future__ import annotations

import enum
import json
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

from .drop import AppDrop, DataDrop, Drop, DropState, MemoryPayload
from .events import EventBus


class SessionState(str, enum.Enum):
    PRISTINE = "PRISTINE"
    BUILDING = "BUILDING"
    DEPLOYING = "DEPLOYING"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    CANCELLED = "CANCELLED"


_TERMINAL_DROP = {DropState.COMPLETED, DropState.ERROR, DropState.CANCELLED,
                  DropState.SKIPPED, DropState.EXPIRED, DropState.DELETED}


class Session:
    def __init__(self, session_id: str, bus: Optional[EventBus] = None) -> None:
        self.session_id = session_id
        self.bus = bus or EventBus()
        self.state = SessionState.PRISTINE
        self.drops: Dict[str, Drop] = {}
        self._finished = threading.Event()
        self._terminal: set = set()     # incremental completion tracking
        self._lock = threading.Lock()
        self.created_at = time.monotonic()
        self.bus.subscribe_all(self._on_event)

    # -- graph attachment --------------------------------------------------------
    def add_drop(self, drop: Drop) -> None:
        self.state = SessionState.BUILDING
        self.drops[drop.uid] = drop

    # -- execution ----------------------------------------------------------------
    def deploy(self) -> None:
        self.state = SessionState.DEPLOYING

    def start(self) -> None:
        """Trigger root drops (paper §3.6)."""
        self.state = SessionState.RUNNING
        roots_data: List[DataDrop] = []
        roots_app: List[AppDrop] = []
        for d in self.drops.values():
            if isinstance(d, DataDrop) and not d.producers:
                roots_data.append(d)
            elif isinstance(d, AppDrop) and not d.inputs \
                    and not d.streaming_inputs:
                roots_app.append(d)
        # root data: "their data is considered to be present and therefore
        # they are marked as completed"
        for d in roots_data:
            if d.state in (DropState.INITIALIZED, DropState.WRITING):
                d.set_completed()
        for a in roots_app:
            if a.state is DropState.INITIALIZED:
                a.trigger_root()
        self._check_finished()

    def _on_event(self, event: Any) -> None:
        # incremental completion tracking: O(1) per event, not O(N) —
        # the decentralised engine must stay flat-overhead as graphs grow
        # (paper Fig. 8)
        if event.type != "status":
            return
        uid = event.source_uid
        d = self.drops.get(uid)
        if d is None:
            return
        with self._lock:
            if d.state in _TERMINAL_DROP:
                self._terminal.add(uid)
            else:
                self._terminal.discard(uid)   # fault recovery resets drops
            done = (self.state is SessionState.RUNNING
                    and len(self._terminal) == len(self.drops))
        if done:
            self._check_finished()

    def _check_finished(self) -> None:
        if self.state is not SessionState.RUNNING:
            return
        if all(d.state in _TERMINAL_DROP for d in self.drops.values()):
            self.state = SessionState.FINISHED
            self._finished.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        self._check_finished()
        return self._finished.wait(timeout)

    def reopen(self) -> None:
        """Back to RUNNING after drops were reset (fault recovery)."""
        self.state = SessionState.RUNNING
        self._rebuild_terminal()
        self._finished.clear()

    def _rebuild_terminal(self) -> None:
        """Resync the incremental tracker after out-of-band state changes
        (checkpoint restore / fault recovery set states without events)."""
        with self._lock:
            self._terminal = {u for u, d in self.drops.items()
                              if d.state in _TERMINAL_DROP}

    def cancel(self) -> None:
        for d in self.drops.values():
            d.cancel()
        self.state = SessionState.CANCELLED
        self._finished.set()

    # -- monitoring (paper: DMs "allow users to query and monitor graph
    # execution status") -----------------------------------------------------------
    def status(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for d in self.drops.values():
            counts[d.state.value] = counts.get(d.state.value, 0) + 1
        return counts

    def errors(self) -> List[Drop]:
        return [d for d in self.drops.values()
                if d.state is DropState.ERROR]

    # -- checkpoint / restart ---------------------------------------------------------
    def checkpoint(self, directory: str,
                   spill_payloads: bool = True) -> str:
        """Persist all drop states (+ completed in-memory payloads)."""
        path = Path(directory)
        path.mkdir(parents=True, exist_ok=True)
        records = {uid: d.to_record() for uid, d in self.drops.items()}
        if spill_payloads:
            pdir = path / "payloads"
            pdir.mkdir(exist_ok=True)
            for uid, d in self.drops.items():
                if (isinstance(d, DataDrop)
                        and d.state is DropState.COMPLETED
                        and isinstance(d.payload, MemoryPayload)
                        and d.payload.exists()):
                    with open(pdir / f"{_safe(uid)}.pkl", "wb") as fh:
                        pickle.dump(d.payload.read(), fh,
                                    protocol=pickle.HIGHEST_PROTOCOL)
                    records[uid]["spilled"] = True
        manifest = path / "session.json"
        with open(manifest, "w") as fh:
            json.dump({"session_id": self.session_id,
                       "records": records}, fh)
        return str(manifest)

    def restore(self, directory: str) -> None:
        """Restore drop states from a checkpoint into an already-built graph."""
        path = Path(directory)
        with open(path / "session.json") as fh:
            data = json.load(fh)
        records = data["records"]
        for uid, rec in records.items():
            d = self.drops.get(uid)
            if d is None:
                continue
            if rec.get("spilled") and isinstance(d, DataDrop):
                with open(path / "payloads" / f"{_safe(uid)}.pkl", "rb") as fh:
                    d.payload.write(pickle.load(fh))
            d.restore_record(rec)

    def resume(self) -> None:
        """Continue a restored session: re-fire completions for COMPLETED
        data drops so not-yet-run consumers get triggered; reset apps that
        were mid-flight."""
        self.state = SessionState.RUNNING
        self._rebuild_terminal()
        from .drop import AppState
        for d in self.drops.values():
            if isinstance(d, AppDrop) and d.exec_state is AppState.RUNNING:
                # was mid-flight at checkpoint time: re-run
                d.exec_state = AppState.NOT_RUN
                d._state = DropState.INITIALIZED
        for d in list(self.drops.values()):
            if isinstance(d, DataDrop) and d.state is DropState.COMPLETED:
                for c in d.consumers:
                    if (isinstance(c, AppDrop)
                            and c.exec_state is AppState.NOT_RUN):
                        c.on_input_completed(d)
        # restart roots that never ran
        for d in self.drops.values():
            if (isinstance(d, AppDrop) and not d.inputs
                    and d.exec_state is AppState.NOT_RUN):
                d.trigger_root()
            if (isinstance(d, DataDrop) and not d.producers
                    and d.state is DropState.INITIALIZED):
                d.set_completed()
        self._check_finished()


def _safe(uid: str) -> str:
    return uid.replace("/", "_").replace("#", "_").replace(".", "_")
