"""Logical-graph constructs (paper §3.2).

The building blocks of a Logical Graph Template:

* ``Data`` and ``Component`` — the two basic constructs, templates from which
  Data Drops and Application Drops are instantiated.  ``Data`` exposes a
  *data volume* property, ``Component`` an *execution time* property (used by
  the translator's cost model).
* ``Scatter`` — data parallelism; ``num_of_copies`` parallel branches.
* ``Gather`` — data barrier; each instance consumes ``num_of_inputs``
  partitions.
* ``GroupBy`` — corner-turn / static shuffle; must be used with nested
  Scatters (validated), regrouping outer×inner partitions by the inner key.
* ``Loop`` — fixed-trip iteration; the body is replicated ``num_of_iterations``
  times with loop-carried Data nodes re-created each iteration (paper §2.3:
  "pre-generated loop structures with new Data Drops created in each
  iteration").

Constructs are pure descriptions — no jax, no threads — serialisable to JSON.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class Kind(str, enum.Enum):
    DATA = "data"
    COMPONENT = "component"
    SCATTER = "scatter"
    GATHER = "gather"
    GROUPBY = "groupby"
    LOOP = "loop"


CONTAINER_KINDS = {Kind.SCATTER, Kind.GATHER, Kind.GROUPBY, Kind.LOOP}


@dataclass
class Construct:
    """A node of the Logical Graph Template."""

    name: str
    kind: Kind
    # basic-construct properties (paper §3.2)
    data_volume: float = 0.0          # bytes, Data only
    execution_time: float = 0.0       # seconds, Component only
    payload_kind: str = "memory"      # Data only: memory|file|null
    app: Optional[str] = None         # Component only: registered app name
    error_threshold: float = 0.0      # Component only: t (Fig. 7)
    # flow-construct properties
    num_of_copies: int = 1            # Scatter
    num_of_inputs: int = 1            # Gather
    num_of_iterations: int = 1        # Loop
    group_key: str = "inner"          # GroupBy: which scatter axis groups
    loop_entry: bool = False          # Data inside Loop receiving carried value
    loop_exit: bool = False           # Data inside Loop producing carried value
    # containment
    parent: Optional[str] = None      # enclosing container construct name
    params: Dict[str, Any] = field(default_factory=dict)

    def is_container(self) -> bool:
        return self.kind in CONTAINER_KINDS

    def to_json(self) -> Dict[str, Any]:
        d = {
            "name": self.name, "kind": self.kind.value,
            "data_volume": self.data_volume,
            "execution_time": self.execution_time,
            "payload_kind": self.payload_kind, "app": self.app,
            "error_threshold": self.error_threshold,
            "num_of_copies": self.num_of_copies,
            "num_of_inputs": self.num_of_inputs,
            "num_of_iterations": self.num_of_iterations,
            "group_key": self.group_key,
            "loop_entry": self.loop_entry, "loop_exit": self.loop_exit,
            "parent": self.parent, "params": self.params,
        }
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Construct":
        d = dict(d)
        d["kind"] = Kind(d["kind"])
        return Construct(**d)


@dataclass(frozen=True)
class LogicalEdge:
    """Directed edge between constructs.

    The linking rule (paper §3.2): Data may only link to Component and vice
    versa ("tasks and data are both nodes of the graph").  Container
    constructs are transparent: edges attach to constructs *inside* them.
    """

    src: str
    dst: str
    streaming: bool = False

    def to_json(self) -> Dict[str, Any]:
        return {"src": self.src, "dst": self.dst, "streaming": self.streaming}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "LogicalEdge":
        return LogicalEdge(**d)
