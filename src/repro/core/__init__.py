"""DALiuGE-style graph execution core (the paper's contribution).

Public surface: Drops, constructs, logical graphs, translation
(unroll+partition), mapping, managers, sessions, the engine facade,
fault handling and data lifecycle management.
"""
from .config import EngineConfig
from .constructs import Construct, Kind, LogicalEdge
from .drop import (AppDrop, AppState, DataDrop, Drop, DropState, FilePayload,
                   MemoryPayload, NullPayload, Payload, PayloadError)
from .engine import ExecutionReport, Pipeline
from .events import Event, EventBus, RecordingListener
from .exec_compiled import ExecHooks, execute_frontier
from .fault import FaultManager, StragglerWatcher, elastic_remap, with_retries
from .resilience import (CompiledFaultManager, FailureScript,
                         ResilienceConfig, ResilienceStats, ResilientRunner,
                         RetryPolicy, StragglerPolicy, execute_resilient)
from .graph_io import iter_pgt, load_lgt, load_pgt, save_lgt, save_pgt
from .lifecycle import DataLifecycleManager
from .logical import (GraphValidationError, LogicalGraph,
                      LogicalGraphTemplate)
from .manager import AdmissionError, EngineManager, SessionTicket
from .managers import (DataIslandDropManager, MasterDropManager,
                       NodeDropManager, ProcNodeDropManager, get_app,
                       make_cluster, register_app)
from .procpool import (PayloadPlane, ProcExecutor, WorkerLost,
                       WorkerTimeout)
from .mapping import NodeInfo, map_partitions, stamp_nodes
from .partition import PartitionResult, min_res, min_time
from .schedule import critical_path, partition_stats, simulate_makespan
from .pgt import CompiledPGT, DropView
from .session import (CompiledDropRef, CompiledSession, Session,
                      SessionState)
from .streaming import StreamAbort, StreamConfig, StreamTable
from .telemetry import (MetricsRegistry, Span, TelemetryConfig, Timeline,
                        export_chrome_trace)
from .templates import (GraphTemplate, TemplateCache, structural_hash,
                        translate_lg)
from .unroll import (Axis, DropSpec, PhysicalGraphTemplate, compile_unroll,
                     leaf_axes, unroll, unroll_dict)

__all__ = [
    "AdmissionError", "AppDrop", "AppState", "Axis", "CompiledDropRef",
    "CompiledFaultManager", "CompiledPGT", "CompiledSession", "Construct",
    "DataDrop", "DataIslandDropManager", "DataLifecycleManager", "Drop",
    "DropSpec", "DropState", "DropView", "EngineConfig", "EngineManager",
    "Event", "EventBus", "ExecHooks", "ExecutionReport", "FailureScript",
    "FaultManager", "FilePayload", "GraphTemplate", "GraphValidationError",
    "Kind", "LogicalEdge", "LogicalGraph", "LogicalGraphTemplate",
    "MasterDropManager", "MemoryPayload", "MetricsRegistry",
    "NodeDropManager", "NodeInfo",
    "NullPayload", "PartitionResult", "Payload", "PayloadError",
    "PayloadPlane", "PhysicalGraphTemplate", "Pipeline",
    "ProcExecutor", "ProcNodeDropManager", "RecordingListener",
    "ResilienceConfig", "ResilienceStats", "ResilientRunner", "RetryPolicy",
    "Session", "SessionState", "SessionTicket", "Span", "StragglerPolicy",
    "StragglerWatcher", "StreamAbort", "StreamConfig", "StreamTable",
    "TelemetryConfig", "TemplateCache", "Timeline", "WorkerLost",
    "WorkerTimeout",
    "compile_unroll", "critical_path",
    "elastic_remap", "execute_frontier", "execute_resilient",
    "export_chrome_trace", "get_app",
    "iter_pgt", "leaf_axes", "load_lgt", "load_pgt", "make_cluster",
    "map_partitions", "min_res", "min_time", "partition_stats",
    "register_app", "save_lgt", "save_pgt", "simulate_makespan",
    "stamp_nodes", "structural_hash", "translate_lg", "unroll",
    "unroll_dict", "with_retries",
]
