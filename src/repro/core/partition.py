"""Logical partitioning of the PGT (paper §3.4, step 3).

Two families, exactly as the paper describes:

* ``min_time`` — "produce an optimal number of partitions such that first the
  total completion time of the pipeline ... is minimised, and second at any
  point in time the number of drops running in parallel within a single
  partition is no greater than a Degree of Parallelism (DoP) threshold."
  Implemented as edge-zeroing internalisation (Sarkar-style): start with one
  partition per drop and merge across data-movement edges in descending cost
  order while the per-level app width of every partition stays within the
  DoP cap.

* ``min_res`` — "minimise the number of produced partitions subject to
  satisfying completion deadline and the DoP threshold constraints."

Each family has two implementations dispatched on the PGT type:

* the seed **dict path** (``PhysicalGraphTemplate``): merge trials validated
  with a full makespan simulation each (plus optional simulated-annealing
  refinement) — O(E · sim); the semantic reference, fine to ~10^4 drops.
* the **array path** (``CompiledPGT``): union-find over int32 ids with
  incremental per-level width tracking, candidate *prefixes* of the
  cost-sorted edge list evaluated with the vectorized critical-path
  estimator (exact event simulation for small graphs), best prefix kept —
  O(E α(E) + checkpoints · E).  This is what sustains the paper's
  millions-of-drops translate regime.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .pgt import KIND_APP, CompiledPGT
from .schedule import (DEFAULT_BANDWIDTH, _critical_path_arrays, _extract,
                       _simulate_arrays, critical_path, edge_cost,
                       simulate_makespan)
from .unroll import PhysicalGraphTemplate

# graphs up to this many drops evaluate merge checkpoints with the exact
# event simulation (guarantees makespan never regresses past the trivial
# partitioning); larger graphs use the vectorized critical-path estimator
EXACT_EVAL_MAX_DROPS = 20_000
# largest graph for which the *final* reported makespan is exact-simulated
EXACT_FINAL_MAX_DROPS = 400_000


@dataclass
class PartitionResult:
    num_partitions: int
    makespan: float
    algorithm: str
    dop: int


# ---------------------------------------------------------------------------
# Degree-of-parallelism accounting
# ---------------------------------------------------------------------------


def _partition_dop(pgt, members: Set[str]) -> int:
    """Max antichain width restricted to a partition's app drops.

    Exact max-antichain is expensive; we use the standard level-width
    over-approximation (drops at the same DAG depth can run concurrently),
    which is what constrains the schedule in practice.
    """
    depth: Dict[str, int] = {}
    width: Dict[int, int] = {}
    for uid in pgt.topological_order():
        d = 0
        for p in pgt.predecessors(uid):
            d = max(d, depth[p] + 1)
        depth[uid] = d
        if uid in members and pgt.drops[uid].kind == "app":
            width[d] = width.get(d, 0) + 1
    return max(width.values()) if width else 0


class _UnionFind:
    def __init__(self, items: List[str]) -> None:
        self.parent = {i: i for i in items}
        self.rank = {i: 0 for i in items}

    def find(self, x: str) -> str:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return ra


def _assign(pgt: PhysicalGraphTemplate, groups: Dict[str, int]) -> None:
    for uid, part in groups.items():
        pgt.drops[uid].partition = part


def _renumber(uf: "_UnionFind", pgt: PhysicalGraphTemplate) -> Dict[str, int]:
    ids: Dict[str, int] = {}
    groups: Dict[str, int] = {}
    for uid in pgt.drops:
        root = uf.find(uid)
        if root not in ids:
            ids[root] = len(ids)
        groups[uid] = ids[root]
    return groups


# ---------------------------------------------------------------------------
# array path: shared merge machinery
# ---------------------------------------------------------------------------


def _resolve_labels(parent: List[int]) -> np.ndarray:
    """Collapse a union-find forest to dense partition labels, vectorized."""
    par = np.asarray(parent, dtype=np.int64)
    while True:
        pp = par[par]
        if np.array_equal(pp, par):
            break
        par = pp
    return np.unique(par, return_inverse=True)[1].astype(np.int32)


class _ArrayMerger:
    """Union-find merge of drops with incremental per-level DoP tracking."""

    def __init__(self, pgt: CompiledPGT, dop: int) -> None:
        self.dop = dop
        self.n = pgt.num_drops
        self.parent = list(range(self.n))
        self.levels = pgt.topo_levels().tolist()
        self.is_app = (pgt.kind_arr == KIND_APP).tolist()
        # per-root level->app-count; singletons are implicit (lazy dicts)
        self.widths: Dict[int, Dict[int, int]] = {}

    def find(self, x: int) -> int:
        parent = self.parent
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def _width_of(self, root: int) -> Dict[int, int]:
        w = self.widths.get(root)
        if w is None:
            w = {self.levels[root]: 1} if self.is_app[root] else {}
        return w

    def try_merge(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return True
        wa, wb = self._width_of(ra), self._width_of(rb)
        small, big = (wa, wb) if len(wa) <= len(wb) else (wb, wa)
        for lvl, c in small.items():
            if big.get(lvl, 0) + c > self.dop:
                return False
        merged = dict(big)
        for lvl, c in small.items():
            merged[lvl] = merged.get(lvl, 0) + c
        self.parent[rb] = ra
        self.widths[ra] = merged
        self.widths.pop(rb, None)
        return True

    def labels(self) -> np.ndarray:
        return _resolve_labels(self.parent)


def _edge_merge_order(pgt: CompiledPGT, bandwidth: float) -> np.ndarray:
    cost = pgt.edge_volumes() / bandwidth
    return np.argsort(-cost, kind="stable")


def _merge_snapshots(pgt: CompiledPGT, a, dop: int, bandwidth: float,
                     max_trials: Optional[int] = None
                     ) -> List[Tuple[int, float, np.ndarray]]:
    """Sweep geometric prefixes of the cost-sorted edge list through the
    DoP-capped union-find merge, evaluating each checkpoint.

    Returns ``(k, makespan, labels)`` snapshots; ``k = 0`` is the trivial
    partitioning.  Evaluation is the exact event simulation for graphs up
    to ``EXACT_EVAL_MAX_DROPS``, the vectorized critical-path estimator
    above.  Shared by ``min_time`` (argmin) and ``min_res`` (deepest
    deadline-meeting prefix).
    """
    exact = pgt.num_drops <= EXACT_EVAL_MAX_DROPS

    def evaluate(labels: np.ndarray) -> float:
        if exact:
            return _simulate_arrays(a, labels, dop, bandwidth)
        return _critical_path_arrays(a, labels, bandwidth)

    merger = _ArrayMerger(pgt, dop)
    esrc = pgt.edge_src.tolist()
    edst = pgt.edge_dst.tolist()
    order = _edge_merge_order(pgt, bandwidth)
    if max_trials is not None:
        order = order[:max_trials]
    order_l = order.tolist()
    ne = len(order_l)
    ks = sorted({0, ne // 32, ne // 16, ne // 8, ne // 4, ne // 2, ne})
    snapshots: List[Tuple[int, float, np.ndarray]] = []
    prev = 0
    for k in ks:
        for j in range(prev, k):
            ei = order_l[j]
            merger.try_merge(esrc[ei], edst[ei])
        prev = k
        labels = merger.labels()
        snapshots.append((k, evaluate(labels), labels))
    return snapshots


# ---------------------------------------------------------------------------
# min_time
# ---------------------------------------------------------------------------


def _min_time_compiled(pgt: CompiledPGT, dop: int, bandwidth: float,
                       max_trials: Optional[int] = None) -> PartitionResult:
    a = _extract(pgt)
    n = pgt.num_drops
    if n == 0:
        pgt.partition = np.empty(0, dtype=np.int32)
        return PartitionResult(0, 0.0, "min_time", dop)

    snapshots = _merge_snapshots(pgt, a, dop, bandwidth, max_trials)
    best_k, best_t, best_labels = min(
        snapshots, key=lambda s: (s[1], -s[0]))   # ties -> fewer partitions

    pgt.partition = best_labels
    nparts = int(best_labels.max()) + 1 if best_labels.size else 0
    if n <= EXACT_EVAL_MAX_DROPS:
        makespan = best_t
    elif n <= EXACT_FINAL_MAX_DROPS:
        makespan = _simulate_arrays(a, best_labels, dop, bandwidth)
    else:
        makespan = best_t   # critical-path estimate (documented)
    return PartitionResult(nparts, makespan, "min_time", dop)


def min_time(pgt, dop: int = 8,
             bandwidth: float = DEFAULT_BANDWIDTH,
             anneal_iters: int = 0, seed: int = 0,
             max_trials: Optional[int] = None) -> PartitionResult:
    """``max_trials`` bounds the number of merge trials (dict path: each
    trial runs a full makespan simulation; array path: bounds the merge
    prefix).  The array path needs no budget — the full cost-sorted edge
    list is merged in O(E α(E))."""
    if isinstance(pgt, CompiledPGT):
        res = _min_time_compiled(pgt, dop, bandwidth, max_trials)
        if anneal_iters:
            # the annealer is view-based and representation-agnostic;
            # explicit opt-in, so the per-move simulation cost is expected
            ms = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                         objective="time")
            n = len({s.partition for s in pgt.drops.values()})
            return PartitionResult(n, ms, "min_time", dop)
        return res
    uids = list(pgt.drops)
    uf = _UnionFind(uids)

    # level-width tracking per merged group (incremental DoP bound)
    depth: Dict[str, int] = {}
    for uid in pgt.topological_order():
        depth[uid] = max((depth[p] + 1 for p in pgt.predecessors(uid)),
                         default=0)
    width: Dict[str, Dict[int, int]] = {}
    for uid in uids:
        if pgt.drops[uid].kind == "app":
            width[uid] = {depth[uid]: 1}
        else:
            width[uid] = {}

    def merged_width_ok(ra: str, rb: str) -> bool:
        wa, wb = width[ra], width[rb]
        small, big = (wa, wb) if len(wa) < len(wb) else (wb, wa)
        return all(big.get(d, 0) + c <= dop for d, c in small.items())

    # heaviest-edge-first internalisation
    edges = sorted(
        ((edge_cost(pgt, s, d, bandwidth), s, d) for s, d, _ in pgt.edges),
        key=lambda t: -t[0])
    if max_trials is not None:
        edges = edges[:max_trials]

    _assign(pgt, _renumber(uf, pgt))
    best_time = simulate_makespan(pgt, dop, bandwidth)

    for cost, s, d in edges:
        ra, rb = uf.find(s), uf.find(d)
        if ra == rb:
            continue
        if not merged_width_ok(ra, rb):
            continue
        # tentatively merge and check completion time does not regress
        saved_parent = dict(uf.parent)
        saved_rank = dict(uf.rank)
        root = uf.union(ra, rb)
        _assign(pgt, _renumber(uf, pgt))
        t = simulate_makespan(pgt, dop, bandwidth)
        if t <= best_time + 1e-12:
            best_time = t
            other = rb if root == ra else ra
            merged = dict(width[root])
            for k, v in width[other].items():
                merged[k] = merged.get(k, 0) + v
            width[root] = merged
        else:
            uf.parent, uf.rank = saved_parent, saved_rank
    groups = _renumber(uf, pgt)
    _assign(pgt, groups)

    if anneal_iters:
        best_time = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                            objective="time")
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, best_time, "min_time", dop)


# ---------------------------------------------------------------------------
# min_res
# ---------------------------------------------------------------------------


def _min_res_compiled(pgt: CompiledPGT, deadline: float, dop: int,
                      bandwidth: float) -> PartitionResult:
    a = _extract(pgt)
    n = pgt.num_drops
    if n == 0:
        pgt.partition = np.empty(0, dtype=np.int32)
        return PartitionResult(0, 0.0, "min_res", dop)
    lower = _critical_path_arrays(a, None, bandwidth)
    deadline = max(deadline, lower)

    exact = n <= EXACT_EVAL_MAX_DROPS

    def evaluate(lab: np.ndarray) -> float:
        if exact:
            return _simulate_arrays(a, lab, dop, bandwidth)
        return _critical_path_arrays(a, lab, bandwidth)

    # cost-ordered internalisation, but — unlike min_time — the merge depth
    # is *chosen by the deadline*: among geometric prefixes of the sorted
    # edge list, take the deepest whose makespan still meets the deadline
    # (maximal internalisation under the DoP cap can serialize independent
    # apps and overshoot a deadline the trivial partitioning meets)
    snapshots = _merge_snapshots(pgt, a, dop, bandwidth)
    meeting = [s for s in snapshots if s[1] <= deadline * (1 + 1e-9)]
    if meeting:
        # deepest merge (fewest partitions) that meets the deadline
        _, t, labels = max(meeting, key=lambda s: s[0])
        # then binary-search the partition COUNT: fold the labelling into k
        # load-balanced bins (respecting the per-level DoP caps) and find
        # the smallest k whose evaluated makespan still meets the deadline.
        # This replaces the old greedy pairwise partition folding, which
        # stopped at the first blocked pair and left the count approximate.
        labels, t = _min_parts_search(pgt, labels, deadline, dop, evaluate,
                                      t)
    else:
        # deadline unmeetable: best-effort fastest assignment
        _, t, labels = min(snapshots, key=lambda s: s[1])

    pgt.partition = labels
    nparts = int(labels.max()) + 1 if labels.size else 0
    if not exact and n <= EXACT_FINAL_MAX_DROPS:
        t = _simulate_arrays(a, labels, dop, bandwidth)
    return PartitionResult(nparts, t, "min_res", dop)


def _fold_to_k(labels: np.ndarray, loads: np.ndarray,
               pwidths: List[Dict[int, int]], dop: int,
               k: int) -> Optional[np.ndarray]:
    """Fold a partitioning into <= k bins: heaviest partitions first, each
    into the least-loaded bin whose per-level app widths stay within the
    DoP cap.  Returns the folded (dense) labels, or None when the width
    caps make k bins infeasible."""
    import heapq as _hq
    nparts = loads.shape[0]
    if k >= nparts:
        return labels
    remap = np.empty(nparts, dtype=np.int32)
    # LPT with k machines: k empty bins up front, heaviest partition into
    # the least-loaded bin whose width caps still hold
    bin_load = [0.0] * k
    bin_width: List[Dict[int, int]] = [dict() for _ in range(k)]
    heap: List[Tuple[float, int]] = [(0.0, b) for b in range(k)]
    for p in np.argsort(-loads, kind="stable").tolist():
        wp = pwidths[p]
        placed = -1
        popped: List[Tuple[float, int]] = []
        while heap:
            load, b = _hq.heappop(heap)
            if load != bin_load[b]:
                continue                   # stale entry
            wb = bin_width[b]
            if all(wb.get(l, 0) + c <= dop for l, c in wp.items()):
                placed = b
                break
            popped.append((load, b))
        for e in popped:
            _hq.heappush(heap, e)
        if placed < 0:
            return None
        wb = bin_width[placed]
        for l, c in wp.items():
            wb[l] = wb.get(l, 0) + c
        bin_load[placed] += float(loads[p])
        _hq.heappush(heap, (bin_load[placed], placed))
        remap[p] = placed
    folded = remap[labels]
    # dense renumber (some of the k bins may have stayed empty)
    return np.unique(folded, return_inverse=True)[1].astype(np.int32)


def _min_parts_search(pgt: CompiledPGT, labels: np.ndarray, deadline: float,
                      dop: int, evaluate, t_best: float
                      ) -> Tuple[np.ndarray, float]:
    """Binary search on the partition count over the exact-sim evaluator.

    ``labels`` must meet the deadline.  Probes fold(k) for k in
    [1, nparts] and returns the labelling of the smallest k found whose
    evaluated makespan still meets the deadline (O(log P) evaluations).
    """
    nparts = int(labels.max()) + 1
    if nparts <= 1:
        return labels, t_best
    loads = np.bincount(labels, weights=pgt.weight_arr, minlength=nparts)
    lv = pgt.topo_levels()
    pwidths: List[Dict[int, int]] = [dict() for _ in range(nparts)]
    for i in np.flatnonzero(pgt.kind_arr == KIND_APP).tolist():
        w = pwidths[labels[i]]
        l = int(lv[i])
        w[l] = w.get(l, 0) + 1
    best_labels, best_t = labels, t_best
    lo, hi = 1, nparts
    while lo < hi:
        mid = (lo + hi) // 2
        folded = _fold_to_k(labels, loads, pwidths, dop, mid)
        if folded is not None:
            tt = evaluate(folded)
            if tt <= deadline * (1 + 1e-9):
                hi = mid
                best_labels, best_t = folded, tt
                continue
        lo = mid + 1
    return best_labels, best_t


def min_res(pgt, deadline: float, dop: int = 8,
            bandwidth: float = DEFAULT_BANDWIDTH,
            anneal_iters: int = 0, seed: int = 0) -> PartitionResult:
    """Greedy topological packing into as few partitions as possible."""
    if isinstance(pgt, CompiledPGT):
        res = _min_res_compiled(pgt, deadline, dop, bandwidth)
        if anneal_iters:
            ms = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                         objective="res", deadline=max(
                             deadline, critical_path(
                                 pgt, bandwidth, partitioned=False)))
            n = len({s.partition for s in pgt.drops.values()})
            return PartitionResult(n, ms, "min_res", dop)
        return res
    order = pgt.topological_order()
    # lower bound on achievable makespan: unpartitioned critical path
    lower = critical_path(pgt, bandwidth, partitioned=False)
    deadline = max(deadline, lower)

    parts: List[Set[str]] = []
    assignment: Dict[str, int] = {}

    def level_ok(members: Set[str], uid: str) -> bool:
        trial = set(members)
        trial.add(uid)
        return _partition_dop(pgt, trial) <= dop

    for uid in order:
        placed = False
        # prefer the partition of a predecessor (internalise heavy edges)
        cand: List[int] = []
        for p in pgt.predecessors(uid):
            if p in assignment and assignment[p] not in cand:
                cand.append(assignment[p])
        cand.extend(i for i in range(len(parts)) if i not in cand)
        for i in cand:
            if not level_ok(parts[i], uid):
                continue
            parts[i].add(uid)
            assignment[uid] = i
            pgt.drops[uid].partition = i
            t = simulate_makespan(pgt, dop, bandwidth)
            if t <= deadline * (1 + 1e-9):
                placed = True
                break
            parts[i].discard(uid)
            del assignment[uid]
        if not placed:
            parts.append({uid})
            assignment[uid] = len(parts) - 1
            pgt.drops[uid].partition = len(parts) - 1

    makespan = simulate_makespan(pgt, dop, bandwidth)
    if anneal_iters:
        makespan = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                           objective="res", deadline=deadline)
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, makespan, "min_res", dop)


# ---------------------------------------------------------------------------
# simulated annealing refinement (paper cites [51] simulated annealing)
# ---------------------------------------------------------------------------


def _anneal(pgt, dop: int, bandwidth: float,
            iters: int, seed: int, objective: str,
            deadline: Optional[float] = None) -> float:
    """Simulated-annealing refinement over the drops-view API
    (representation-agnostic: dict PGTs and CompiledPGTs both work)."""
    rng = random.Random(seed)
    uids = list(pgt.drops)
    cur_parts = {u: pgt.drops[u].partition for u in uids}
    nparts = max(cur_parts.values()) + 1 if cur_parts else 1

    def score() -> float:
        t = simulate_makespan(pgt, dop, bandwidth)
        n = len({s.partition for s in pgt.drops.values()})
        if objective == "time":
            return t + 1e-9 * n
        # res: minimise partitions, deadline as penalty
        pen = 0.0 if (deadline is None or t <= deadline * (1 + 1e-9)) \
            else 1e6 * (t - deadline)
        return n + pen

    cur = score()
    best = cur
    best_parts = dict(cur_parts)
    temp0 = max(cur, 1.0)
    for k in range(iters):
        u = rng.choice(uids)
        old = pgt.drops[u].partition
        new = rng.randrange(nparts)
        if new == old:
            continue
        pgt.drops[u].partition = new
        members = {x for x in uids if pgt.drops[x].partition == new}
        if _partition_dop(pgt, members) > dop:
            pgt.drops[u].partition = old
            continue
        s = score()
        temp = temp0 * (1.0 - k / max(iters, 1)) + 1e-9
        if s <= cur or rng.random() < math.exp(-(s - cur) / temp):
            cur = s
            if s < best:
                best = s
                best_parts = {x: pgt.drops[x].partition for x in uids}
        else:
            pgt.drops[u].partition = old
    for x, p in best_parts.items():
        pgt.drops[x].partition = p
    return simulate_makespan(pgt, dop, bandwidth)
