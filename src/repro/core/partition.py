"""Logical partitioning of the PGT (paper §3.4, step 3).

Two families, exactly as the paper describes:

* ``min_time`` — "produce an optimal number of partitions such that first the
  total completion time of the pipeline ... is minimised, and second at any
  point in time the number of drops running in parallel within a single
  partition is no greater than a Degree of Parallelism (DoP) threshold."
  Implemented as edge-zeroing internalisation (Sarkar-style): start with one
  partition per drop and merge across data-movement edges in descending cost
  order while the per-level app width of every partition stays within the
  DoP cap.

* ``min_res`` — "minimise the number of produced partitions subject to
  satisfying completion deadline and the DoP threshold constraints."

Each family has two implementations dispatched on the PGT type:

* the seed **dict path** (``PhysicalGraphTemplate``): merge trials validated
  with a full makespan simulation each (plus optional simulated-annealing
  refinement) — O(E · sim); the semantic reference, fine to ~10^4 drops.
* the **array path** (``CompiledPGT``): union-find over int32 ids with
  incremental per-level width tracking, candidate *prefixes* of the
  cost-sorted edge list evaluated with the vectorized critical-path
  estimator (exact event simulation for small graphs), best prefix kept —
  O(E α(E) + checkpoints · E).  This is what sustains the paper's
  millions-of-drops translate regime.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .logical import GraphValidationError
from .pgt import KIND_APP, CompiledPGT
from .schedule import (DEFAULT_BANDWIDTH, PrefixCP, _critical_path_arrays,
                       _extract, _simulate_arrays, critical_path, edge_cost,
                       simulate_makespan)
from .substrate import PartitionHierarchy
from .substrate import dense_labels as _dense_labels
from .unroll import PhysicalGraphTemplate

# graphs up to this many drops evaluate merge checkpoints with the exact
# event simulation (guarantees makespan never regresses past the trivial
# partitioning); larger graphs use the vectorized critical-path estimator
EXACT_EVAL_MAX_DROPS = 20_000
# largest graph for which the *final* reported makespan is exact-simulated
EXACT_FINAL_MAX_DROPS = 400_000


@dataclass
class PartitionResult:
    num_partitions: int
    makespan: float
    algorithm: str
    dop: int


# ---------------------------------------------------------------------------
# Degree-of-parallelism accounting
# ---------------------------------------------------------------------------


def _partition_dop(pgt, members: Set[str]) -> int:
    """Max antichain width restricted to a partition's app drops.

    Exact max-antichain is expensive; we use the standard level-width
    over-approximation (drops at the same DAG depth can run concurrently),
    which is what constrains the schedule in practice.
    """
    depth: Dict[str, int] = {}
    width: Dict[int, int] = {}
    for uid in pgt.topological_order():
        d = 0
        for p in pgt.predecessors(uid):
            d = max(d, depth[p] + 1)
        depth[uid] = d
        if uid in members and pgt.drops[uid].kind == "app":
            width[d] = width.get(d, 0) + 1
    return max(width.values()) if width else 0


class _UnionFind:
    def __init__(self, items: List[str]) -> None:
        self.parent = {i: i for i in items}
        self.rank = {i: 0 for i in items}

    def find(self, x: str) -> str:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return ra


def _assign(pgt: PhysicalGraphTemplate, groups: Dict[str, int]) -> None:
    for uid, part in groups.items():
        pgt.drops[uid].partition = part


def _renumber(uf: "_UnionFind", pgt: PhysicalGraphTemplate) -> Dict[str, int]:
    ids: Dict[str, int] = {}
    groups: Dict[str, int] = {}
    for uid in pgt.drops:
        root = uf.find(uid)
        if root not in ids:
            ids[root] = len(ids)
        groups[uid] = ids[root]
    return groups


# ---------------------------------------------------------------------------
# array path: shared merge machinery
# ---------------------------------------------------------------------------


def _resolve_labels(parent: List[int]) -> np.ndarray:
    """Collapse a union-find forest to dense partition labels, vectorized."""
    par = np.asarray(parent, dtype=np.int64)
    while True:
        pp = par[par]
        if np.array_equal(pp, par):
            break
        par = pp
    return np.unique(par, return_inverse=True)[1].astype(np.int32)


class _ArrayMerger:
    """Union-find merge of drops with incremental per-level DoP tracking."""

    def __init__(self, pgt: CompiledPGT, dop: int) -> None:
        self.dop = dop
        self.n = pgt.num_drops
        self.parent = list(range(self.n))
        self.levels = pgt.topo_levels().tolist()
        self.is_app = (pgt.kind_arr == KIND_APP).tolist()
        # per-root level->app-count; singletons are implicit (lazy dicts)
        self.widths: Dict[int, Dict[int, int]] = {}

    def find(self, x: int) -> int:
        parent = self.parent
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def _width_of(self, root: int) -> Dict[int, int]:
        w = self.widths.get(root)
        if w is None:
            w = {self.levels[root]: 1} if self.is_app[root] else {}
        return w

    def try_merge(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return True
        wa, wb = self._width_of(ra), self._width_of(rb)
        small, big = (wa, wb) if len(wa) <= len(wb) else (wb, wa)
        for lvl, c in small.items():
            if big.get(lvl, 0) + c > self.dop:
                return False
        merged = dict(big)
        for lvl, c in small.items():
            merged[lvl] = merged.get(lvl, 0) + c
        self.parent[rb] = ra
        self.widths[ra] = merged
        self.widths.pop(rb, None)
        return True

    def labels(self) -> np.ndarray:
        return _resolve_labels(self.parent)


def _edge_merge_order(pgt: CompiledPGT, bandwidth: float) -> np.ndarray:
    cost = pgt.edge_volumes()
    if cost.size == 0 or cost.max() == cost.min():
        # all ties: the stable sort would return the identity anyway
        return np.arange(cost.size, dtype=np.int64)
    return np.argsort(-cost, kind="stable")


class _BatchedMerger:
    """Vectorized DoP-capped edge-zeroing for large ``CompiledPGT``s.

    Processes a cost-ordered edge window in *rounds* of bulk numpy
    operations instead of one Python union-find walk per edge:

    1. resolve current partition roots of the window's endpoints,
    2. each partition elects its lowest-order crossing edge (*top*);
       edges that are top for **both** endpoints merge as a matching,
    3. edges that are top for exactly one endpoint form *hub sweeps*: all
       pending merges into one partition are resolved together with a
       cumulative per-level width scan in edge order (the star pattern —
       e.g. one source feeding 10^5 scattered branches — that a matching
       alone would need 10^5 rounds for),
    4. rejected merges (a DoP level-width cap would be exceeded) retire
       their edge permanently, mirroring the sequential path's
       attempt-once semantics.

    Width caps are enforced exactly.  Cheap sufficient conditions
    (combined app count <= dop, or disjoint app level ranges) avoid
    building the per-level tables for the common case.  Merge *results*
    can differ from the strictly sequential order when several candidate
    merges contend for one partition in the same round — the snapshot
    evaluation in ``_merge_snapshots`` judges the outcome either way.
    """

    _BIG = np.iinfo(np.int64).max
    # drop/edge ids all fit int32; the hot per-round arrays use it to
    # halve memory traffic (the rounds are bandwidth-bound)
    _BIG32 = np.iinfo(np.int32).max

    def __init__(self, pgt: CompiledPGT, dop: int) -> None:
        n = pgt.num_drops
        self.n = n
        self.dop = dop
        self.parent = np.arange(n, dtype=np.int32)
        self._dirty = False
        self.levels = pgt.topo_levels()
        self.lspan = int(self.levels.max()) + 1 if n else 1
        is_app = pgt.kind_arr == KIND_APP
        self.app_idx = np.flatnonzero(is_app)
        self.app_lv = self.levels[self.app_idx].astype(np.int32)
        # per-root scalars for the cheap cap tests
        self.app_cnt = is_app.astype(np.int32)
        self.lv_min = np.where(is_app, self.levels,
                               self._BIG32).astype(np.int32)
        self.lv_max = np.where(is_app, self.levels,
                               -1).astype(np.int32)
        self.esrc = pgt.edge_src            # already int32
        self.edst = pgt.edge_dst
        self._top = np.full(n, self._BIG32, dtype=np.int32)
        self._slot = np.full(n, -1, dtype=np.int32)       # sweep scratch
        self._hub_slot = np.full(n, -1, dtype=np.int32)
        # role marks (hub / partner) as a stamped scratch array: bumping
        # the stamp retires a whole round's marks without memsets
        self._mark = np.zeros(n, dtype=np.int32)
        self._stamp = 0

    # -- union-find ---------------------------------------------------------
    def _resolve(self, ids: np.ndarray) -> np.ndarray:
        # no write-back needed: labels() globally compresses the forest on
        # every merging round, so chains here are at most a couple deep
        par = self.parent
        r = par[ids]
        if not self._dirty:               # forest is flat: one gather
            return r
        while True:
            rr = par[r]
            if not (rr != r).any():
                return r
            r = rr

    def labels(self) -> np.ndarray:
        """Current root label per drop (path-compresses the forest)."""
        if not self._dirty:
            return self.parent
        par = self.parent
        while True:
            pp = par[par]
            if np.array_equal(pp, par):
                break
            par = pp
        self.parent = par
        self._dirty = False
        return par

    # -- cap checks ---------------------------------------------------------
    def _cheap_ok(self, pa: np.ndarray, pb: np.ndarray) -> np.ndarray:
        """Sufficient (never unsafe) vectorized width-cap test."""
        return ((self.app_cnt[pa] + self.app_cnt[pb] <= self.dop)
                | (self.lv_max[pa] < self.lv_min[pb])
                | (self.lv_max[pb] < self.lv_min[pa]))

    def _exact_pair_ok(self, lab: np.ndarray, pa: np.ndarray,
                       pb: np.ndarray) -> np.ndarray:
        """Exact pairwise width check: per-level app counts of pa[i]+pb[i]
        must stay within dop.  One bulk histogram over the member apps."""
        k = pa.shape[0]
        pairid = self._slot                       # scratch, reset below
        pairid[pa] = np.arange(k)
        pairid[pb] = np.arange(k)
        sel = pairid[lab[self.app_idx]]
        pairid[pa] = -1
        pairid[pb] = -1
        m = sel >= 0
        if not m.any():
            return np.ones(k, dtype=bool)
        keys = sel[m] * np.int64(self.lspan) + self.app_lv[m]
        uniq, counts = np.unique(keys, return_counts=True)
        ok = np.ones(k, dtype=bool)
        ok[np.unique(uniq[counts > self.dop] // self.lspan)] = False
        return ok

    def _apply(self, pa: np.ndarray, pb: np.ndarray) -> None:
        """Merge roots pb into pa (both sides distinct — matched pairs)
        + update the cheap-test scalars."""
        self.parent[pb] = pa
        self._dirty = True
        self.app_cnt[pa] += self.app_cnt[pb]
        self.lv_min[pa] = np.minimum(self.lv_min[pa], self.lv_min[pb])
        self.lv_max[pa] = np.maximum(self.lv_max[pa], self.lv_max[pb])

    def _apply_grouped(self, hubs: np.ndarray,
                       partners: np.ndarray) -> None:
        """Merge each (sorted, possibly repeated) hub's partners into it.

        A fancy ``+=`` would drop all but one increment per duplicated
        hub; the hub runs are contiguous, so segment ``reduceat``s give
        the per-hub aggregates without a slow unbuffered scatter."""
        if hubs.size == 0:
            return
        self.parent[partners] = hubs
        self._dirty = True
        starts = np.flatnonzero(
            np.concatenate(([True], hubs[1:] != hubs[:-1])))
        uh = hubs[starts]
        self.app_cnt[uh] += np.add.reduceat(self.app_cnt[partners], starts)
        self.lv_min[uh] = np.minimum(
            self.lv_min[uh], np.minimum.reduceat(self.lv_min[partners],
                                                 starts))
        self.lv_max[uh] = np.maximum(
            self.lv_max[uh], np.maximum.reduceat(self.lv_max[partners],
                                                 starts))

    # -- hub sweeps ---------------------------------------------------------
    def _sweep_hubs(self, lab: np.ndarray, hubs: np.ndarray,
                    partners: np.ndarray) -> np.ndarray:
        """Resolve all pending merges into each hub partition at once.

        Input arrays are sorted by (hub, edge order).  For every hub the
        partners' per-level app counts are accumulated in order; partners
        before the first level-cap breach merge, the rest are retired —
        exactly what attempting them one by one against the growing hub
        would do whenever the breach is monotone (identical partner
        shapes), and a conservative subset otherwise.  Returns the
        accept mask.
        """
        dop = self.dop
        # cumulative scalar count along each hub run as a first cut: the
        # total-app-count bound is sufficient (a level can never hold more
        # apps than the partition does); the exact per-level scan below
        # only runs for runs that breach it
        grp_new = np.concatenate(([True], hubs[1:] != hubs[:-1]))
        heads = np.flatnonzero(grp_new)
        run_len = np.diff(np.concatenate((heads, [hubs.size])))
        run_of = np.cumsum(grp_new) - 1                  # pos -> run id
        nruns = int(heads.size)
        csum = np.cumsum(self.app_cnt[partners])
        base = np.repeat(csum[heads] - self.app_cnt[partners[grp_new]],
                         run_len)
        cum_cnt = csum - base + self.app_cnt[hubs]
        scalar_ok = cum_cnt <= dop
        if bool(scalar_ok.all()):
            return np.ones(hubs.size, dtype=bool)
        pos = np.arange(hubs.size, dtype=np.int64)
        inrun = pos - heads[run_of]
        # scalar-clean runs accept everything without building any rows;
        # breaching runs get the exact per-level cumulative scan — over a
        # geometric *prefix* only: the accept boundary j* depends just on
        # the partners before it, so scanning the first K per run decides
        # it whenever the breach lies within (a saturated star resolves
        # with ~dop rows instead of one row per member app)
        run_breach = np.zeros(nruns, dtype=bool)
        run_breach[run_of[~scalar_ok]] = True
        j_star = np.full(nruns, self._BIG, dtype=np.int64)
        undecided = run_breach.copy()
        slot = self._slot                         # scratch, reset below
        hub_slot = self._hub_slot
        app_roots = lab[self.app_idx]
        k_scan = max(4 * dop, 64)
        while undecided.any():
            scan = undecided[run_of] & (inrun < k_scan)
            sp = partners[scan]
            slot[sp] = pos[scan]
            uheads = heads[undecided]
            hub_slot[hubs[uheads]] = uheads
            ps = slot[app_roots]
            hs = hub_slot[app_roots]
            slot[sp] = -1
            hub_slot[hubs[uheads]] = -1
            # rows: (run id, level, order-within-run, count 1 each)
            pm = ps >= 0
            hm = hs >= 0
            rows_run = np.concatenate((run_of[ps[pm]], run_of[hs[hm]]))
            rows_lv = np.concatenate((self.app_lv[pm], self.app_lv[hm]))
            # hub apps sort before every partner (order -1)
            rows_j = np.concatenate((ps[pm], np.full(int(hm.sum()), -1)))
            kspan = hubs.size + 2
            if nruns * self.lspan * kspan < (1 << 62):
                # fused single-key argsort (cheaper than 3-key lexsort)
                order = np.argsort(
                    (rows_run * np.int64(self.lspan) + rows_lv)
                    * np.int64(kspan) + rows_j + 1, kind="stable")
            else:                               # pragma: no cover - huge
                order = np.lexsort((rows_j, rows_lv, rows_run))
            rows_run, rows_lv, rows_j = (rows_run[order], rows_lv[order],
                                         rows_j[order])
            seg = np.concatenate(([True], (rows_run[1:] != rows_run[:-1])
                                  | (rows_lv[1:] != rows_lv[:-1])))
            idx = np.arange(rows_run.size, dtype=np.int64)
            seg_start = np.repeat(idx[seg], np.diff(np.concatenate(
                (np.flatnonzero(seg), [rows_run.size]))))
            cum = idx - seg_start + 1                    # per (run, level)
            breach = cum > dop
            if breach.any():
                bj = rows_j[breach]
                # a breach on a hub row (j == -1) would mean the hub
                # already violates — impossible by construction
                bj = np.where(bj < 0, 0, bj)
                np.minimum.at(j_star, rows_run[breach], bj)
                undecided &= j_star == self._BIG         # found => decided
            # breach-free runs fully covered by this prefix are clean
            undecided &= run_len > k_scan
            k_scan *= 8
        return pos < j_star[run_of]

    # -- main entry ---------------------------------------------------------
    def merge_window(self, eids: np.ndarray, guard_rounds: int = 200
                     ) -> None:
        """Attempt every edge of ``eids`` (already cost-ordered) once."""
        self.merge_ordered(self.esrc[eids], self.edst[eids], guard_rounds)

    def merge_ordered(self, ew_src: np.ndarray, ew_dst: np.ndarray,
                      guard_rounds: int = 200) -> None:
        """Like :meth:`merge_window` but over pre-gathered endpoint
        arrays (the snapshot sweep gathers the whole cost order once and
        hands out zero-copy window slices)."""
        if ew_src.size == 0:
            return
        pending = np.arange(ew_src.size, dtype=np.int32)
        for _ in range(guard_rounds):
            if pending.size == 0:
                return
            ra = self._resolve(ew_src[pending])
            rb = self._resolve(ew_dst[pending])
            cross = ra != rb
            if not cross.all():
                pending = pending[cross]
                if pending.size == 0:
                    return
                ra, rb = ra[cross], rb[cross]
            pending = self._round(pending, ra, rb)
        # pathological contention (should not happen — every round
        # resolves at least the active chain tops): finish strictly
        # sequentially rather than failing translate
        self._finish_sequential(ew_src, ew_dst, pending)

    def _round(self, pending: np.ndarray, ra: np.ndarray,
               rb: np.ndarray) -> np.ndarray:
        """One vectorized merge round; returns the surviving edges.

        Structure: every partition elects its lowest-order pending edge
        (*top*).  An edge that is top for exactly one endpoint joins the
        other endpoint's *group* (hub); a mutual top joins the hub side
        (or merges immediately as an isolated pair when neither side has
        a group).  Groups chain along "my hub is your partner" links — a
        forest, ordered by edge priority — and are applied deepest layer
        first, so a hub always absorbs its own partners (updating its
        width scalars and member mapping) before a shallower group
        absorbs *it*: every cap check sees exact, current widths.
        """
        # election: lowest-order pending edge per root.  ``pending`` is
        # ascending, so writing both endpoint arrays interleaved in
        # *reverse* makes the last (= lowest-order) write win — a pair of
        # fancy-index stores instead of two slow ``minimum.at``s
        top = self._top
        top[ra] = self._BIG32
        top[rb] = self._BIG32
        w = pending.size
        cc = np.empty(2 * w, dtype=np.int32)
        pp = np.empty(2 * w, dtype=np.int32)
        cc[0::2], cc[1::2] = ra[::-1], rb[::-1]
        pp[0::2] = pp[1::2] = pending[::-1]
        top[cc] = pp
        ta, tb = top[ra] == pending, top[rb] == pending
        mutual = ta & tb
        single = ta ^ tb
        retire = np.zeros(pending.size, dtype=bool)
        mark = self._mark
        s_hub = self._stamp + 1            # role stamp for this round
        self._stamp += 1
        # hubs: partitions other elections point into (the side the edge
        # is NOT top for); their pending merges resolve together
        si = np.flatnonzero(single)
        hub = np.where(ta[si], rb[si], ra[si])
        partner = np.where(ta[si], ra[si], rb[si])
        mark[hub] = s_hub
        # a mutual top joins the hub side's group (its lowest-order
        # candidate); with hubs on both sides the src side wins — the
        # parity schedule below serialises the two groups.  With no hub
        # attached the pair is isolated and merges immediately.
        mi = np.flatnonzero(mutual)
        ha, hb = mark[ra[mi]] == s_hub, mark[rb[mi]] == s_hub
        isolated = mi[~(ha | hb)]
        mf = mi[ha | hb]
        fa = ha[ha | hb]                   # fold into ra side when hub
        if isolated.size:
            pa, pb = ra[isolated], rb[isolated]
            ok = self._cheap_ok(pa, pb)
            if not ok.all():
                bad = ~ok
                ok[bad] = self._exact_pair_ok(
                    self.labels(), pa[bad], pb[bad])
            self._apply(pa[ok], pb[ok])
            retire[isolated] = True        # merged or cap-rejected
        if mf.size or si.size:
            si = np.concatenate((si, mf))
            hub = np.concatenate((hub, np.where(fa, ra[mf], rb[mf])))
            partner = np.concatenate(
                (partner, np.where(fa, rb[mf], ra[mf])))
            depth = self._group_depths(hub, partner)
            # fused (depth desc, hub, order) key — deepest layer first
            dmax = int(depth.max())
            espan = np.int64(self.esrc.size + 1)
            if (dmax + 1) * self.n * int(espan) < (1 << 62):
                o = np.argsort(
                    (np.int64(dmax) - depth) * np.int64(self.n) * espan
                    + hub * espan + pending[si], kind="stable")
            else:                               # pragma: no cover - huge
                o = np.lexsort((pending[si], hub, dmax - depth))
            si, hub, partner, depth = si[o], hub[o], partner[o], depth[o]
            bounds = np.flatnonzero(np.concatenate(
                ([True], depth[1:] != depth[:-1]))).tolist() + [si.size]
            for lo, hi in zip(bounds, bounds[1:]):
                # one layer: hubs here are never partners of an
                # already-processed (deeper) layer's hub... the reverse:
                # their partners' own groups (deeper) have already been
                # applied, so member scans and scalars are exact
                acc = self._sweep_hubs(self.labels(), hub[lo:hi],
                                       partner[lo:hi])
                # each partner root occurs exactly once (its top edge is
                # unique), so bulk-applying the accepts is safe
                self._apply_grouped(hub[lo:hi][acc], partner[lo:hi][acc])
            retire[si] = True              # merged or cap-rejected
        return pending[~retire]

    def _finish_sequential(self, ew_src: np.ndarray, ew_dst: np.ndarray,
                           pending: np.ndarray) -> None:
        """Strictly sequential remainder: correctness valve for inputs
        that starve the round scheduler (not observed in practice)."""
        if pending.size == 0:
            return
        lab = self.labels()
        roots = np.unique(np.concatenate(
            (lab[ew_src[pending]], lab[ew_dst[pending]])))
        widths: Dict[int, Dict[int, int]] = {int(r): {} for r in roots}
        app_roots = lab[self.app_idx]
        m = np.isin(app_roots, roots)
        for r, l in zip(app_roots[m].tolist(), self.app_lv[m].tolist()):
            d = widths[r]
            d[l] = d.get(l, 0) + 1
        parent = self.parent

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = int(parent[x])
            return x

        for e in pending.tolist():
            a_, b_ = find(int(ew_src[e])), find(int(ew_dst[e]))
            if a_ == b_:
                continue
            wa, wb = widths[a_], widths[b_]
            small, big = (wa, wb) if len(wa) <= len(wb) else (wb, wa)
            if any(big.get(l, 0) + c > self.dop for l, c in small.items()):
                continue
            for l, c in small.items():
                big[l] = big.get(l, 0) + c
            parent[b_] = a_
            widths[a_] = big
            widths[b_] = {}
            self.app_cnt[a_] += self.app_cnt[b_]
            self.lv_min[a_] = min(self.lv_min[a_], self.lv_min[b_])
            self.lv_max[a_] = max(self.lv_max[a_], self.lv_max[b_])
        self._dirty = True

    def _group_depths(self, hub: np.ndarray,
                      partner: np.ndarray) -> np.ndarray:
        """Per-edge depth of the edge's group in the defers-to forest.

        Group links — "group(h) is a child of group(g) when h is one of
        g's partners" — form a forest (a cycle would need an edge-order
        contradiction).  Applying groups deepest-first keeps the width
        accounting exact: a hub absorbs its own partners (and has its
        scalars updated) before any shallower group absorbs *it*.  Depth
        is computed with pointer jumping in O(log depth) vectorized
        steps, no sort.
        """
        k = hub.size
        gof = self._slot                   # scratch: hub -> canonical slot
        gof[hub] = np.arange(k, dtype=np.int32)
        gid = gof[hub]                     # per-edge canonical group slot
        gof[hub] = -1
        pg = self._hub_slot                # scratch: partner -> its group
        pg[partner] = gid
        up_edge = pg[hub]                  # -1 => forest root
        pg[partner] = -1
        if not (up_edge >= 0).any():
            return np.zeros(k, dtype=np.int64)
        up = np.full(k, -1, dtype=np.int32)
        up[gid] = up_edge
        dep = (up >= 0).astype(np.int64)
        j = up.copy()
        while True:
            m = j >= 0
            if not m.any():
                break
            dj, jj = dep.copy(), j.copy()
            dep[m] += dj[jj[m]]
            j[m] = jj[jj[m]]
        return dep[gid]


def _record_hierarchy(pgt: CompiledPGT, best_k: int, best_labels: np.ndarray,
                      snapshots: List[Tuple[int, float, np.ndarray]]) -> None:
    """Record the merge hierarchy onto the PGT for the mapper.

    The kept labelling is the finest level; snapshots *deeper* along the
    merge prefix (``k > best_k``) are its coarser nested levels — the
    union-find only ever coarsens, so every kept partition maps into
    exactly one partition of each deeper snapshot.  ``map_partitions``
    consumes this instead of re-coarsening from scratch (see
    ``core/substrate.py``).
    """
    coarser = [_dense_labels(lab) for k, _, lab in snapshots if k > best_k]
    _, load, mem, count, eu, ev, ew = pgt.partition_graph_arrays()
    pgt._partition_hierarchy = PartitionHierarchy.from_labelings(
        [best_labels] + coarser, load, mem, count, eu, ev, ew)


def _merge_snapshots(pgt: CompiledPGT, a, dop: int, bandwidth: float,
                     max_trials: Optional[int] = None
                     ) -> List[Tuple[int, float, np.ndarray]]:
    """Sweep geometric prefixes of the cost-sorted edge list through the
    DoP-capped union-find merge, evaluating each checkpoint.

    Returns ``(k, makespan, labels)`` snapshots; ``k = 0`` is the trivial
    partitioning.  Shared by ``min_time`` (argmin) and ``min_res``
    (deepest deadline-meeting prefix).

    Two regimes, split at ``EXACT_EVAL_MAX_DROPS``:

    * small graphs keep the strictly sequential per-edge merge
      (:class:`_ArrayMerger`) and evaluate checkpoints with the exact
      event simulation — bit-compatible with the original behaviour;
    * large graphs use the vectorized :class:`_BatchedMerger` and the
      *incremental* :class:`~repro.core.schedule.PrefixCP` critical-path
      evaluator, which reuses the longest-path state across checkpoints
      (merges only ever internalise edges, so consecutive prefixes share
      almost all of it).  Snapshot labels in this regime are union-find
      root ids — callers densify the labelling they keep via
      :func:`_dense_labels`.
    """
    order = _edge_merge_order(pgt, bandwidth)
    if max_trials is not None:
        order = order[:max_trials]
    ne = int(order.size)
    exact = pgt.num_drops <= EXACT_EVAL_MAX_DROPS
    if exact:
        ks = sorted({0, ne // 32, ne // 16, ne // 8, ne // 4, ne // 2, ne})
    else:
        # the exact simulator's non-monotone makespans reward a fine
        # checkpoint grid; the estimator regime is monotone in practice,
        # so a thinner geometric schedule buys the same argmin for less
        # merge-window bookkeeping
        ks = sorted({0, ne // 16, ne // 4, ne})
    snapshots: List[Tuple[int, float, np.ndarray]] = []
    prev = 0
    if exact:
        merger = _ArrayMerger(pgt, dop)
        esrc = pgt.edge_src.tolist()
        edst = pgt.edge_dst.tolist()
        order_l = order.tolist()
        for k in ks:
            for j in range(prev, k):
                ei = order_l[j]
                merger.try_merge(esrc[ei], edst[ei])
            prev = k
            labels = merger.labels()
            snapshots.append(
                (k, _simulate_arrays(a, labels, dop, bandwidth), labels))
    else:
        bmerger = _BatchedMerger(pgt, dop)
        evaluator = PrefixCP(a, bandwidth)
        es_sorted = bmerger.esrc[order]
        ed_sorted = bmerger.edst[order]
        for k in ks:
            bmerger.merge_ordered(es_sorted[prev:k], ed_sorted[prev:k])
            prev = k
            labels = bmerger.labels().copy()
            snapshots.append((k, evaluator.evaluate(labels), labels))
    return snapshots


# ---------------------------------------------------------------------------
# min_time
# ---------------------------------------------------------------------------


def _min_time_compiled(pgt: CompiledPGT, dop: int, bandwidth: float,
                       max_trials: Optional[int] = None) -> PartitionResult:
    a = _extract(pgt)
    n = pgt.num_drops
    pgt._partition_hierarchy = None
    if n == 0:
        pgt.partition = np.empty(0, dtype=np.int32)
        return PartitionResult(0, 0.0, "min_time", dop)

    snapshots = _merge_snapshots(pgt, a, dop, bandwidth, max_trials)
    best_k, best_t, best_labels = min(
        snapshots, key=lambda s: (s[1], -s[0]))   # ties -> fewer partitions

    best_labels = _dense_labels(best_labels)
    pgt.partition = best_labels
    _record_hierarchy(pgt, best_k, best_labels, snapshots)
    nparts = int(best_labels.max()) + 1 if best_labels.size else 0
    if n <= EXACT_EVAL_MAX_DROPS:
        makespan = best_t
    elif n <= EXACT_FINAL_MAX_DROPS:
        makespan = _simulate_arrays(a, best_labels, dop, bandwidth)
    else:
        makespan = best_t   # critical-path estimate (documented)
    return PartitionResult(nparts, makespan, "min_time", dop)


def min_time(pgt, dop: int = 8,
             bandwidth: float = DEFAULT_BANDWIDTH,
             anneal_iters: int = 0, seed: int = 0,
             max_trials: Optional[int] = None) -> PartitionResult:
    """``max_trials`` bounds the number of merge trials (dict path: each
    trial runs a full makespan simulation; array path: bounds the merge
    prefix).  The array path needs no budget — the full cost-sorted edge
    list is merged in O(E α(E))."""
    if isinstance(pgt, CompiledPGT):
        res = _min_time_compiled(pgt, dop, bandwidth, max_trials)
        if anneal_iters:
            # the annealer is view-based and representation-agnostic;
            # explicit opt-in, so the per-move simulation cost is expected
            ms = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                         objective="time")
            n = len({s.partition for s in pgt.drops.values()})
            return PartitionResult(n, ms, "min_time", dop)
        return res
    uids = list(pgt.drops)
    uf = _UnionFind(uids)

    # level-width tracking per merged group (incremental DoP bound)
    depth: Dict[str, int] = {}
    for uid in pgt.topological_order():
        depth[uid] = max((depth[p] + 1 for p in pgt.predecessors(uid)),
                         default=0)
    width: Dict[str, Dict[int, int]] = {}
    for uid in uids:
        if pgt.drops[uid].kind == "app":
            width[uid] = {depth[uid]: 1}
        else:
            width[uid] = {}

    def merged_width_ok(ra: str, rb: str) -> bool:
        wa, wb = width[ra], width[rb]
        small, big = (wa, wb) if len(wa) < len(wb) else (wb, wa)
        return all(big.get(d, 0) + c <= dop for d, c in small.items())

    # heaviest-edge-first internalisation
    edges = sorted(
        ((edge_cost(pgt, s, d, bandwidth), s, d) for s, d, _ in pgt.edges),
        key=lambda t: -t[0])
    if max_trials is not None:
        edges = edges[:max_trials]

    _assign(pgt, _renumber(uf, pgt))
    best_time = simulate_makespan(pgt, dop, bandwidth)

    for cost, s, d in edges:
        ra, rb = uf.find(s), uf.find(d)
        if ra == rb:
            continue
        if not merged_width_ok(ra, rb):
            continue
        # tentatively merge and check completion time does not regress
        saved_parent = dict(uf.parent)
        saved_rank = dict(uf.rank)
        root = uf.union(ra, rb)
        _assign(pgt, _renumber(uf, pgt))
        t = simulate_makespan(pgt, dop, bandwidth)
        if t <= best_time + 1e-12:
            best_time = t
            other = rb if root == ra else ra
            merged = dict(width[root])
            for k, v in width[other].items():
                merged[k] = merged.get(k, 0) + v
            width[root] = merged
        else:
            uf.parent, uf.rank = saved_parent, saved_rank
    groups = _renumber(uf, pgt)
    _assign(pgt, groups)

    if anneal_iters:
        best_time = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                            objective="time")
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, best_time, "min_time", dop)


# ---------------------------------------------------------------------------
# min_res
# ---------------------------------------------------------------------------


def _min_res_compiled(pgt: CompiledPGT, deadline: float, dop: int,
                      bandwidth: float) -> PartitionResult:
    a = _extract(pgt)
    n = pgt.num_drops
    # min_res labellings are fold products, not the recorded merge chain —
    # any hierarchy from an earlier min_time run is stale for them
    pgt._partition_hierarchy = None
    if n == 0:
        pgt.partition = np.empty(0, dtype=np.int32)
        return PartitionResult(0, 0.0, "min_res", dop)
    lower = _critical_path_arrays(a, None, bandwidth)
    deadline = max(deadline, lower)

    exact = n <= EXACT_EVAL_MAX_DROPS
    # the fold probes below relabel non-monotonically; PrefixCP handles
    # that and shares its longest-path state across the O(log P) probes
    # (exactly equal to the from-scratch pass — see the test suite)
    probe_cp = None if exact else PrefixCP(a, bandwidth)

    def evaluate(lab: np.ndarray) -> float:
        if exact:
            return _simulate_arrays(a, lab, dop, bandwidth)
        return probe_cp.evaluate(lab)

    # cost-ordered internalisation, but — unlike min_time — the merge depth
    # is *chosen by the deadline*: among geometric prefixes of the sorted
    # edge list, take the deepest whose makespan still meets the deadline
    # (maximal internalisation under the DoP cap can serialize independent
    # apps and overshoot a deadline the trivial partitioning meets)
    snapshots = _merge_snapshots(pgt, a, dop, bandwidth)
    meeting = [s for s in snapshots if s[1] <= deadline * (1 + 1e-9)]
    if meeting:
        # deepest merge (fewest partitions) that meets the deadline
        _, t, labels = max(meeting, key=lambda s: s[0])
        # then binary-search the partition COUNT: fold the labelling into k
        # load-balanced bins (respecting the per-level DoP caps) and find
        # the smallest k whose evaluated makespan still meets the deadline.
        # This replaces the old greedy pairwise partition folding, which
        # stopped at the first blocked pair and left the count approximate.
        labels, t = _min_parts_search(pgt, _dense_labels(labels), deadline,
                                      dop, evaluate, t)
    else:
        # deadline unmeetable: best-effort fastest assignment
        _, t, labels = min(snapshots, key=lambda s: s[1])
        labels = _dense_labels(labels)

    pgt.partition = labels
    nparts = int(labels.max()) + 1 if labels.size else 0
    if not exact and n <= EXACT_FINAL_MAX_DROPS:
        t = _simulate_arrays(a, labels, dop, bandwidth)
    return PartitionResult(nparts, t, "min_res", dop)


def _fold_to_k(labels: np.ndarray, loads: np.ndarray,
               pwidths: List[Dict[int, int]], dop: int,
               k: int) -> Optional[np.ndarray]:
    """Fold a partitioning into <= k bins: heaviest partitions first, each
    into the least-loaded bin whose per-level app widths stay within the
    DoP cap.  Returns the folded (dense) labels, or None when the width
    caps make k bins infeasible."""
    import heapq as _hq
    nparts = loads.shape[0]
    if k >= nparts:
        return labels
    remap = np.empty(nparts, dtype=np.int32)
    # LPT with k machines: k empty bins up front, heaviest partition into
    # the least-loaded bin whose width caps still hold
    bin_load = [0.0] * k
    bin_width: List[Dict[int, int]] = [dict() for _ in range(k)]
    heap: List[Tuple[float, int]] = [(0.0, b) for b in range(k)]
    for p in np.argsort(-loads, kind="stable").tolist():
        wp = pwidths[p]
        placed = -1
        popped: List[Tuple[float, int]] = []
        while heap:
            load, b = _hq.heappop(heap)
            if load != bin_load[b]:
                continue                   # stale entry
            wb = bin_width[b]
            if all(wb.get(l, 0) + c <= dop for l, c in wp.items()):
                placed = b
                break
            popped.append((load, b))
        for e in popped:
            _hq.heappush(heap, e)
        if placed < 0:
            return None
        wb = bin_width[placed]
        for l, c in wp.items():
            wb[l] = wb.get(l, 0) + c
        bin_load[placed] += float(loads[p])
        _hq.heappush(heap, (bin_load[placed], placed))
        remap[p] = placed
    folded = remap[labels]
    # dense renumber (some of the k bins may have stayed empty)
    return np.unique(folded, return_inverse=True)[1].astype(np.int32)


def _min_parts_search(pgt: CompiledPGT, labels: np.ndarray, deadline: float,
                      dop: int, evaluate, t_best: float
                      ) -> Tuple[np.ndarray, float]:
    """Binary search on the partition count over the exact-sim evaluator.

    ``labels`` must meet the deadline.  Probes fold(k) for k in
    [1, nparts] and returns the labelling of the smallest k found whose
    evaluated makespan still meets the deadline (O(log P) evaluations).
    """
    nparts = int(labels.max()) + 1
    if nparts <= 1:
        return labels, t_best
    loads = np.bincount(labels, weights=pgt.weight_arr, minlength=nparts)
    lv = pgt.topo_levels()
    pwidths: List[Dict[int, int]] = [dict() for _ in range(nparts)]
    for i in np.flatnonzero(pgt.kind_arr == KIND_APP).tolist():
        w = pwidths[labels[i]]
        l = int(lv[i])
        w[l] = w.get(l, 0) + 1
    best_labels, best_t = labels, t_best
    lo, hi = 1, nparts
    while lo < hi:
        mid = (lo + hi) // 2
        folded = _fold_to_k(labels, loads, pwidths, dop, mid)
        if folded is not None:
            tt = evaluate(folded)
            if tt <= deadline * (1 + 1e-9):
                hi = mid
                best_labels, best_t = folded, tt
                continue
        lo = mid + 1
    return best_labels, best_t


def min_res(pgt, deadline: float, dop: int = 8,
            bandwidth: float = DEFAULT_BANDWIDTH,
            anneal_iters: int = 0, seed: int = 0) -> PartitionResult:
    """Greedy topological packing into as few partitions as possible."""
    if isinstance(pgt, CompiledPGT):
        res = _min_res_compiled(pgt, deadline, dop, bandwidth)
        if anneal_iters:
            ms = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                         objective="res", deadline=max(
                             deadline, critical_path(
                                 pgt, bandwidth, partitioned=False)))
            n = len({s.partition for s in pgt.drops.values()})
            return PartitionResult(n, ms, "min_res", dop)
        return res
    order = pgt.topological_order()
    # lower bound on achievable makespan: unpartitioned critical path
    lower = critical_path(pgt, bandwidth, partitioned=False)
    deadline = max(deadline, lower)

    parts: List[Set[str]] = []
    assignment: Dict[str, int] = {}

    def level_ok(members: Set[str], uid: str) -> bool:
        trial = set(members)
        trial.add(uid)
        return _partition_dop(pgt, trial) <= dop

    for uid in order:
        placed = False
        # prefer the partition of a predecessor (internalise heavy edges)
        cand: List[int] = []
        for p in pgt.predecessors(uid):
            if p in assignment and assignment[p] not in cand:
                cand.append(assignment[p])
        cand.extend(i for i in range(len(parts)) if i not in cand)
        for i in cand:
            if not level_ok(parts[i], uid):
                continue
            parts[i].add(uid)
            assignment[uid] = i
            pgt.drops[uid].partition = i
            t = simulate_makespan(pgt, dop, bandwidth)
            if t <= deadline * (1 + 1e-9):
                placed = True
                break
            parts[i].discard(uid)
            del assignment[uid]
        if not placed:
            parts.append({uid})
            assignment[uid] = len(parts) - 1
            pgt.drops[uid].partition = len(parts) - 1

    makespan = simulate_makespan(pgt, dop, bandwidth)
    if anneal_iters:
        makespan = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                           objective="res", deadline=deadline)
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, makespan, "min_res", dop)


# ---------------------------------------------------------------------------
# simulated annealing refinement (paper cites [51] simulated annealing)
# ---------------------------------------------------------------------------


def _anneal(pgt, dop: int, bandwidth: float,
            iters: int, seed: int, objective: str,
            deadline: Optional[float] = None) -> float:
    """Simulated-annealing refinement over the drops-view API
    (representation-agnostic: dict PGTs and CompiledPGTs both work)."""
    rng = random.Random(seed)
    uids = list(pgt.drops)
    cur_parts = {u: pgt.drops[u].partition for u in uids}
    nparts = max(cur_parts.values()) + 1 if cur_parts else 1

    def score() -> float:
        t = simulate_makespan(pgt, dop, bandwidth)
        n = len({s.partition for s in pgt.drops.values()})
        if objective == "time":
            return t + 1e-9 * n
        # res: minimise partitions, deadline as penalty
        pen = 0.0 if (deadline is None or t <= deadline * (1 + 1e-9)) \
            else 1e6 * (t - deadline)
        return n + pen

    cur = score()
    best = cur
    best_parts = dict(cur_parts)
    temp0 = max(cur, 1.0)
    for k in range(iters):
        u = rng.choice(uids)
        old = pgt.drops[u].partition
        new = rng.randrange(nparts)
        if new == old:
            continue
        pgt.drops[u].partition = new
        members = {x for x in uids if pgt.drops[x].partition == new}
        if _partition_dop(pgt, members) > dop:
            pgt.drops[u].partition = old
            continue
        s = score()
        temp = temp0 * (1.0 - k / max(iters, 1)) + 1e-9
        if s <= cur or rng.random() < math.exp(-(s - cur) / temp):
            cur = s
            if s < best:
                best = s
                best_parts = {x: pgt.drops[x].partition for x in uids}
        else:
            pgt.drops[u].partition = old
    for x, p in best_parts.items():
        pgt.drops[x].partition = p
    return simulate_makespan(pgt, dop, bandwidth)
