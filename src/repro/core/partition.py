"""Logical partitioning of the PGT (paper §3.4, step 3).

Two families, exactly as the paper describes:

* ``min_time`` — "produce an optimal number of partitions such that first the
  total completion time of the pipeline ... is minimised, and second at any
  point in time the number of drops running in parallel within a single
  partition is no greater than a Degree of Parallelism (DoP) threshold."
  Implemented as edge-zeroing internalisation (Sarkar-style): start with one
  partition per drop, repeatedly merge across the heaviest data-movement edge
  when doing so does not increase the estimated completion time and respects
  the DoP cap; refined with simulated annealing (the paper cites simulated
  annealing and stochastic local search for exactly this step).

* ``min_res`` — "minimise the number of produced partitions subject to
  satisfying completion deadline and the DoP threshold constraints."
  Implemented as topological bin-packing with deadline checks + annealing.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from .schedule import (DEFAULT_BANDWIDTH, critical_path, edge_cost,
                       simulate_makespan)
from .unroll import PhysicalGraphTemplate


@dataclass
class PartitionResult:
    num_partitions: int
    makespan: float
    algorithm: str
    dop: int


# ---------------------------------------------------------------------------
# Degree-of-parallelism accounting
# ---------------------------------------------------------------------------


def _partition_dop(pgt: PhysicalGraphTemplate, members: Set[str]) -> int:
    """Max antichain width restricted to a partition's app drops.

    Exact max-antichain is expensive; we use the standard level-width
    over-approximation (drops at the same DAG depth can run concurrently),
    which is what constrains the schedule in practice.
    """
    depth: Dict[str, int] = {}
    width: Dict[int, int] = {}
    for uid in pgt.topological_order():
        d = 0
        for p in pgt.predecessors(uid):
            d = max(d, depth[p] + 1)
        depth[uid] = d
        if uid in members and pgt.drops[uid].kind == "app":
            width[d] = width.get(d, 0) + 1
    return max(width.values()) if width else 0


class _UnionFind:
    def __init__(self, items: List[str]) -> None:
        self.parent = {i: i for i in items}
        self.rank = {i: 0 for i in items}

    def find(self, x: str) -> str:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: str, b: str) -> str:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return ra


def _assign(pgt: PhysicalGraphTemplate, groups: Dict[str, int]) -> None:
    for uid, part in groups.items():
        pgt.drops[uid].partition = part


def _renumber(uf: "_UnionFind", pgt: PhysicalGraphTemplate) -> Dict[str, int]:
    ids: Dict[str, int] = {}
    groups: Dict[str, int] = {}
    for uid in pgt.drops:
        root = uf.find(uid)
        if root not in ids:
            ids[root] = len(ids)
        groups[uid] = ids[root]
    return groups


# ---------------------------------------------------------------------------
# min_time
# ---------------------------------------------------------------------------


def min_time(pgt: PhysicalGraphTemplate, dop: int = 8,
             bandwidth: float = DEFAULT_BANDWIDTH,
             anneal_iters: int = 0, seed: int = 0,
             max_trials: Optional[int] = None) -> PartitionResult:
    """``max_trials`` bounds the number of merge trials (each trial runs a
    full makespan simulation, O(N log N)); for very large PGTs pass a
    budget — the heaviest data-movement edges are tried first, which is
    where nearly all of the win lives."""
    uids = list(pgt.drops)
    uf = _UnionFind(uids)

    # level-width tracking per merged group (incremental DoP bound)
    depth: Dict[str, int] = {}
    for uid in pgt.topological_order():
        depth[uid] = max((depth[p] + 1 for p in pgt.predecessors(uid)),
                         default=0)
    width: Dict[str, Dict[int, int]] = {}
    for uid in uids:
        if pgt.drops[uid].kind == "app":
            width[uid] = {depth[uid]: 1}
        else:
            width[uid] = {}

    def merged_width_ok(ra: str, rb: str) -> bool:
        wa, wb = width[ra], width[rb]
        small, big = (wa, wb) if len(wa) < len(wb) else (wb, wa)
        return all(big.get(d, 0) + c <= dop for d, c in small.items())

    # heaviest-edge-first internalisation
    edges = sorted(
        ((edge_cost(pgt, s, d, bandwidth), s, d) for s, d, _ in pgt.edges),
        key=lambda t: -t[0])
    if max_trials is not None:
        edges = edges[:max_trials]

    _assign(pgt, _renumber(uf, pgt))
    best_time = simulate_makespan(pgt, dop, bandwidth)

    for cost, s, d in edges:
        if cost <= 0.0:
            # zero-cost edges: merge freely if DoP allows (fewer partitions,
            # same makespan)
            pass
        ra, rb = uf.find(s), uf.find(d)
        if ra == rb:
            continue
        if not merged_width_ok(ra, rb):
            continue
        # tentatively merge and check completion time does not regress
        saved_parent = dict(uf.parent)
        saved_rank = dict(uf.rank)
        root = uf.union(ra, rb)
        _assign(pgt, _renumber(uf, pgt))
        t = simulate_makespan(pgt, dop, bandwidth)
        if t <= best_time + 1e-12:
            best_time = t
            other = rb if root == ra else ra
            merged = dict(width[root])
            for k, v in width[other].items():
                merged[k] = merged.get(k, 0) + v
            width[root] = merged
        else:
            uf.parent, uf.rank = saved_parent, saved_rank
    groups = _renumber(uf, pgt)
    _assign(pgt, groups)

    if anneal_iters:
        best_time = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                            objective="time")
    n = len(set(groups.values()))
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, best_time, "min_time", dop)


# ---------------------------------------------------------------------------
# min_res
# ---------------------------------------------------------------------------


def min_res(pgt: PhysicalGraphTemplate, deadline: float, dop: int = 8,
            bandwidth: float = DEFAULT_BANDWIDTH,
            anneal_iters: int = 0, seed: int = 0) -> PartitionResult:
    """Greedy topological packing into as few partitions as possible."""
    order = pgt.topological_order()
    # lower bound on achievable makespan: unpartitioned critical path
    lower = critical_path(pgt, bandwidth, partitioned=False)
    deadline = max(deadline, lower)

    parts: List[Set[str]] = []
    assignment: Dict[str, int] = {}

    def level_ok(members: Set[str], uid: str) -> bool:
        trial = set(members)
        trial.add(uid)
        return _partition_dop(pgt, trial) <= dop

    for uid in order:
        placed = False
        # prefer the partition of a predecessor (internalise heavy edges)
        cand: List[int] = []
        for p in pgt.predecessors(uid):
            if p in assignment and assignment[p] not in cand:
                cand.append(assignment[p])
        cand.extend(i for i in range(len(parts)) if i not in cand)
        for i in cand:
            if not level_ok(parts[i], uid):
                continue
            parts[i].add(uid)
            assignment[uid] = i
            pgt.drops[uid].partition = i
            t = simulate_makespan(pgt, dop, bandwidth)
            if t <= deadline * (1 + 1e-9):
                placed = True
                break
            parts[i].discard(uid)
            del assignment[uid]
        if not placed:
            parts.append({uid})
            assignment[uid] = len(parts) - 1
            pgt.drops[uid].partition = len(parts) - 1

    makespan = simulate_makespan(pgt, dop, bandwidth)
    if anneal_iters:
        makespan = _anneal(pgt, dop, bandwidth, anneal_iters, seed,
                           objective="res", deadline=deadline)
    n = len({s.partition for s in pgt.drops.values()})
    return PartitionResult(n, makespan, "min_res", dop)


# ---------------------------------------------------------------------------
# simulated annealing refinement (paper cites [51] simulated annealing)
# ---------------------------------------------------------------------------


def _anneal(pgt: PhysicalGraphTemplate, dop: int, bandwidth: float,
            iters: int, seed: int, objective: str,
            deadline: Optional[float] = None) -> float:
    rng = random.Random(seed)
    uids = list(pgt.drops)
    cur_parts = {u: pgt.drops[u].partition for u in uids}
    nparts = max(cur_parts.values()) + 1 if cur_parts else 1

    def score() -> float:
        t = simulate_makespan(pgt, dop, bandwidth)
        n = len({s.partition for s in pgt.drops.values()})
        if objective == "time":
            return t + 1e-9 * n
        # res: minimise partitions, deadline as penalty
        pen = 0.0 if (deadline is None or t <= deadline * (1 + 1e-9)) \
            else 1e6 * (t - deadline)
        return n + pen

    cur = score()
    best = cur
    best_parts = dict(cur_parts)
    temp0 = max(cur, 1.0)
    for k in range(iters):
        u = rng.choice(uids)
        old = pgt.drops[u].partition
        new = rng.randrange(nparts)
        if new == old:
            continue
        pgt.drops[u].partition = new
        members = {x for x in uids if pgt.drops[x].partition == new}
        if _partition_dop(pgt, members) > dop:
            pgt.drops[u].partition = old
            continue
        s = score()
        temp = temp0 * (1.0 - k / max(iters, 1)) + 1e-9
        if s <= cur or rng.random() < math.exp(-(s - cur) / temp):
            cur = s
            if s < best:
                best = s
                best_parts = {x: pgt.drops[x].partition for x in uids}
        else:
            pgt.drops[u].partition = old
    for x, p in best_parts.items():
        pgt.drops[x].partition = p
    return simulate_makespan(pgt, dop, bandwidth)
