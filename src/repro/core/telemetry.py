"""Array-native engine telemetry (paper §4.2, §5).

DALiuGE's managers expose the runtime status of every drop up the MM/DIM/NM
hierarchy so operators can watch a million-task pipeline execute; the
follow-up "Empirical Evaluation On the Applicability of the DALiuGE
Execution Framework" diagnoses pipeline behaviour from exactly that
per-drop status/timing data.  The compiled path deliberately publishes no
per-drop events — this module restores the *observability* without giving
back the throughput, by keeping telemetry in the same shape as the engine:
flat parallel arrays, stamped wave-at-a-time.

Three layers, all off by default and enabled via :class:`TelemetryConfig`:

* :class:`Timeline` — per-drop ``t_start``/``t_end`` (float64 monotonic
  seconds), wave index and executing-node arrays on a
  ``CompiledSession``.  Batch fast paths (noop/identity/sleep and data
  drops) stamp whole waves vectorized; real Python apps are stamped
  individually around the registry call, so speculation and retries show
  their true durations.
* :class:`MetricsRegistry` — process-local counters/gauges/fixed-bucket
  histograms (no external deps), wired into ``execute_frontier`` (waves,
  frontier sizes, dispatch batches), ``EngineManager`` (admission,
  queue depth, session-latency histogram, template cache traffic) and
  the resilience runner (retries, speculative wins, recoveries).
* :func:`export_chrome_trace` — Perfetto / chrome://tracing JSON: one
  track per cluster node, one slice per drop (or one aggregated slice
  per wave-batch above ``batch_threshold``), plus a pipeline-span track
  (translate/map/deploy/execute).  A 100k-drop session opens directly in
  ``ui.perfetto.dev``.

Overhead is gated: ``bench_execute.py --telemetry`` measures instrumented
vs clean drops/s and ``scripts/check_bench.py`` enforces the committed
``telemetry_overhead_pct`` ceiling (see ``docs/observability.md``).
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span",
    "TelemetryConfig", "Timeline", "export_chrome_trace",
    "FRONTIER_BUCKETS", "LATENCY_BUCKETS_S",
]

# default fixed bucket grids (upper bounds; one overflow slot is appended)
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
FRONTIER_BUCKETS = (1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6, 1e7)


@dataclass(frozen=True)
class TelemetryConfig:
    """What the engine records.  Everything defaults off (or free):
    a default-constructed config must leave the hot path untouched —
    ``tests/test_telemetry.py`` asserts no session arrays are allocated.

    * ``timeline`` — allocate + stamp the per-drop :class:`Timeline`
      arrays (4 × num_drops extra memory, a few array writes per wave);
    * ``metrics`` — create/attach a :class:`MetricsRegistry` and update
      it at wave/session granularity;
    * ``spans`` — record translate/map/deploy/execute :class:`Span`\\ s
      on the ``Pipeline`` (a handful of appends per run, kept on);
    * ``trace_batch_threshold`` — per-(node, wave) drop count above
      which :func:`export_chrome_trace` emits one aggregated slice
      instead of per-drop slices.
    """

    timeline: bool = False
    metrics: bool = False
    spans: bool = True
    trace_batch_threshold: int = 64


@dataclass
class Span:
    """One named pipeline stage interval (monotonic seconds)."""

    name: str
    t_start: float
    t_end: float

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


# ---------------------------------------------------------------------------
# Per-drop timelines
# ---------------------------------------------------------------------------


class Timeline:
    """Parallel per-drop timing arrays over one ``CompiledSession``.

    * ``t_start`` / ``t_end`` — float64 ``time.monotonic()`` stamps
      (NaN until the drop reaches a terminal state);
    * ``wave`` — int32 scheduler wave index (-1 = never stamped);
    * ``node`` — int32 id of the node that *executed* the drop — the
      placement node except for speculative straggler duplicates, where
      the winning node is recorded.

    Stamping is two-speed.  ``stamp_batch`` — the call the vectorized
    fast paths make once per wave batch — only appends ``(ids, t0, t1,
    wave)`` to a pending list: O(1) per *batch*, so the execute hot
    path pays a dozen list appends per million drops instead of
    million-element scatters (the scatters also trash the LLC mid-run,
    which taxes the scheduler's own ``ufunc.at`` passes — measured,
    that pushed instrumented overhead past 10%; deferral holds it near
    zero, gated by ``telemetry_overhead_pct`` in the bench).  The
    scatters replay once, lazily, on first array access via the
    ``t_start``/``t_end``/``wave`` properties.  Callers hand over the
    ``ids`` array (always a fresh fancy-index subset in the scheduler)
    and must not mutate it afterwards.

    The arrays themselves allocate *lazily*, at the first scalar stamp
    or read — not when telemetry is enabled.  Filling ~24 bytes/drop of
    fresh pages right before execute wipes the LLC that holds the warm
    template CSR arrays, which measured ~4% on the 1M execute wall all
    by itself; a purely fast-path run now allocates nothing until
    someone actually reads the timeline.

    ``node`` is pre-filled with the placement at allocation — the batch
    fast paths always execute on the placement node, so only scalar
    stamps ever rewrite an entry (speculative winner on a different
    node).  ``stamp`` — used by ``_run_python`` / the resilience runner
    around the actual app call — writes through immediately: real apps
    are micro-seconds-plus each, and their true per-drop timings must
    not be clobbered by a later batch replay.  Scalar and batch stamps
    always target distinct indices (one writer per drop), so replay
    order does not matter; batch stamps come from the single scheduler
    thread, and only the allocation itself is locked (scalar stamps
    race in from pool workers).
    """

    __slots__ = ("pgt", "_t_start", "_t_end", "_wave", "_node", "epoch",
                 "max_wave", "_pending", "_alloc_lock", "chunks")

    def __init__(self, session: Any) -> None:
        self.pgt = session.pgt
        self._t_start: Optional[np.ndarray] = None
        self._t_end: Optional[np.ndarray] = None
        self._wave: Optional[np.ndarray] = None
        self._node: Optional[np.ndarray] = None
        self.epoch = time.monotonic()     # export timebase reference
        self.max_wave = -1                # resume continues from here
        self._pending: List[tuple] = []   # deferred batch stamps
        self._alloc_lock = threading.Lock()
        # streaming chunk spans: (consumer idx, seq, t0, t1) per chunk
        # processed by the compiled lane.  A plain list — chunks are
        # application-granular, and appends under the GIL are atomic
        # enough for the multi-threaded consumer lanes.
        self.chunks: List[tuple] = []

    def _ensure(self) -> None:
        """Allocate the stamp arrays on first use.  Double-checked on
        ``_wave``, which is published last — an unlocked reader that
        sees it non-None sees fully initialized arrays (GIL-ordered)."""
        if self._wave is None:
            with self._alloc_lock:
                if self._wave is None:
                    n = self.pgt.num_drops
                    self._t_start = np.full(n, np.nan, dtype=np.float64)
                    self._t_end = np.full(n, np.nan, dtype=np.float64)
                    self._node = self.pgt.node_ids.astype(np.int32,
                                                          copy=True)
                    self._wave = np.full(n, -1, dtype=np.int32)

    @property
    def t_start(self) -> np.ndarray:
        self._replay()
        return self._t_start

    @property
    def t_end(self) -> np.ndarray:
        self._replay()
        return self._t_end

    @property
    def wave(self) -> np.ndarray:
        self._replay()
        return self._wave

    @property
    def node(self) -> np.ndarray:
        self._ensure()
        return self._node

    def _replay(self) -> None:
        """Materialize deferred batch stamps into the arrays (three 1-D
        scalar-broadcast scatters per batch — NumPy's fastest scatter
        path; a 2-D ``(n, 2)`` row scatter or a structured-dtype
        scatter both measure 3-5x slower)."""
        self._ensure()
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        for ids, t0, t1, wave in pending:
            self._t_start[ids] = t0
            self._t_end[ids] = t1
            self._wave[ids] = wave

    def stamp_batch(self, ids: np.ndarray, t0: float, t1: float,
                    wave: int) -> None:
        """Deferred stamp for one wave's fast-path batch (O(1); the
        caller must not mutate ``ids`` afterwards)."""
        self._pending.append((ids, t0, t1, wave))
        if wave > self.max_wave:
            self.max_wave = wave

    def stamp(self, i: int, t0: float, t1: float, wave: int,
              node: Optional[int] = None) -> None:
        """Immediate scalar stamp for one registry-app execution."""
        self._ensure()
        self._t_start[i] = t0
        self._t_end[i] = t1
        self._wave[i] = wave
        if node is not None:
            self._node[i] = node
        if wave > self.max_wave:
            self.max_wave = wave

    def stamp_chunk(self, i: int, seq: int, t0: float, t1: float) -> None:
        """Record one processed stream chunk (consumer ``i``, chunk
        ``seq``).  Called from lane consumer threads."""
        self.chunks.append((int(i), int(seq), t0, t1))

    def chunk_spans(self) -> np.ndarray:
        """Chunk spans as a float64 array of rows (idx, seq, t0, t1) —
        what the streaming bench computes overlap fractions from."""
        if not self.chunks:
            return np.empty((0, 4), dtype=np.float64)
        return np.asarray(self.chunks, dtype=np.float64)

    def stamped(self) -> np.ndarray:
        """Ids of drops that have been stamped (wave >= 0)."""
        return np.flatnonzero(self.wave >= 0)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter.  ``inc`` takes one uncontended lock — callers
    sit at wave/session granularity, never per-drop."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Union[int, float]:
        return self._value


class Gauge:
    """Instantaneous value (queue depth, open sessions)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram: ``uppers[i]`` is the inclusive upper
    bound of bucket ``i``; one extra overflow slot catches the rest.
    Counts live in one int64 array — ``observe_many`` bins a whole
    value array with ``searchsorted`` + ``bincount``."""

    __slots__ = ("name", "uppers", "counts", "count", "sum", "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = LATENCY_BUCKETS_S) -> None:
        self.name = name
        self.uppers = np.asarray(sorted(buckets), dtype=np.float64)
        if self.uppers.size == 0:
            raise ValueError("histogram needs at least one bucket")
        self.counts = np.zeros(self.uppers.size + 1, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = int(np.searchsorted(self.uppers, value, side="left"))
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += value

    def observe_many(self, values: np.ndarray) -> None:
        values = np.asarray(values, dtype=np.float64)
        idx = np.searchsorted(self.uppers, values, side="left")
        binned = np.bincount(idx, minlength=self.counts.size)
        with self._lock:
            self.counts += binned
            self.count += int(values.size)
            self.sum += float(values.sum())

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket holding the ``q``-quantile
        observation (conservative — bucket resolution)."""
        with self._lock:
            total = self.count
            if total == 0:
                return 0.0
            target = q * total
            cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if i >= self.uppers.size:
            return float("inf")
        return float(self.uppers[i])

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "buckets": [float(u) for u in self.uppers],
                "counts": [int(c) for c in self.counts],
                "count": int(self.count),
                "sum": float(self.sum),
            }


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Creation takes the registry lock once; afterwards callers hold the
    metric object and update it directly (each metric has its own tiny
    lock), so N concurrent manager sessions never serialize on the
    registry itself.  ``snapshot()`` returns plain JSON-serialisable
    Python values — what ``launch/serve.py --stats-json`` dumps.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, *args)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS_S
                  ) -> Histogram:
        return self._get(name, Histogram, buckets)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            metrics = list(self._metrics.values())
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for m in metrics:
            if isinstance(m, Counter):
                v = m.value
                out["counters"][m.name] = \
                    int(v) if isinstance(v, (int, np.integer)) else float(v)
            elif isinstance(m, Gauge):
                out["gauges"][m.name] = float(m.value)
            elif isinstance(m, Histogram):
                out["histograms"][m.name] = m.snapshot()
        return out


# ---------------------------------------------------------------------------
# Perfetto / chrome-tracing export
# ---------------------------------------------------------------------------

_TID_PIPELINE = 1      # span track
_TID_NODE0 = 2         # node tracks start here (tid = node_id + 2)


def export_chrome_trace(session: Any, path: Union[str, Path], *,
                        spans: Optional[Sequence[Span]] = None,
                        batch_threshold: int = 64) -> Dict[str, int]:
    """Write one session's timeline as chrome-tracing JSON for Perfetto.

    Track layout: one process per session, one thread track per cluster
    node (thread 1 is the pipeline-span track).  Per-(node, wave) drop
    groups with at most ``batch_threshold`` members get one "X" slice
    per drop (named by uid); larger groups collapse into a single
    aggregated wave slice spanning min ``t_start`` .. max ``t_end`` with
    the drop count in ``args`` — a 100k-drop wave is one slice, not
    100k.  Returns a summary dict (event/slice/track counts).
    """
    tl: Optional[Timeline] = getattr(session, "timeline", None)
    if tl is None:
        raise ValueError(
            "session has no timeline — run it with "
            "TelemetryConfig(timeline=True)")
    pgt = tl.pgt
    ids = tl.stamped()
    events: List[Dict[str, Any]] = []
    pid = 1
    events.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                   "args": {"name": f"session {session.session_id}"}})
    events.append({"ph": "M", "pid": pid, "tid": _TID_PIPELINE,
                   "name": "thread_name", "args": {"name": "pipeline"}})
    tracks = 1
    for nid, node_name in enumerate(pgt.node_names):
        events.append({"ph": "M", "pid": pid, "tid": _TID_NODE0 + nid,
                       "name": "thread_name", "args": {"name": node_name}})
        tracks += 1
    unplaced_tid = _TID_NODE0 + len(pgt.node_names)

    # common timebase: earliest stamp across drops, chunks and spans
    chunk_rows = tl.chunk_spans()
    bases = []
    if ids.size:
        bases.append(float(np.nanmin(tl.t_start[ids])))
    if chunk_rows.shape[0]:
        bases.append(float(chunk_rows[:, 2].min()))
    for sp in spans or ():
        bases.append(sp.t_start)
    t_base = min(bases) if bases else tl.epoch

    def us(t: float) -> float:
        return round((t - t_base) * 1e6, 3)

    slices = 0
    for sp in spans or ():
        events.append({
            "ph": "X", "pid": pid, "tid": _TID_PIPELINE, "name": sp.name,
            "ts": us(sp.t_start),
            "dur": max(round(sp.duration * 1e6, 3), 0.01)})
        slices += 1

    if ids.size:
        waves = tl.wave[ids]
        nodes = tl.node[ids]
        # pack (node, wave) -> group key; node -1 maps to the last track
        nkey = np.where(nodes >= 0, nodes,
                        len(pgt.node_names)).astype(np.int64)
        key = nkey * (int(waves.max()) + 1) + waves
        order = np.argsort(key, kind="stable")
        bounds = np.flatnonzero(np.diff(key[order])) + 1
        used_unplaced = False
        for grp in np.split(order, bounds):
            g = ids[grp]
            nid = int(nodes[grp[0]])
            wave = int(waves[grp[0]])
            tid = _TID_NODE0 + nid if nid >= 0 else unplaced_tid
            used_unplaced |= nid < 0
            if g.size > batch_threshold:
                t0 = float(np.nanmin(tl.t_start[g]))
                t1 = float(np.nanmax(tl.t_end[g]))
                events.append({
                    "ph": "X", "pid": pid, "tid": tid,
                    "name": f"wave {wave} [{g.size} drops]",
                    "ts": us(t0),
                    "dur": max(round((t1 - t0) * 1e6, 3), 0.01),
                    "args": {"wave": wave, "drops": int(g.size)}})
                slices += 1
            else:
                state = session.drop_state
                from .session import _ST_NAMES
                for i in g.tolist():
                    events.append({
                        "ph": "X", "pid": pid, "tid": tid,
                        "name": pgt.uid_of(i),
                        "ts": us(float(tl.t_start[i])),
                        "dur": max(round(
                            (float(tl.t_end[i])
                             - float(tl.t_start[i])) * 1e6, 3), 0.01),
                        "args": {"wave": wave,
                                 "state": _ST_NAMES[state[i]]}})
                    slices += 1
        if used_unplaced:
            events.append({"ph": "M", "pid": pid, "tid": unplaced_tid,
                           "name": "thread_name",
                           "args": {"name": "unplaced"}})
            tracks += 1

    # streaming chunk spans: one slice per processed chunk on the
    # consumer's node track — this is where producer/consumer overlap
    # becomes visible (chunk slices sitting under a producer's slice)
    node_ids = pgt.node_ids
    for idx, seq, t0, t1 in tl.chunks:
        nid = int(node_ids[idx])
        tid = _TID_NODE0 + nid if nid >= 0 else unplaced_tid
        events.append({
            "ph": "X", "pid": pid, "tid": tid,
            "name": f"{pgt.uid_of(int(idx))} · chunk {int(seq)}",
            "ts": us(float(t0)),
            "dur": max(round((float(t1) - float(t0)) * 1e6, 3), 0.01),
            "args": {"chunk": int(seq)}})
        slices += 1

    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
    return {"events": len(events), "slices": slices, "tracks": tracks,
            "drops_stamped": int(ids.size)}
