"""Process-backed node execution: spawn workers + a shared-memory payload plane.

DALiuGE's node/island Drop Managers are real OS processes; apps on different
nodes never share a GIL and a crashed app takes down only its own manager.
This module gives the compiled engine the same shape behind the existing
``node_executors()`` contract:

- :class:`ProcExecutor` — one spawn-based worker process per node, driven by a
  duplex-pipe mailbox.  The scheduler ships *work orders* (drop indices plus
  pre-resolved input references), never graph objects, and the worker replies
  with per-drop status, staged output writes, and monotonic timing stamps.
- :class:`PayloadPlane` — a per-island registry of
  ``multiprocessing.shared_memory`` segments.  Array payloads (``numpy``
  buffers over a size threshold) cross the process boundary as ``(segment,
  dtype, shape)`` descriptors and are mapped zero-copy on both sides; pickle
  is reserved for opaque (non-buffer) values and island-boundary edges, whose
  descriptor cache never spans planes.
- :class:`WorkerLost` — raised when a worker dies (SIGKILL, hard crash, wedged
  past its grace).  Callers treat it exactly like a scripted node failure:
  ``execute_resilient`` fails the node and recovers via the lineage machinery.

Workers are crash-isolated but *not* respawned: a lost worker is a lost node,
and recovery migrates its drops to surviving nodes — the same permanent-death
model the thread-backed recovery tier simulates.

Resource-tracker note (Python <= 3.12): ``SharedMemory`` registers every
segment it creates *or attaches* with the resource tracker.  Spawn workers
inherit the parent's tracker process, whose cache is a per-name set, so the
create/attach registrations collapse to one entry and the plane's single
``unlink()`` at close (which unregisters internally) balances it — no manual
``resource_tracker.unregister`` calls, which would leave the later unlink
unmatched and error the tracker.  Segments belonging to a worker killed
mid-batch stay registered until the plane unlinks them; any the plane never
saw are reaped by the tracker at interpreter exit instead of leaking into
``/dev/shm``.
"""
from __future__ import annotations

import multiprocessing
import os
import pickle
import threading
import time
import traceback
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from multiprocessing.shared_memory import SharedMemory
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .drop import PayloadError

__all__ = [
    "DEFAULT_SHM_MIN_BYTES",
    "PayloadPlane",
    "ProcExecutor",
    "TrackingThreadPool",
    "WorkerLost",
    "WorkerTimeout",
]

#: Arrays below this many bytes ship inline (pickled into the mailbox blob);
#: at or above it they ride the shared-memory plane.  Small arrays are cheaper
#: to copy than to segment (one shm segment costs a file descriptor + mmap).
DEFAULT_SHM_MIN_BYTES = 64 * 1024

_PROTO = pickle.HIGHEST_PROTOCOL
_mp = multiprocessing.get_context("spawn")


class WorkerLost(RuntimeError):
    """A node's worker process died (or wedged past grace) mid-execution.

    Carries the node names whose workers are gone; the resilience loop treats
    them exactly like scripted node failures and recovers via lineage.
    """

    def __init__(self, nodes: Sequence[str]):
        self.nodes: List[str] = list(nodes)
        super().__init__(f"worker process lost for node(s): {', '.join(self.nodes)}")


class WorkerTimeout(RuntimeError):
    """A mailbox round trip exceeded its budget but the worker is still alive."""


def _create_segment(nbytes: int) -> SharedMemory:
    return SharedMemory(create=True, size=max(1, int(nbytes)))


def _is_plane_array(v: Any, min_bytes: int) -> bool:
    return (
        isinstance(v, np.ndarray)
        and not v.dtype.hasobject
        and v.nbytes >= min_bytes
    )


class TrackingThreadPool(ThreadPoolExecutor):
    """ThreadPoolExecutor that remembers outstanding futures so shutdown can
    drain in-flight work with a bounded grace instead of abandoning it."""

    def __init__(self, *args: Any, **kwargs: Any) -> None:
        super().__init__(*args, **kwargs)
        self._tracked: set = set()
        self._track_lock = threading.Lock()

    def submit(self, fn: Callable[..., Any], /, *args: Any, **kwargs: Any) -> Future:
        fut = super().submit(fn, *args, **kwargs)
        with self._track_lock:
            self._tracked.add(fut)
        fut.add_done_callback(self._discard)
        return fut

    def _discard(self, fut: Future) -> None:
        with self._track_lock:
            self._tracked.discard(fut)

    def drain(self, grace: float) -> List[Future]:
        """Wait up to *grace* seconds for queued + running work; return the
        futures still unfinished (work that would be abandoned)."""
        with self._track_lock:
            futs = list(self._tracked)
        deadline = time.monotonic() + max(0.0, grace)
        leftover: List[Future] = []
        for fut in futs:
            try:
                fut.result(timeout=max(0.0, deadline - time.monotonic()))
            except _FutureTimeout:
                leftover.append(fut)
            except (CancelledError, Exception):
                # work-level failures are the session's problem, not drain's
                pass
        return leftover


class PayloadPlane:
    """Parent-side registry of shared-memory payload segments for one island.

    Array values cross process boundaries as ``("shm", (name, dtype, shape))``
    descriptors.  The plane caches ``id(array) -> descriptor`` (pinning the
    array so ids stay valid), so an array produced by one worker and consumed
    by another on the same island ships as a descriptor only — zero copies,
    zero pickling.  A cross-island edge consults a *different* plane, misses
    the cache, and falls back to an export copy (or pickle below threshold):
    exactly the "pickle only for non-buffer objects and island-boundary
    edges" contract.

    Reference-counted by the node managers that share it; the last release
    unlinks every segment.
    """

    def __init__(self, shm_min_bytes: int = DEFAULT_SHM_MIN_BYTES):
        self.shm_min_bytes = int(shm_min_bytes)
        self._lock = threading.Lock()
        self._segments: Dict[str, SharedMemory] = {}
        self._by_id: Dict[int, Tuple[np.ndarray, tuple]] = {}
        self._refs = 0
        self._closed = False
        self.stats: Dict[str, int] = {
            "shm_exports": 0,      # parent heap array copied into a fresh segment
            "shm_passthrough": 0,  # descriptor cache hit: shipped with no copy
            "shm_results": 0,      # worker-produced segment mapped zero-copy
            "raw_values": 0,       # non-array / sub-threshold value pickled inline
        }

    # -- lifecycle ---------------------------------------------------------
    def retain(self) -> None:
        with self._lock:
            self._refs += 1

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            last = self._refs <= 0
        if last:
            self.close()

    def close(self) -> None:
        with self._lock:
            segs = list(self._segments.values())
            self._segments.clear()
            self._by_id.clear()
            self._closed = True
        for seg in segs:
            try:
                seg.close()
            except Exception:
                pass
            try:
                seg.unlink()
            except Exception:
                pass

    @property
    def closed(self) -> bool:
        return self._closed

    # -- wire encoding -----------------------------------------------------
    def encode(self, value: Any) -> Tuple[str, Any]:
        """Encode one input value for the mailbox: a shm descriptor for plane
        arrays (cache hit = no copy at all), the raw value otherwise."""
        if not _is_plane_array(value, self.shm_min_bytes):
            with self._lock:
                self.stats["raw_values"] += 1
            return ("raw", value)
        with self._lock:
            hit = self._by_id.get(id(value))
            if hit is not None and hit[0] is value:
                self.stats["shm_passthrough"] += 1
                return ("shm", hit[1])
        contig = np.ascontiguousarray(value)
        seg = _create_segment(contig.nbytes)
        np.ndarray(contig.shape, dtype=contig.dtype, buffer=seg.buf)[...] = contig
        desc = (seg.name, contig.dtype.str, contig.shape)
        with self._lock:
            self._segments[seg.name] = seg
            self._by_id[id(value)] = (value, desc)
            self.stats["shm_exports"] += 1
        return ("shm", desc)

    def attach(self, desc: tuple) -> np.ndarray:
        """Map a worker-exported segment zero-copy and pin it in the cache so
        forwarding it to another worker ships the descriptor only."""
        name, dtype, shape = desc
        with self._lock:
            seg = self._segments.get(name)
            if seg is None:
                seg = SharedMemory(name=name)
                self._segments[name] = seg
            arr = np.ndarray(tuple(shape), dtype=np.dtype(dtype), buffer=seg.buf)
            self._by_id[id(arr)] = (arr, desc)
            self.stats["shm_results"] += 1
        return arr

    def decode(self, wire: Tuple[str, Any]) -> Any:
        tag, payload = wire
        if tag == "shm":
            return self.attach(payload)
        if tag == "rawb":
            return pickle.loads(payload)
        return payload

    def discard_segment(self, name: str) -> None:
        """Unlink an orphaned worker-side segment (errored drop's partial writes)."""
        with self._lock:
            seg = self._segments.pop(name, None)
        try:
            if seg is None:
                seg = SharedMemory(name=name)
            seg.close()
            seg.unlink()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# Worker side.  Everything below the fold runs in the spawned child process;
# it imports only this module (plus numpy / drop), never the scheduler.
# ---------------------------------------------------------------------------
class _WorkerInRef:
    """Input reference handed to the app inside the worker.  Values were
    resolved parent-side; a parent read failure re-raises as PayloadError at
    ``read()`` time, matching in-process lazy-read semantics."""

    __slots__ = ("uid", "meta", "_value", "_error")

    def __init__(self, uid: str, meta: Dict[str, Any], value: Any, error: Optional[str]):
        self.uid = uid
        self.meta = meta
        self._value = value
        self._error = error

    def read(self) -> Any:
        if self._error is not None:
            raise PayloadError(self._error)
        return self._value


class _WorkerOutRef:
    """Output reference: writes are staged locally and shipped back in the
    reply; the parent replays them into the session payload table."""

    __slots__ = ("idx", "uid", "meta", "_writes")

    def __init__(self, idx: int, uid: str, meta: Dict[str, Any], writes: List[Tuple[int, Any]]):
        self.idx = idx
        self.uid = uid
        self.meta = meta
        self._writes = writes

    def write(self, value: Any) -> None:
        self._writes.append((self.idx, value))


class _WorkerAppRef:
    __slots__ = ("uid", "meta", "node", "scratch")

    def __init__(self, uid: str, meta: Dict[str, Any], node: Optional[str]):
        self.uid = uid
        self.meta = meta
        self.node = node
        self.scratch: Dict[str, Any] = {}


def _decode_input(wire: Tuple[str, Any], segments: Dict[str, SharedMemory]) -> Any:
    tag, payload = wire
    if tag != "shm":
        return payload
    name, dtype, shape = payload
    seg = segments.get(name)
    if seg is None:
        seg = SharedMemory(name=name)
        segments[name] = seg
    return np.ndarray(tuple(shape), dtype=np.dtype(dtype), buffer=seg.buf)


def _encode_output(value: Any, min_bytes: int) -> Tuple[str, Any]:
    if _is_plane_array(value, min_bytes):
        contig = np.ascontiguousarray(value)
        seg = _create_segment(contig.nbytes)
        np.ndarray(contig.shape, dtype=contig.dtype, buffer=seg.buf)[...] = contig
        desc = (seg.name, contig.dtype.str, contig.shape)
        seg.close()  # close the mapping; the segment itself lives until unlink
        return ("shm", desc)
    return ("rawb", pickle.dumps(value, protocol=_PROTO))


def _run_spec(
    idx: int,
    blob: bytes,
    node: str,
    deadline: float,
    segments: Dict[str, SharedMemory],
    min_bytes: int,
) -> Dict[str, Any]:
    t0 = time.monotonic()
    if t0 >= deadline:
        return {"idx": idx, "status": "timeout"}
    encoded: List[Tuple[int, Tuple[str, Any]]] = []
    try:
        spec = pickle.loads(blob)
        func = spec.get("func")
        ins = [
            _WorkerInRef(uid, meta, _decode_input(wire, segments), err)
            for uid, meta, wire, err in spec.get("inputs", ())
        ]
        writes: List[Tuple[int, Any]] = []
        outs = [
            _WorkerOutRef(j, uid, meta, writes)
            for j, uid, meta in spec.get("outputs", ())
        ]
        app = _WorkerAppRef(spec.get("uid", ""), spec.get("meta", {}), node)
        if func is not None:
            if getattr(func, "streaming", False):
                fin = getattr(func, "finish", None)
                if fin is not None:
                    fin(ins, outs, app)
            else:
                func(ins, outs, app)
        for j, v in writes:
            encoded.append((j, _encode_output(v, min_bytes)))
        return {
            "idx": idx,
            "status": "ok",
            "writes": encoded,
            "t0": t0,
            "t1": time.monotonic(),
        }
    except Exception:
        return {
            "idx": idx,
            "status": "err",
            "tb": traceback.format_exc(limit=8),
            # partial shm exports from staged writes would otherwise leak
            "orphans": [d[0] for _, (tag, d) in encoded if tag == "shm"],
            "t0": t0,
            "t1": time.monotonic(),
        }


def _worker_main(conn: Any, node: str, min_bytes: int) -> None:
    """Mailbox loop of one node worker.  Requests: ("run", bid, items,
    budget) / ("ping",) / ("stop",).  Replies: ("done", bid, results) /
    ("pong", pid)."""
    segments: Dict[str, SharedMemory] = {}
    try:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                break
            tag = msg[0]
            if tag == "stop":
                break
            if tag == "ping":
                conn.send(("pong", os.getpid()))
                continue
            _, bid, items, budget = msg
            deadline = time.monotonic() + float(budget)
            results = [
                _run_spec(idx, blob, node, deadline, segments, min_bytes)
                for idx, blob in items
            ]
            conn.send(("done", bid, results))
    finally:
        for seg in segments.values():
            try:
                seg.close()
            except Exception:
                pass
        try:
            conn.close()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# Parent side.
# ---------------------------------------------------------------------------
class ProcExecutor:
    """One crash-isolated spawn worker for one node, plus a small thread pool
    so existing ``executor.submit(...)`` call sites keep working.

    ``run_batch`` is the process path: it wire-encodes specs (per-spec pickle,
    so one unpicklable app poisons only its own drop), ships them through the
    mailbox, and decodes the reply.  Worker death — pipe EOF, ``is_alive()``
    false, or a wedge past ``budget + grace`` (the worker is then SIGKILLed) —
    raises :class:`WorkerLost`; the worker is never respawned.
    """

    #: extra seconds past the batch budget before a silent worker is declared
    #: wedged and killed.  Generous: a busy loop just under budget plus reply
    #: serialisation must fit.
    grace = 10.0

    def __init__(
        self,
        node: str,
        plane: PayloadPlane,
        submit_workers: int = 4,
        shm_min_bytes: Optional[int] = None,
    ):
        self.node = node
        self.plane = plane
        self.shm_min_bytes = int(
            plane.shm_min_bytes if shm_min_bytes is None else shm_min_bytes
        )
        self.on_lost: Optional[Callable[[], None]] = None
        self._threads = TrackingThreadPool(
            max_workers=submit_workers, thread_name_prefix=f"procex-{node}"
        )
        self._lock = threading.Lock()  # serialises mailbox round trips
        self._proc: Optional[Any] = None
        self._conn: Optional[Any] = None
        self._dead = False
        self._batch_seq = 0

    # -- thread-pool facade (ResilientRunner, AppDrop call sites) ----------
    def submit(self, fn: Callable[..., Any], /, *args: Any, **kwargs: Any) -> Future:
        return self._threads.submit(fn, *args, **kwargs)

    def drain(self, grace: float) -> List[Future]:
        return self._threads.drain(grace)

    # -- worker lifecycle --------------------------------------------------
    @property
    def pid(self) -> Optional[int]:
        proc = self._proc
        return proc.pid if proc is not None else None

    @property
    def dead(self) -> bool:
        return self._dead

    def _ensure_worker(self) -> None:
        if self._proc is not None:
            return
        parent_conn, child_conn = _mp.Pipe(duplex=True)
        proc = _mp.Process(
            target=_worker_main,
            args=(child_conn, self.node, self.shm_min_bytes),
            name=f"procpool-{self.node}",
            daemon=True,
        )
        proc.start()
        child_conn.close()
        self._proc = proc
        self._conn = parent_conn

    def _mark_lost(self) -> None:
        self._dead = True
        cb = self.on_lost
        if cb is not None:
            try:
                cb()
            except Exception:
                pass

    def kill(self) -> None:
        """SIGKILL the worker (recovery drills / wedge escalation)."""
        proc = self._proc
        if proc is not None and proc.is_alive():
            proc.kill()

    def shutdown(self, wait: bool = False, cancel_futures: bool = True) -> None:
        self._threads.shutdown(wait=wait, cancel_futures=cancel_futures)
        self._stop_worker()

    def _stop_worker(self) -> None:
        proc, conn = self._proc, self._conn
        self._proc, self._conn = None, None
        if conn is not None:
            try:
                conn.send(("stop",))
            except Exception:
                pass
        if proc is not None:
            proc.join(timeout=2.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=2.0)
        if conn is not None:
            try:
                conn.close()
            except Exception:
                pass

    # -- the mailbox -------------------------------------------------------
    def run_batch(
        self, specs: Sequence[Dict[str, Any]], budget: float
    ) -> List[Dict[str, Any]]:
        """Execute *specs* in the worker; returns one result dict per spec:
        ``{"idx", "status": "ok"|"err"|"timeout", "writes": [(out_idx, value)],
        "tb", "t0", "t1"}`` with writes already plane-decoded.  Raises
        :class:`WorkerLost` if the worker dies or wedges past grace."""
        if not specs:
            return []
        with self._lock:
            if self._dead:
                raise WorkerLost([self.node])
            self._ensure_worker()
            now = time.monotonic()
            parent_fail: List[Dict[str, Any]] = []
            items: List[Tuple[int, bytes]] = []
            for spec in specs:
                try:
                    items.append(
                        (int(spec["idx"]), pickle.dumps(self._encode_spec(spec), protocol=_PROTO))
                    )
                except Exception:
                    parent_fail.append(
                        {
                            "idx": int(spec["idx"]),
                            "status": "err",
                            "tb": (
                                "app or inputs not picklable for process dispatch "
                                f"(node {self.node}):\n" + traceback.format_exc(limit=8)
                            ),
                            "t0": now,
                            "t1": now,
                        }
                    )
            if not items:
                return parent_fail
            self._batch_seq += 1
            bid = self._batch_seq
            try:
                self._conn.send(("run", bid, items, float(budget)))
            except (BrokenPipeError, OSError):
                self._mark_lost()
                raise WorkerLost([self.node]) from None
            reply = self._recv(bid, float(budget))
            return parent_fail + [self._decode_result(r) for r in reply]

    def _recv(self, bid: int, budget: float) -> List[Dict[str, Any]]:
        hard = time.monotonic() + max(budget, 0.0) + self.grace
        conn, proc = self._conn, self._proc
        while True:
            if conn.poll(0.1):
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    self._mark_lost()
                    raise WorkerLost([self.node]) from None
                if msg[0] == "done" and msg[1] == bid:
                    return msg[2]
                continue  # stale reply from a batch we gave up on
            if not proc.is_alive():
                self._mark_lost()
                raise WorkerLost([self.node])
            if time.monotonic() >= hard:
                # wedged past grace: a hung worker is indistinguishable from a
                # dead one to the scheduler, so make it actually dead
                self.kill()
                self._mark_lost()
                raise WorkerLost([self.node])

    def _encode_spec(self, spec: Dict[str, Any]) -> Dict[str, Any]:
        wire = dict(spec)
        wire["inputs"] = [
            (uid, meta, self.plane.encode(value), err)
            for uid, meta, value, err in spec.get("inputs", ())
        ]
        return wire

    def _decode_result(self, r: Dict[str, Any]) -> Dict[str, Any]:
        if r.get("status") == "ok":
            r["writes"] = [(j, self.plane.decode(w)) for j, w in r.get("writes", ())]
        else:
            for name in r.pop("orphans", ()):
                self.plane.discard_segment(name)
        return r
