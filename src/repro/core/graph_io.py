"""Graph (de)serialisation (paper §3.7).

"We currently use JSON as the serialization format for the different graphs.
JSON-encoded graphs are compressed and uncompressed on-the-fly when
transmitted.  We parse the JSON content iteratively to keep memory low for
big graphs."

We mirror that: gzip-compressed JSON for LGTs and PGTs, with an incremental
(chunked) writer/reader for physical graphs so multi-million-drop graphs never
need a single monolithic in-memory string (the paper's ijson adaptation).
"""
from __future__ import annotations

import gzip
import json
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .logical import LogicalGraph, LogicalGraphTemplate
from .pgt import CompiledPGT, _uid_str
from .unroll import DropSpec, PhysicalGraphTemplate


# -- logical graphs -----------------------------------------------------------


def save_lgt(lgt: LogicalGraphTemplate, path: str) -> None:
    raw = json.dumps(lgt.to_json()).encode()
    with gzip.open(path, "wb") as fh:
        fh.write(raw)


def load_lgt(path: str) -> LogicalGraphTemplate:
    with gzip.open(path, "rb") as fh:
        return LogicalGraphTemplate.from_json(json.loads(fh.read()))


# -- physical graphs: incremental JSONL-in-gzip ---------------------------------


def _spec_to_json(s: DropSpec) -> Dict[str, Any]:
    return {
        "uid": s.uid, "kind": s.kind, "construct": s.construct,
        "oid": list(s.oid), "app": s.app, "payload_kind": s.payload_kind,
        "execution_time": s.execution_time, "data_volume": s.data_volume,
        "error_threshold": s.error_threshold, "params": s.params,
        "partition": s.partition, "node": s.node,
    }


def _spec_from_json(d: Dict[str, Any]) -> DropSpec:
    d = dict(d)
    d["oid"] = tuple(d["oid"])
    return DropSpec(**d)


def _iter_drop_records(pgt) -> Any:
    """Per-drop JSON dicts; CompiledPGTs in group-derived (array-native)
    mode are walked group by group straight off the arrays — no
    ``DropView`` attribute machinery, no per-drop group bisect — which is
    several times cheaper at million-drop scale."""
    if not (isinstance(pgt, CompiledPGT) and pgt._uids is None):
        for spec in pgt.drops.values():
            yield _spec_to_json(spec)
        return
    import itertools
    part = pgt.partition
    node_ids = pgt.node_ids
    names = pgt.node_names
    exec_arr, vol_arr = pgt.exec_arr, pgt.vol_arr
    err = pgt.err_arr
    overrides = pgt._params_override
    for g in pgt.groups:
        kind = "data" if g.kind == 1 else "app"
        ranges = [range(s) for s in g.sizes]
        for local, oid in enumerate(itertools.product(*ranges)):
            i = g.base + local
            uid = _uid_str(g.name, oid)
            nid = node_ids[i]
            yield {
                "uid": uid, "kind": kind, "construct": g.name,
                "oid": list(oid), "app": g.app,
                "payload_kind": g.payload_kind,
                "execution_time": float(exec_arr[i]),
                "data_volume": float(vol_arr[i]),
                "error_threshold": (float(err[i]) if err is not None
                                    else g.error_threshold),
                "params": overrides.get(i, g.params),
                "partition": int(part[i]),
                "node": None if nid < 0 else names[nid],
            }


def save_pgt(pgt: PhysicalGraphTemplate, path: str,
             chunk: int = 10000) -> None:
    """Stream the PGT out as gzip JSONL: header, then drops, then edges."""
    with gzip.open(path, "wt") as fh:
        fh.write(json.dumps({"type": "header", "name": pgt.name,
                             "num_drops": len(pgt.drops),
                             "num_edges": len(pgt.edges)}) + "\n")
        buf: List[Dict[str, Any]] = []
        for rec in _iter_drop_records(pgt):
            buf.append(rec)
            if len(buf) >= chunk:
                fh.write(json.dumps({"type": "drops", "items": buf}) + "\n")
                buf = []
        if buf:
            fh.write(json.dumps({"type": "drops", "items": buf}) + "\n")
        ebuf: List[List[Any]] = []
        for s, d, streaming in pgt.edges:
            ebuf.append([s, d, streaming])
            if len(ebuf) >= chunk:
                fh.write(json.dumps({"type": "edges", "items": ebuf}) + "\n")
                ebuf = []
        if ebuf:
            fh.write(json.dumps({"type": "edges", "items": ebuf}) + "\n")


def iter_pgt(path: str) -> Iterator[Tuple[str, Any]]:
    """Incremental PGT reader: yields ('header'|'drop'|'edge', payload)."""
    with gzip.open(path, "rt") as fh:
        for line in fh:
            rec = json.loads(line)
            if rec["type"] == "header":
                yield "header", rec
            elif rec["type"] == "drops":
                for item in rec["items"]:
                    yield "drop", _spec_from_json(item)
            elif rec["type"] == "edges":
                for item in rec["items"]:
                    yield "edge", tuple(item)


def load_pgt(path: str) -> CompiledPGT:
    """Incrementally load a PGT into the array-based representation."""
    name: Optional[str] = None
    specs: List[DropSpec] = []
    edges: List[Tuple[str, str, bool]] = []
    for kind, payload in iter_pgt(path):
        if kind == "header":
            name = payload["name"]
        elif kind == "drop":
            specs.append(payload)
        else:
            edges.append(payload)
    assert name is not None, f"no header found in {path}"
    return CompiledPGT.from_specs(name, specs, edges)
