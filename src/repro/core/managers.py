"""Hierarchical Drop Managers (paper §3.5, Fig. 6).

"A Node Drop Manager exists for each compute node ... ultimately responsible
for creating and deleting Drops.  Because compute nodes are grouped into Data
Islands, a Data Island Drop Manager exists at the Data Island level ...
Finally, in order to expose a single point of contact a Master Drop Manager
manages all Data Island Managers."

Deployment recursively traverses the hierarchy: the Master splits the PG by
island placement, each Island splits by node placement and records the edges
crossing node boundaries, communicating them to the relevant Node Managers
afterwards.

This container is one host, so "nodes" are thread pools; the structure,
splitting logic and bookkeeping are exactly the paper's, and node failure /
island accounting operate on these objects.
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .drop import AppDrop, DataDrop, Drop, DropState, make_payload
from .events import EventBus
from .mapping import NodeInfo
from .pgt import CompiledPGT
from .procpool import PayloadPlane, ProcExecutor, TrackingThreadPool
from .session import CompiledSession, Session
from .unroll import DropSpec, PhysicalGraphTemplate
from .util import safe_uid as _safe

# ---------------------------------------------------------------------------
# Application registry — pipeline components (paper §3.1)
# ---------------------------------------------------------------------------

AppFunc = Callable[[List[DataDrop], List[DataDrop], AppDrop], Any]

_APP_REGISTRY: Dict[str, AppFunc] = {}


def register_app(name: str, *, streaming: bool = False,
                 finish: Optional[AppFunc] = None
                 ) -> Callable[[AppFunc], AppFunc]:
    """Register a pipeline component (paper §3.1).

    ``streaming=True`` marks the function as a *chunk handler*: it is
    called as ``fn(value, app)`` once per chunk arriving on a streaming
    input (§4/Fig. 10), accumulating across chunks in ``app.scratch``.
    The optional ``finish(ok_inputs, outputs, app)`` runs at batch
    resolution (all inputs terminal) to emit final outputs; without it
    the drop completes without writing.  Both engines honour the marks —
    see ``docs/streaming.md``."""
    def deco(fn: AppFunc) -> AppFunc:
        if streaming:
            fn.streaming = True            # type: ignore[attr-defined]
        if finish is not None:
            fn.finish = finish             # type: ignore[attr-defined]
        _APP_REGISTRY[name] = fn
        return fn
    return deco


def get_app(name: str) -> AppFunc:
    if name not in _APP_REGISTRY:
        raise KeyError(f"app {name!r} not registered "
                       f"(known: {sorted(_APP_REGISTRY)})")
    return _APP_REGISTRY[name]


# -- built-in apps (paper §3.7: bash commands, python funcs, sockets...) ------


@register_app("noop")
def _noop(inputs: List[DataDrop], outputs: List[DataDrop],
          app: AppDrop) -> None:
    for o in outputs:
        o.write(None)


@register_app("identity")
def _identity(inputs: List[DataDrop], outputs: List[DataDrop],
              app: AppDrop) -> None:
    vals = [i.read() for i in inputs]
    v = vals[0] if len(vals) == 1 else vals
    for o in outputs:
        o.write(v)


@register_app("sleep")
def _sleep(inputs: List[DataDrop], outputs: List[DataDrop],
           app: AppDrop) -> None:
    time.sleep(float(app.meta.get("seconds", 0.001)))
    for o in outputs:
        o.write(None)


# the built-in implementations the compiled engine may replace with
# vectorised fast paths — if a user re-registers one of these names, the
# registry entry no longer ``is`` the builtin and the fast path must yield
BUILTIN_FAST_APPS: Dict[str, AppFunc] = {
    "noop": _noop, "identity": _identity, "sleep": _sleep}


@register_app("bash")
def _bash(inputs: List[DataDrop], outputs: List[DataDrop],
          app: AppDrop) -> None:
    import subprocess
    cmd = app.meta["command"]
    res = subprocess.run(cmd, shell=True, capture_output=True, text=True,
                         timeout=app.meta.get("timeout", 60))
    if res.returncode != 0:
        raise RuntimeError(f"bash app failed ({res.returncode}): "
                           f"{res.stderr[:500]}")
    for o in outputs:
        o.write(res.stdout)


# ---------------------------------------------------------------------------
# Node Drop Manager
# ---------------------------------------------------------------------------


class NodeDropManager:
    """Creates/deletes Drops for one compute node; bottom of the hierarchy."""

    #: seconds shutdown() waits for in-flight app calls before abandoning
    #: them and failing their sessions
    SHUTDOWN_GRACE_S = 5.0

    def __init__(self, info: NodeInfo, max_workers: int = 4) -> None:
        self.info = info
        self.executor = self._make_executor(max_workers)
        self.sessions: Dict[str, Dict[str, Drop]] = {}
        # compiled sessions: session id -> drop-id index slice on this node
        self.compiled_sessions: Dict[str, np.ndarray] = {}
        # sessions deployed here, weakly held so shutdown can fail the ones
        # it abandons work for without pinning closed sessions in memory
        self._session_refs: "weakref.WeakValueDictionary[str, Any]" = \
            weakref.WeakValueDictionary()
        self._lock = threading.Lock()

    def _make_executor(self, max_workers: int) -> TrackingThreadPool:
        return TrackingThreadPool(
            max_workers=max_workers,
            thread_name_prefix=f"ndm-{self.info.name}")

    @property
    def name(self) -> str:
        return self.info.name

    # -- deployment ------------------------------------------------------------
    def create_drops(self, session: Session,
                     specs: Sequence[DropSpec]) -> Dict[str, Drop]:
        """Instantiate the Drops placed on this node (paper: NM deployment =
        'checking the validity of the PG and the creation of the Session and
        all its Drops')."""
        created: Dict[str, Drop] = {}
        for spec in specs:
            if spec.node != self.name:
                raise ValueError(
                    f"drop {spec.uid} placed on {spec.node}, "
                    f"not this node {self.name}")
            drop = self._instantiate(spec, session.bus)
            created[spec.uid] = drop
            session.add_drop(drop)
        with self._lock:
            self.sessions.setdefault(session.session_id, {}).update(created)
        self._session_refs[session.session_id] = session
        return created

    def _instantiate(self, spec: DropSpec, bus: EventBus) -> Drop:
        meta = {"oid": spec.oid, "construct": spec.construct, **spec.params}
        if spec.kind == "data":
            path = None
            if spec.payload_kind == "file":
                path = spec.params.get(
                    "path", f"/tmp/repro_drops/{_safe(spec.uid)}.pkl")
            payload = make_payload(spec.payload_kind, path=path)
            d: Drop = DataDrop(spec.uid, payload=payload, bus=bus,
                               node=self.name, meta=meta,
                               lifetime=spec.params.get("lifetime"))
            d.meta["data_volume"] = spec.data_volume
        else:
            func = get_app(spec.app) if spec.app else None
            d = AppDrop(spec.uid, func,
                        error_threshold=spec.error_threshold,
                        executor=self.executor, bus=bus, node=self.name,
                        meta=meta)
            d.meta["execution_time"] = spec.execution_time
        return d

    def register_compiled(self, session: CompiledSession,
                          indices: np.ndarray) -> None:
        """Batched deploy: record the drop-id slice placed on this node.

        The array path's replacement for ``create_drops`` — no per-drop
        instantiation; the drops *are* the rows of the session's state
        arrays, and this node owns the ``indices`` view of them.
        """
        with self._lock:
            self.compiled_sessions[session.session_id] = indices
        self._session_refs[session.session_id] = session
        session.node_slices[self.name] = indices

    # -- failure simulation -----------------------------------------------------
    def fail(self) -> None:
        """Simulate node death: everything non-terminal on it is lost
        (plus volatile COMPLETED memory payloads — memory dies with the
        node).  Object sessions recover via ``fault.FaultManager``;
        compiled sessions via ``resilience.CompiledFaultManager``."""
        self.info.alive = False

    def shutdown(self) -> None:
        """Drain in-flight app calls with a bounded grace, then stop the pool.

        ``executor.shutdown(wait=False, cancel_futures=True)`` alone abandons
        calls mid-write: a session shut down during dispatch was left
        non-terminal with half-written payloads.  Now running + queued work
        gets ``SHUTDOWN_GRACE_S`` seconds to finish; anything still pending
        after that is cancelled and every non-terminal session deployed here
        is marked FAILED with an error naming the abandonment."""
        leftover = self.executor.drain(self.SHUTDOWN_GRACE_S)
        self.executor.shutdown(wait=False, cancel_futures=True)
        if leftover:
            self._fail_open_sessions(len(leftover))

    def _fail_open_sessions(self, n_inflight: int) -> None:
        reason = (f"node {self.name} shut down with {n_inflight} in-flight "
                  f"app call(s) abandoned after {self.SHUTDOWN_GRACE_S}s "
                  "grace; payloads may be partially written")
        for session in list(self._session_refs.values()):
            fail = getattr(session, "fail", None)
            if fail is not None:
                fail(reason)


class ProcNodeDropManager(NodeDropManager):
    """Node manager whose executor is a crash-isolated spawn worker process.

    Same ``node_executors()`` contract as the thread-backed manager — the
    executor still has ``submit`` (orchestration thunks run on a small local
    thread pool) — plus ``run_batch``, which the compiled dispatcher detects
    and routes Python-app batches through.  All nodes of one island share a
    :class:`~repro.core.procpool.PayloadPlane`, so intra-island array edges
    travel as shared-memory descriptors; a dead worker flips
    ``info.alive`` so the scheduler and resilience loop see a failed node.
    """

    def __init__(self, info: NodeInfo, plane: PayloadPlane,
                 max_workers: int = 4,
                 shm_min_bytes: Optional[int] = None) -> None:
        self._plane = plane
        self._shm_min_bytes = shm_min_bytes
        plane.retain()
        super().__init__(info, max_workers=max_workers)

    @property
    def plane(self) -> PayloadPlane:
        return self._plane

    def _make_executor(self, max_workers: int) -> ProcExecutor:
        ex = ProcExecutor(self.info.name, plane=self._plane,
                          submit_workers=max_workers,
                          shm_min_bytes=self._shm_min_bytes)
        ex.on_lost = self._on_worker_lost
        return ex

    def _on_worker_lost(self) -> None:
        self.info.alive = False

    def shutdown(self) -> None:
        leftover = self.executor.drain(self.SHUTDOWN_GRACE_S)
        self.executor.shutdown()          # stops the worker process too
        if leftover:
            self._fail_open_sessions(len(leftover))
        self._plane.release()


# ---------------------------------------------------------------------------
# Data Island Drop Manager
# ---------------------------------------------------------------------------


class DataIslandDropManager:
    def __init__(self, name: str,
                 node_managers: Sequence[NodeDropManager]) -> None:
        self.name = name
        self.node_managers = {nm.name: nm for nm in node_managers}
        # edges leaving/entering this island, recorded PER SESSION (a
        # single shared list used to accumulate across sessions and leak
        # one session's edges into the next deployment's wiring pass)
        self.cross_node_edges: Dict[str, List[Tuple[str, str, bool]]] = {}

    def deploy(self, session: Session, pgt: PhysicalGraphTemplate,
               specs: Sequence[DropSpec]) -> None:
        """Split by node placement; record crossing edges; wire afterwards."""
        by_node: Dict[str, List[DropSpec]] = {}
        for spec in specs:
            by_node.setdefault(spec.node or "?", []).append(spec)
        unknown = set(by_node) - set(self.node_managers)
        if unknown:
            raise ValueError(f"island {self.name}: drops placed on unknown "
                             f"nodes {sorted(unknown)}")
        for node, nspecs in by_node.items():
            self.node_managers[node].create_drops(session, nspecs)
        # intra-island edges: wire those whose both ends live here
        mine = {s.uid for s in specs}
        crossing = self.cross_node_edges.setdefault(session.session_id, [])
        for s, d, streaming in pgt.edges:
            if s in mine and d in mine:
                _wire(session, s, d, streaming)
            elif s in mine or d in mine:
                crossing.append((s, d, streaming))

    def deploy_compiled(self, session: CompiledSession, pgt: CompiledPGT,
                        by_node: Dict[str, np.ndarray]) -> None:
        """Array-native deployment: hand each node its drop-id slice.

        No edge wiring happens — adjacency stays in the shared CSR arrays
        and the frontier scheduler reads it directly; islands only
        validate node placement, exactly the paper's Fig. 6 split.
        """
        unknown = set(by_node) - set(self.node_managers)
        if unknown:
            raise ValueError(f"island {self.name}: drops placed on unknown "
                             f"nodes {sorted(unknown)}")
        for node, indices in by_node.items():
            self.node_managers[node].register_compiled(session, indices)

    def nodes_alive(self) -> List[str]:
        return [n for n, nm in self.node_managers.items() if nm.info.alive]


# ---------------------------------------------------------------------------
# Master Drop Manager
# ---------------------------------------------------------------------------


class MasterDropManager:
    """Single point of contact (paper §3.5); splits the PG by island."""

    def __init__(self, islands: Sequence[DataIslandDropManager]) -> None:
        self.islands = {im.name: im for im in islands}
        self._sessions: Dict[str, Session] = {}
        self._session_counter = 0

    # island of a node
    def _island_of(self, node: str) -> DataIslandDropManager:
        for im in self.islands.values():
            if node in im.node_managers:
                return im
        raise KeyError(f"node {node!r} not managed by any island")

    def create_session(self, session_id: Optional[str] = None,
                       bus: Optional[EventBus] = None) -> Session:
        if session_id is None:
            self._session_counter += 1
            session_id = f"session-{self._session_counter}"
        s = Session(session_id, bus=bus)
        self._sessions[session_id] = s
        return s

    def deploy(self, session: Session,
               pgt: PhysicalGraphTemplate) -> None:
        """Recursive deployment (paper Fig. 6): split by island, then node."""
        session.deploy()
        by_island: Dict[str, List[DropSpec]] = {}
        for spec in pgt.drops.values():
            if spec.node is None:
                raise ValueError(f"drop {spec.uid} not mapped to a node; "
                                 "run mapping.map_partitions first")
            im = self._island_of(spec.node)
            by_island.setdefault(im.name, []).append(spec)
        for iname, specs in by_island.items():
            self.islands[iname].deploy(session, pgt, specs)
        # wire edges crossing island boundaries (recorded by the islands,
        # scoped to THIS session; a cross-island edge appears in both
        # endpoint islands' records and must be wired exactly once)
        sid = session.session_id
        wired: Set[Tuple[str, str, bool]] = set()
        for im in self.islands.values():
            record = im.cross_node_edges.get(sid, [])
            for key in record:
                if key in wired:
                    continue
                s, d, streaming = key
                if s in session.drops and d in session.drops:
                    _wire(session, s, d, streaming)
                    wired.add(key)
            remaining = [e for e in record if e not in wired]
            if remaining:
                im.cross_node_edges[sid] = remaining
            else:
                im.cross_node_edges.pop(sid, None)

    def deploy_compiled(self, session: CompiledSession,
                        pgt: CompiledPGT) -> None:
        """Recursive array-native deployment (paper Fig. 6, batched).

        One stable ``argsort`` over ``node_ids`` yields every node's
        drop-id slice; islands get their nodes' slices — no DropSpec
        views are materialised anywhere on this path.
        """
        session.deploy()
        node_ids = pgt.node_ids
        if node_ids.size and int(node_ids.min()) < 0:
            first = int(np.flatnonzero(node_ids < 0)[0])
            raise ValueError(
                f"drop {pgt.uid_of(first)} not mapped to a node; "
                "run mapping.map_partitions first")
        by_island: Dict[str, Dict[str, np.ndarray]] = {}
        for name, indices in _node_slices(pgt).items():
            im = self._island_of(name)
            by_island.setdefault(im.name, {})[name] = indices
        for iname, by_node in by_island.items():
            self.islands[iname].deploy_compiled(session, pgt, by_node)
        if pgt.num_edges:
            session.cross_node_edges = int(
                (node_ids[pgt.edge_src] != node_ids[pgt.edge_dst]).sum())
        self._sessions[session.session_id] = session  # type: ignore[assignment]

    def refresh_compiled_slices(
            self, session: CompiledSession, pgt: CompiledPGT,
            moved_by_node: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Re-register per-node drop-id slices after ``node_ids`` changed
        (the batched analogue of re-deploying migrated drops onto their
        new Node Managers).

        With ``moved_by_node`` (new node -> migrated drop ids, from fault
        recovery) the update is incremental — O(moved + slices touched)
        instead of re-argsorting the whole graph; without it, slices are
        rebuilt from scratch."""
        nms = self.node_managers()
        if moved_by_node is None or not session.node_slices:
            sid = session.session_id
            for nm in nms.values():
                nm.compiled_sessions.pop(sid, None)
            session.node_slices.clear()
            for name, indices in _node_slices(pgt).items():
                self._island_of(name)   # placement must still be managed
                nms[name].register_compiled(session, indices)
            return
        gained = dict(moved_by_node)
        for node, old in list(session.node_slices.items()):
            add = gained.pop(node, None)
            if nms[node].info.alive:
                # live slices only ever gain (drops migrate OFF dead nodes)
                if add is not None:
                    nms[node].register_compiled(
                        session, np.concatenate([old, add]))
                continue
            # dead node: keep only the drops still placed there (terminal
            # survivors); everything migrated points elsewhere now
            keep = old[pgt.node_ids[old] == pgt.node_id_for(node)]
            new = keep if add is None else np.concatenate([keep, add])
            nms[node].register_compiled(session, new)
        for node, add in gained.items():   # nodes with no prior slice
            self._island_of(node)
            nms[node].register_compiled(session, add)

    def node_managers(self) -> Dict[str, NodeDropManager]:
        out: Dict[str, NodeDropManager] = {}
        for im in self.islands.values():
            out.update(im.node_managers)
        return out

    def live_node_managers(self) -> Dict[str, NodeDropManager]:
        """Node managers still alive (the migration-target view)."""
        return {n: nm for n, nm in self.node_managers().items()
                if nm.info.alive}

    def node_executors(self) -> Dict[str, ThreadPoolExecutor]:
        """Per-node thread pools of the live nodes — what the compiled
        engine's threaded wave dispatch overlaps Python-app batches on
        (``exec_compiled.execute_frontier(..., executors=...)``)."""
        return {n: nm.executor
                for n, nm in self.node_managers().items()
                if nm.info.alive}

    def dead_nodes(self) -> List[str]:
        return [n for n, nm in self.node_managers().items()
                if not nm.info.alive]

    def shutdown(self) -> None:
        for nm in self.node_managers().values():
            nm.shutdown()


def _node_slices(pgt: CompiledPGT) -> Dict[str, np.ndarray]:
    """Per-node drop-id index slices from ``node_ids`` — one stable
    argsort, shared by ``deploy_compiled`` and slice re-registration."""
    node_ids = pgt.node_ids
    order = np.argsort(node_ids, kind="stable").astype(np.int64)
    uniq, starts = np.unique(node_ids[order], return_index=True)
    bounds = np.append(starts, node_ids.size)
    return {pgt.node_names[nid]: order[bounds[k]:bounds[k + 1]]
            for k, nid in enumerate(uniq.tolist())}


def _wire(session: Session, src: str, dst: str, streaming: bool) -> None:
    s, d = session.drops[src], session.drops[dst]
    if isinstance(s, DataDrop) and isinstance(d, AppDrop):
        d.add_input(s, streaming=streaming)
    elif isinstance(s, AppDrop) and isinstance(d, DataDrop):
        s.add_output(d)
    else:
        raise ValueError(f"invalid edge {src}->{dst}: "
                         f"{type(s).__name__}->{type(d).__name__}")


# ---------------------------------------------------------------------------
# Convenience topology builder
# ---------------------------------------------------------------------------


def make_cluster(num_nodes: int, num_islands: int = 1,
                 workers_per_node: int = 4, workers: str = "thread",
                 shm_min_bytes: Optional[int] = None
                 ) -> Tuple[MasterDropManager, List[NodeInfo]]:
    """Build a Master/Island/Node manager hierarchy (paper Fig. 6).

    ``workers="process"`` gives every node a crash-isolated spawn worker
    (:class:`ProcNodeDropManager`) and every island one shared
    :class:`~repro.core.procpool.PayloadPlane`; ``shm_min_bytes`` tunes the
    array-size threshold below which values ship pickled instead of via
    shared memory (see ``docs/multiprocess.md``).
    """
    if num_islands < 1 or num_nodes < num_islands:
        raise ValueError("need >=1 island and nodes >= islands")
    if workers not in ("thread", "process"):
        raise ValueError(f"unknown workers mode {workers!r}")
    nodes: List[NodeInfo] = []
    islands: List[DataIslandDropManager] = []
    per = num_nodes // num_islands
    extra = num_nodes % num_islands
    idx = 0
    for i in range(num_islands):
        count = per + (1 if i < extra else 0)
        plane: Optional[PayloadPlane] = None
        if workers == "process":
            plane = (PayloadPlane() if shm_min_bytes is None
                     else PayloadPlane(shm_min_bytes=shm_min_bytes))
        nms: List[NodeDropManager] = []
        for _ in range(count):
            info = NodeInfo(name=f"node{idx}", island=f"island{i}")
            nodes.append(info)
            if plane is not None:
                nms.append(ProcNodeDropManager(
                    info, plane, max_workers=workers_per_node,
                    shm_min_bytes=shm_min_bytes))
            else:
                nms.append(NodeDropManager(info,
                                           max_workers=workers_per_node))
            idx += 1
        islands.append(DataIslandDropManager(f"island{i}", nms))
    return MasterDropManager(islands), nodes
