"""Resident multi-tenant engine (paper §3.5 — the MM/DIM/NM daemons).

The paper's Drop Managers are long-lived services: a Master Manager is
"a single point of contact" that stays up across observations, and each
observation is just a new *session* on the already-running hierarchy.
:class:`EngineManager` is that shape for the compiled path:

* one resident cluster (``make_cluster``) whose per-node thread pools
  are created **once** and shared by every session — ``Pipeline`` used
  to rebuild them per run,
* a :class:`~repro.core.templates.TemplateCache` so repeated
  submissions of the same logical-graph shape skip translate+map and
  pay only an O(drops) :meth:`~repro.core.templates.GraphTemplate.materialize`,
* bounded **admission control**: at most ``max_concurrent`` sessions
  execute at once and at most ``max_pending`` wait; beyond that
  ``submit`` raises :class:`AdmissionError` (or blocks, if asked to)
  instead of letting queue depth grow without bound,
* per-session **error isolation**: a failing app (or a crashing
  dispatch) marks *that* session's report failed and never unwinds the
  manager or its neighbours,
* session **close/eviction** that actually frees the dense payload
  table (:meth:`~repro.core.session.CompiledSession.close`) and
  unregisters the session's slices from every Node Drop Manager.

``benchmarks/bench_serve.py`` measures this as sustained sessions/s
with p50/p99 session latency — the millions-of-users serving shape the
ROADMAP targets.
"""
from __future__ import annotations

import threading
import time
import uuid
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

from .engine import ExecutionReport
from .events import EventBus
from .logical import LogicalGraph
from .session import CompiledSession, SessionState
from .telemetry import (LATENCY_BUCKETS_S, MetricsRegistry,
                        TelemetryConfig)
from .templates import GraphTemplate, TemplateCache, structural_hash

__all__ = ["AdmissionError", "SessionTicket", "EngineManager"]


class AdmissionError(RuntimeError):
    """The manager's admission queue is full (``max_concurrent`` running
    plus ``max_pending`` waiting); the caller should back off and retry."""


class SessionTicket:
    """Handle for one submitted session: its future report + timings.

    ``latency`` is the *session* latency a client observes — submit to
    report, queueing included — which is what bench_serve's p50/p99
    quantiles are computed over.
    """

    __slots__ = ("session_id", "template_key", "session", "future",
                 "submitted_at", "started_at", "finished_at", "_accounted")

    def __init__(self, session_id: str, template_key: str,
                 session: CompiledSession, future: "Future[ExecutionReport]"
                 ) -> None:
        self.session_id = session_id
        self.template_key = template_key
        self.session = session
        self.future = future
        self.submitted_at = time.monotonic()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self._accounted = threading.Event()   # manager _on_done ran

    def result(self, timeout: Optional[float] = None) -> ExecutionReport:
        report = self.future.result(timeout)
        # the done-callback stamps finished_at, but future waiters can
        # wake *before* callbacks run — stamp here too so latency is
        # never None, and wait for the manager's accounting callback so
        # stats()/metrics are consistent once result() has returned
        if self.finished_at is None:
            self.finished_at = time.monotonic()
        self._accounted.wait(timeout=5.0)
        return report

    def done(self) -> bool:
        return self.future.done()

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def queue_delay(self) -> Optional[float]:
        if self.started_at is None:
            return None
        return self.started_at - self.submitted_at


class EngineManager:
    """Resident compiled-path engine: template cache + concurrent sessions.

    Usage::

        with EngineManager(num_nodes=4, max_concurrent=4) as mgr:
            t1 = mgr.submit(lg, inputs={"in": 1})       # cold: translate+map
            t2 = mgr.submit(lg, inputs={"in": 2})       # warm: cache hit
            r1, r2 = t1.result(), t2.result()

    All sessions of one template share its ``CompiledPGT`` arrays
    (read-only) and the manager's node thread pools; each gets fresh
    state/payload/error storage, so concurrent sessions are fully
    isolated (``tests/test_serving.py``).
    """

    def __init__(self, num_nodes: int = 2, num_islands: int = 1,
                 workers_per_node: int = 4, dop: int = 8,
                 algorithm: str = "min_time",
                 deadline: Optional[float] = None,
                 max_templates: int = 8,
                 max_concurrent: int = 4,
                 max_pending: int = 64,
                 keep_finished: int = 32,
                 telemetry: Optional[TelemetryConfig] = None,
                 workers: str = "thread") -> None:
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        if max_pending < 0:
            raise ValueError("max_pending must be >= 0")
        from .managers import make_cluster
        self.master, self.nodes = make_cluster(
            num_nodes, num_islands, workers_per_node, workers=workers)
        self.dop = dop
        self.algorithm = algorithm
        self.deadline = deadline
        self.telemetry = telemetry if telemetry is not None \
            else TelemetryConfig()
        self.metrics = MetricsRegistry() if self.telemetry.metrics else None
        if self.metrics is not None:
            # pre-created handles: submit/_on_done touch metric locks
            # only, never the registry dict
            self._m_submitted = self.metrics.counter("manager.submitted")
            self._m_rejected = self.metrics.counter("manager.rejected")
            self._m_completed = self.metrics.counter("manager.completed")
            self._m_failed = self.metrics.counter("manager.failed")
            self._m_queue = self.metrics.gauge("manager.queue_depth")
            self._m_latency = self.metrics.histogram(
                "manager.session_latency_s", LATENCY_BUCKETS_S)
        self.templates = TemplateCache(max_templates, metrics=self.metrics)
        self.max_concurrent = max_concurrent
        self.max_pending = max_pending
        self.keep_finished = keep_finished
        # satellite: node executors cached once for the manager's lifetime
        # (Pipeline rebuilt the dict per run; the pools themselves now also
        # outlive any single session and are shut down only by close())
        self.executors = self.master.node_executors()
        self._pool = ThreadPoolExecutor(
            max_workers=max_concurrent, thread_name_prefix="engine-session")
        # admission: running + pending slots; acquired in submit(),
        # released when the session's report future resolves
        self._slots = threading.BoundedSemaphore(max_concurrent + max_pending)
        self._lock = threading.Lock()
        self._tickets: "Dict[str, SessionTicket]" = {}
        self._finished_order: List[str] = []
        self._session_counter = 0
        self._closed = False
        self.stats_counters = {
            "submitted": 0, "completed": 0, "failed": 0,
            "rejected": 0, "closed_sessions": 0}

    # -- templates ---------------------------------------------------------
    def get_template(self, lg: LogicalGraph, *,
                     algorithm: Optional[str] = None,
                     dop: Optional[int] = None,
                     deadline: Optional[float] = None) -> GraphTemplate:
        """Cached translate+map for one logical graph shape.

        Cold path builds outside the cache lock (translate can take
        seconds at large tiers); racing builders are deduplicated by
        first-insert-wins."""
        algorithm = algorithm if algorithm is not None else self.algorithm
        dop = dop if dop is not None else self.dop
        deadline = deadline if deadline is not None else self.deadline
        key = structural_hash(lg, algorithm=algorithm, dop=dop,
                              deadline=deadline, nodes=self.nodes)
        tpl = self.templates.lookup(key)
        if tpl is not None:
            return tpl
        tpl = GraphTemplate.build(lg, self.nodes, algorithm=algorithm,
                                  dop=dop, deadline=deadline, key=key)
        return self.templates.insert(tpl)

    # -- session submission ------------------------------------------------
    def submit(self, lg: LogicalGraph, *,
               inputs: Optional[Dict[str, Any]] = None,
               timeout: float = 60.0,
               session_id: Optional[str] = None,
               block: bool = False,
               admission_timeout: Optional[float] = None) -> SessionTicket:
        """Admit one session and schedule it on the session pool.

        Non-blocking by default: raises :class:`AdmissionError` when all
        ``max_concurrent + max_pending`` slots are taken.  With
        ``block=True`` waits (up to ``admission_timeout``) for a slot.
        """
        if self._closed:
            raise RuntimeError("EngineManager is closed")
        acquired = (self._slots.acquire(timeout=admission_timeout)
                    if block else self._slots.acquire(blocking=False))
        if not acquired:
            with self._lock:
                self.stats_counters["rejected"] += 1
            if self.metrics is not None:
                self._m_rejected.inc()
            raise AdmissionError(
                f"admission queue full ({self.max_concurrent} running + "
                f"{self.max_pending} pending)")
        try:
            template = self.get_template(lg)
            if session_id is None:
                with self._lock:
                    self._session_counter += 1
                    session_id = (f"svc-{self._session_counter}-"
                                  f"{uuid.uuid4().hex[:6]}")
            session = template.materialize(session_id, master=self.master)
            if self.telemetry.timeline:
                session.enable_timeline()
            session.metrics = self.metrics
            if inputs:
                for uid, value in inputs.items():
                    session.write(uid, value)
            future = self._pool.submit(
                self._run, session, template, timeout)
        except BaseException:
            self._slots.release()
            raise
        ticket = SessionTicket(session_id, template.key, session, future)
        with self._lock:
            self._tickets[session_id] = ticket
            self.stats_counters["submitted"] += 1
        if self.metrics is not None:
            self._m_submitted.inc()
            self._m_queue.inc()

        def _on_done(fut: "Future[ExecutionReport]",
                     t: SessionTicket = ticket) -> None:
            if t.finished_at is None:
                t.finished_at = time.monotonic()
            self._slots.release()
            failed = (fut.cancelled() or fut.exception() is not None
                      or not fut.result().ok)
            with self._lock:
                self.stats_counters["failed" if failed else "completed"] += 1
                self._finished_order.append(t.session_id)
            if self.metrics is not None:
                self._m_queue.dec()
                (self._m_failed if failed else self._m_completed).inc()
                lat = t.latency
                if lat is not None:
                    self._m_latency.observe(lat)
            t._accounted.set()
            self._evict_finished()

        future.add_done_callback(_on_done)
        return ticket

    def _run(self, session: CompiledSession, template: GraphTemplate,
             timeout: float) -> ExecutionReport:
        """Execute one admitted session; never lets an exception escape
        into the pool — errors become a failed report (isolation)."""
        from .exec_compiled import execute_frontier
        ticket = self._tickets.get(session.session_id)
        if ticket is not None:
            ticket.started_at = time.monotonic()
        t0 = time.monotonic()
        try:
            finished = execute_frontier(session, timeout=timeout,
                                        executors=self.executors)
            errs = [f"{r.uid}: {(r.error_info or '')[:200]}"
                    for r in session.errors()]
            state = session.state.value if finished else "TIMEOUT"
        except Exception as exc:   # scheduler crash: this session only
            finished = False
            errs = [f"<scheduler>: {type(exc).__name__}: {exc}"[:240]]
            state = "FAILED"
        return ExecutionReport(
            session_id=session.session_id,
            state=state,
            status_counts=session.status(),
            wall_time=time.monotonic() - t0,
            events_published=session.bus.published,
            errors=errs,
        )

    def run(self, lg: LogicalGraph, *,
            inputs: Optional[Dict[str, Any]] = None,
            timeout: float = 60.0,
            session_id: Optional[str] = None) -> ExecutionReport:
        """Synchronous convenience: submit (blocking admission) + wait."""
        ticket = self.submit(lg, inputs=inputs, timeout=timeout,
                             session_id=session_id, block=True)
        return ticket.result()

    # -- session lifecycle -------------------------------------------------
    def get_session(self, session_id: str) -> Optional[CompiledSession]:
        t = self._tickets.get(session_id)
        return t.session if t is not None else None

    def close_session(self, session_id: str) -> bool:
        """Release one finished session's resources *for real*: drop the
        dense payload table and unregister its slices from every NM."""
        with self._lock:
            ticket = self._tickets.pop(session_id, None)
        if ticket is None:
            return False
        for nm in self.master.node_managers().values():
            nm.compiled_sessions.pop(session_id, None)
        self.master._sessions.pop(session_id, None)
        ticket.session.close()
        with self._lock:
            self.stats_counters["closed_sessions"] += 1
        return True

    def _evict_finished(self) -> None:
        """Retain only the newest ``keep_finished`` finished sessions;
        older ones are closed (payload tables freed) automatically."""
        to_close: List[str] = []
        with self._lock:
            self._finished_order = [
                sid for sid in self._finished_order if sid in self._tickets]
            excess = len(self._finished_order) - self.keep_finished
            if excess > 0:
                to_close = self._finished_order[:excess]
        for sid in to_close:
            self.close_session(sid)

    # -- monitoring --------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = dict(self.stats_counters)
            out["open_sessions"] = len(self._tickets)
        out["templates"] = self.templates.stats()
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        return out

    # -- shutdown ----------------------------------------------------------
    def close(self, wait: bool = True) -> None:
        """Drain the session pool, close every session, then shut the
        node pools down — the one place shared executors die."""
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=wait)
        for sid in list(self._tickets):
            self.close_session(sid)
        self.master.shutdown()

    def __enter__(self) -> "EngineManager":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
