"""Event system — the tokens travelling through graph edges (paper §1 item 3, §4.1).

DALiuGE fires events between Drops via direct object invocation (same node) or
ZeroMQ pub/sub (cross node).  This container is single-host, so the transport
is an in-process bus; the ``EventChannel`` interface is what a network
deployment would re-implement (the paper keeps "communication channels" cleanly
separated from bulk data operations — §4.1 — and so do we).
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Event:
    """An event fired by a Drop as it transitions through its lifecycle."""

    type: str                      # e.g. "status", "producerFinished", "dropCompleted"
    source_uid: str                # uid of the Drop that fired it
    data: Dict[str, Any] = field(default_factory=dict)
    timestamp: float = field(default_factory=time.monotonic)


Listener = Callable[[Event], None]


class EventChannel:
    """Abstract transport for events between managers/nodes."""

    def publish(self, event: Event) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def subscribe(self, source_uid: str, listener: Listener) -> None:  # pragma: no cover
        raise NotImplementedError


class EventBus(EventChannel):
    """In-process pub/sub bus.

    Thread-safe; listeners are invoked synchronously on the publishing thread
    (the decentralised cascade of the paper: a completed Data Drop directly
    triggers its consumers, which may schedule work on their own executor).
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._subs: Dict[str, List[Listener]] = defaultdict(list)
        self._global_subs: List[Listener] = []
        self.published = 0  # instrumentation for the overhead benchmark

    def subscribe(self, source_uid: str, listener: Listener) -> None:
        with self._lock:
            self._subs[source_uid].append(listener)

    def subscribe_all(self, listener: Listener) -> None:
        with self._lock:
            self._global_subs.append(listener)

    def unsubscribe(self, source_uid: str, listener: Listener) -> None:
        with self._lock:
            if listener in self._subs.get(source_uid, []):
                self._subs[source_uid].remove(listener)

    def publish(self, event: Event) -> None:
        with self._lock:
            listeners = list(self._subs.get(event.source_uid, ()))
            listeners.extend(self._global_subs)
            self.published += 1
        for listener in listeners:
            listener(event)


class RecordingListener:
    """Test/benchmark helper — records every event it sees."""

    def __init__(self) -> None:
        self.events: List[Event] = []
        self._lock = threading.Lock()

    def __call__(self, event: Event) -> None:
        with self._lock:
            self.events.append(event)

    def of_type(self, type_: str) -> List[Event]:
        with self._lock:
            return [e for e in self.events if e.type == type_]
