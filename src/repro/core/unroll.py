"""LG -> Physical Graph Template translation (paper §3.4, step 2).

"The second step unrolls the logical graph by first creating all necessary
Drop specifications ... and second establishing directed edges amongst these
Drop specifications."

Unrolling model
---------------
Every *leaf* construct survives to a set of physical instances indexed by the
**axes** contributed by its enclosing containers:

* ``Scatter(K)``     -> axis of size K,
* ``Loop(T)``        -> axis of size T,
* ``Gather(g)``      -> collapses the innermost axis K -> K/g; each surviving
  index q covers underlying coordinates ``[q*g, (q+1)*g)``,
* ``GroupBy``        -> the corner turn: drops the *outer* scatter axis and
  keeps the *inner* one; each instance consumes every outer coordinate.

Edges between leaves connect instance-wise by **joining on underlying scatter
coordinates**: shared axes align, a dst-range (Gather) fans in, a missing axis
on the dst side (GroupBy / graph-level reduce) consumes the full range, a
missing axis on the src side broadcasts.  Loop-carried Data nodes are aliased:
iteration ``t``'s ``loop_entry`` *is* iteration ``t-1``'s ``loop_exit`` drop
("new Data Drops created in each iteration", paper §2.3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .constructs import Construct, Kind
from .logical import GraphValidationError, LogicalGraph


# ---------------------------------------------------------------------------
# Physical Graph Template
# ---------------------------------------------------------------------------


@dataclass
class DropSpec:
    """A Drop specification — a PGT node (not yet bound to resources)."""

    uid: str
    kind: str                      # "app" | "data"
    construct: str                 # originating construct name
    oid: Tuple[int, ...]           # instance coordinates
    app: Optional[str] = None
    payload_kind: str = "memory"
    execution_time: float = 0.0
    data_volume: float = 0.0
    error_threshold: float = 0.0
    params: Dict[str, Any] = field(default_factory=dict)
    partition: int = -1            # logical partition (paper §3.4 step 3)
    node: Optional[str] = None     # physical node (paper §3.5)

    def weight(self) -> float:
        """Cost-model weight: runtime for apps, volume for data."""
        return self.execution_time if self.kind == "app" else 0.0


@dataclass
class PhysicalGraphTemplate:
    name: str
    drops: Dict[str, DropSpec] = field(default_factory=dict)
    edges: List[Tuple[str, str, bool]] = field(default_factory=list)
    _succ: Optional[Dict[str, List[str]]] = None
    _pred: Optional[Dict[str, List[str]]] = None

    def add_drop(self, spec: DropSpec) -> None:
        if spec.uid in self.drops:
            raise GraphValidationError(f"duplicate drop uid {spec.uid!r}")
        self.drops[spec.uid] = spec
        self._succ = self._pred = None
        self.__dict__.pop("_sched_arrays", None)

    def add_edge(self, src: str, dst: str, streaming: bool = False) -> None:
        self.edges.append((src, dst, streaming))
        self._succ = self._pred = None
        self.__dict__.pop("_sched_arrays", None)

    # -- adjacency --------------------------------------------------------------
    def _build_adj(self) -> None:
        succ: Dict[str, List[str]] = {u: [] for u in self.drops}
        pred: Dict[str, List[str]] = {u: [] for u in self.drops}
        for s, d, _ in self.edges:
            succ[s].append(d)
            pred[d].append(s)
        self._succ, self._pred = succ, pred

    def successors(self, uid: str) -> List[str]:
        if self._succ is None:
            self._build_adj()
        return self._succ[uid]  # type: ignore[index]

    def predecessors(self, uid: str) -> List[str]:
        if self._pred is None:
            self._build_adj()
        return self._pred[uid]  # type: ignore[index]

    def roots(self) -> List[str]:
        if self._pred is None:
            self._build_adj()
        return [u for u, p in self._pred.items() if not p]  # type: ignore[union-attr]

    def topological_order(self) -> List[str]:
        if self._pred is None:
            self._build_adj()
        indeg = {u: len(p) for u, p in self._pred.items()}  # type: ignore[union-attr]
        stack = [u for u, d in indeg.items() if d == 0]
        order: List[str] = []
        while stack:
            u = stack.pop()
            order.append(u)
            for v in self._succ[u]:  # type: ignore[index]
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != len(self.drops):
            raise GraphValidationError("physical graph contains a cycle")
        return order

    def __len__(self) -> int:
        return len(self.drops)


# ---------------------------------------------------------------------------
# Axes
# ---------------------------------------------------------------------------


@dataclass
class Axis:
    """A surviving instance axis of a leaf construct.

    ``underlying`` is the contributing Scatter/Loop construct name;
    ``size`` the number of surviving indices; ``group`` the number of
    underlying coordinates covered by one surviving index (Gather collapse).
    """

    underlying: str
    underlying_size: int
    size: int
    group: int = 1   # surviving index q covers [q*group, (q+1)*group)

    def to_index(self, coord: int) -> int:
        return coord // self.group

    def to_coords(self, index: int) -> range:
        return range(index * self.group, (index + 1) * self.group)


class AxisResolver:
    """Resolve the surviving axes of every leaf construct.

    Scatter and Loop ancestors *contribute* axes.  Gather and GroupBy
    *transform* the axes of their **incoming flow** (paper Fig. 3 draws them
    as siblings consuming the scattered branches; they may equally be nested
    inside the Scatter — both spellings resolve identically here):

    * Gather(g): innermost incoming axis K -> K/g (fan-in g per instance),
    * GroupBy:   corner turn — drop the outer of the last two incoming axes,
      keep the inner (each instance consumes the full outer range).

    The incoming flow of a container is taken from the edge whose source is
    outside the container subtree and carries the most axes (the most
    specific producer — broadcast side-inputs don't define the flow shape).
    """

    def __init__(self, lg: LogicalGraph) -> None:
        self.lg = lg
        self._leaf_cache: Dict[str, List[Axis]] = {}
        self._cont_cache: Dict[Optional[str], List[Axis]] = {}
        self._resolving: set = set()

    # -- public ----------------------------------------------------------
    def leaf_axes(self, leaf: str) -> List[Axis]:
        if leaf not in self._leaf_cache:
            c = self.lg.constructs[leaf]
            self._leaf_cache[leaf] = list(self._container_axes(c.parent))
        return self._leaf_cache[leaf]

    # -- internals ----------------------------------------------------------
    def _subtree_leaves(self, name: str) -> List[str]:
        out: List[str] = []
        stack = [name]
        while stack:
            n = stack.pop()
            for ch in self.lg.children(n):
                if ch.is_container():
                    stack.append(ch.name)
                else:
                    out.append(ch.name)
        return out

    def _incoming_axes(self, name: str) -> List[Axis]:
        inside = set(self._subtree_leaves(name))
        best: Optional[List[Axis]] = None
        for e in self.lg.edges:
            if e.dst in inside and e.src not in inside:
                axes = self.leaf_axes(e.src)
                if best is None or len(axes) > len(best):
                    best = axes
        if best is None:
            raise GraphValidationError(
                f"{name!r} has no incoming flow to aggregate")
        return list(best)

    def _container_axes(self, name: Optional[str]) -> List[Axis]:
        if name in self._cont_cache:
            return self._cont_cache[name]
        if name is None:
            return []
        if name in self._resolving:
            raise GraphValidationError(
                f"cyclic aggregation through container {name!r}")
        self._resolving.add(name)
        try:
            c = self.lg.constructs[name]
            if c.kind is Kind.SCATTER:
                axes = self._container_axes(c.parent) + [
                    Axis(c.name, c.num_of_copies, c.num_of_copies)]
            elif c.kind is Kind.LOOP:
                axes = self._container_axes(c.parent) + [
                    Axis(c.name, c.num_of_iterations, c.num_of_iterations)]
            elif c.kind is Kind.GATHER:
                axes = self._incoming_axes(name)
                if not axes:
                    raise GraphValidationError(
                        f"Gather {c.name!r} has no incoming axis to collapse")
                last = axes[-1]
                g = c.num_of_inputs
                if last.size % g:
                    raise GraphValidationError(
                        f"Gather {c.name!r}: fan-in {g} does not divide "
                        f"branch count {last.size}")
                axes[-1] = Axis(last.underlying, last.underlying_size,
                                last.size // g, last.group * g)
            elif c.kind is Kind.GROUPBY:
                axes = self._incoming_axes(name)
                if len(axes) < 2:
                    raise GraphValidationError(
                        f"GroupBy {c.name!r} needs two incoming axes "
                        "(nested Scatters)")
                # corner turn: drop the outer axis, keep the inner
                axes = axes[:-2] + [axes[-1]]
            else:  # pragma: no cover - validated earlier
                raise GraphValidationError(
                    f"{name!r} is not a container")
        finally:
            self._resolving.discard(name)
        self._cont_cache[name] = axes
        return axes


def leaf_axes(lg: LogicalGraph, leaf: str) -> List[Axis]:
    """Compute the surviving axes of a leaf (convenience wrapper)."""
    return AxisResolver(lg).leaf_axes(leaf)


# ---------------------------------------------------------------------------
# Unroll
# ---------------------------------------------------------------------------


def _uid(name: str, idx: Tuple[int, ...]) -> str:
    return name if not idx else f"{name}#{'.'.join(map(str, idx))}"


def unroll_dict(lg: LogicalGraph) -> PhysicalGraphTemplate:
    """Reference dict-of-DropSpec unroll (the seed path).

    Kept as the semantic oracle for the vectorized CSR path (see
    :func:`unroll`) and as the fallback for loop-carried graphs, whose
    iteration-aliasing is inherently per-instance.
    """
    lg.validate()
    pgt = PhysicalGraphTemplate(name=lg.name)

    leaves = lg.leaves()
    resolver = AxisResolver(lg)
    axes_of: Dict[str, List[Axis]] = {
        c.name: resolver.leaf_axes(c.name) for c in leaves}

    # --- loop-carried aliasing ------------------------------------------------
    # map (entry_name, loop_coord) -> exit construct name, for t > 0
    carries: Dict[str, str] = {}          # entry -> exit
    loop_axis_of: Dict[str, str] = {}     # entry -> loop construct name
    for c in leaves:
        if c.kind is Kind.DATA and c.loop_exit:
            entry = c.params.get("carries")
            if not entry or entry not in lg.constructs:
                raise GraphValidationError(
                    f"loop_exit {c.name!r} must name its 'carries' entry")
            e = lg.constructs[entry]
            if not e.loop_entry:
                raise GraphValidationError(
                    f"{entry!r} is not marked loop_entry")
            carries[entry] = c.name
            loops = [a for a in lg.ancestors(c.name) if a.kind is Kind.LOOP]
            if not loops:
                raise GraphValidationError(
                    f"loop_exit {c.name!r} is outside any Loop")
            loop_axis_of[entry] = loops[-1].name

    def loop_pos(leaf: str) -> Optional[int]:
        """Index of the carried loop axis within the leaf's axes."""
        la = loop_axis_of.get(leaf)
        if la is None:
            return None
        for i, ax in enumerate(axes_of[leaf]):
            if ax.underlying == la:
                return i
        return None

    # --- instantiate drops ------------------------------------------------------
    # alias: (construct, idx) -> uid actually used
    alias: Dict[Tuple[str, Tuple[int, ...]], str] = {}

    for c in leaves:
        axes = axes_of[c.name]
        lp = loop_pos(c.name) if c.name in carries else None
        for idx in itertools.product(*(range(a.size) for a in axes)):
            if lp is not None and idx[lp] > 0:
                # entry at iteration t>0 aliases exit at t-1
                exit_name = carries[c.name]
                prev = list(idx)
                prev[lp] -= 1
                # exit axes may be ordered differently; align by axis name
                e_axes = axes_of[exit_name]
                coordmap = {axes[i].underlying: prev[i]
                            for i in range(len(axes))}
                e_idx = tuple(coordmap[a.underlying] for a in e_axes)
                alias[(c.name, idx)] = _uid(exit_name, e_idx)
                continue
            uid = _uid(c.name, idx)
            if c.kind is Kind.DATA:
                spec = DropSpec(uid=uid, kind="data", construct=c.name,
                                oid=idx, payload_kind=c.payload_kind,
                                data_volume=float(c.data_volume),
                                params=dict(c.params))
            else:
                spec = DropSpec(uid=uid, kind="app", construct=c.name,
                                oid=idx, app=c.app,
                                execution_time=float(c.execution_time),
                                error_threshold=c.error_threshold,
                                params=dict(c.params))
            pgt.add_drop(spec)

    def resolve(name: str, idx: Tuple[int, ...]) -> str:
        return alias.get((name, idx), _uid(name, idx))

    # --- connect edges -----------------------------------------------------------
    seen: set = set()
    for e in lg.edges:
        s_axes, d_axes = axes_of[e.src], axes_of[e.dst]
        d_axis_names = {a.underlying for a in d_axes}
        src_c = lg.constructs[e.src]
        # loop_exit -> consumer outside the loop: only the FINAL iteration's
        # exit drop leaves the loop (the paper's loop produces one result).
        exit_pin: Dict[str, int] = {}
        if src_c.kind is Kind.DATA and src_c.loop_exit:
            loops = [a for a in lg.ancestors(e.src) if a.kind is Kind.LOOP]
            if loops and loops[-1].name not in d_axis_names:
                exit_pin[loops[-1].name] = loops[-1].num_of_iterations - 1
        for d_idx in itertools.product(*(range(a.size) for a in d_axes)):
            if (e.dst, d_idx) in alias:
                # loop-entry instances at t>0 are pure aliases of exit[t-1];
                # nothing is ever produced *into* them directly.
                continue
            # constraints: underlying coords covered by this dst instance
            constraints: Dict[str, Iterable[int]] = {
                a.underlying: a.to_coords(i)
                for a, i in zip(d_axes, d_idx)}
            # enumerate matching src coordinates per src axis
            coord_ranges = []
            for a in s_axes:
                if a.underlying in exit_pin:
                    coords: Iterable[int] = (exit_pin[a.underlying],)
                else:
                    coords = constraints.get(a.underlying,
                                             range(a.underlying_size))
                coord_ranges.append(coords)
            dst_uid = resolve(e.dst, d_idx)
            for combo in itertools.product(*coord_ranges):
                s_idx = tuple(a.to_index(c)
                              for a, c in zip(s_axes, combo))
                src_uid = resolve(e.src, s_idx)
                key = (src_uid, dst_uid, e.streaming)
                if key in seen or src_uid == dst_uid:
                    continue
                seen.add(key)
                pgt.add_edge(src_uid, dst_uid, e.streaming)
    # sanity: the PGT must be a DAG (validated LGs always are, but aliasing
    # of loop-carried drops could surface user errors)
    pgt.topological_order()
    return pgt


# ---------------------------------------------------------------------------
# Vectorized unroll -> CompiledPGT (CSR arrays)
# ---------------------------------------------------------------------------


class _NeedsFallback(Exception):
    """Raised when an edge pattern has no closed-form array expansion."""


def _expand_edge(s_axes: List[Axis], d_axes: List[Axis],
                 s_base: int, d_base: int):
    """Vectorized instance-wise edge expansion for one logical edge.

    Mirrors the per-instance join of :func:`unroll_dict`: shared underlying
    axes align (with Gather fan-in/fan-out via the group ratios), an axis
    missing on the dst side is consumed in full, an axis missing on the src
    side broadcasts.  Returns (src_ids, dst_ids) int64 arrays.
    """
    d_sizes = [a.size for a in d_axes]
    nd = 1
    for s in d_sizes:
        nd *= s
    d_strides = []
    acc = 1
    for s in reversed(d_sizes):
        d_strides.append(acc)
        acc *= s
    d_strides.reverse()
    dmap = {a.underlying: (a, j) for j, a in enumerate(d_axes)}

    s_strides = []
    acc = 1
    for a in reversed(s_axes):
        s_strides.append(acc)
        acc *= a.size
    s_strides.reverse()

    dst = np.arange(nd, dtype=np.int64)
    src_acc = np.zeros(nd, dtype=np.int64)
    for a, s_stride in zip(s_axes, s_strides):
        hit = dmap.get(a.underlying)
        if hit is not None:
            da, j = hit
            cj = (dst // d_strides[j]) % d_sizes[j]
            gd, gs = da.group, a.group
            if gs % gd == 0:
                # dst instance covers one src index (or a sub-block of one)
                src_acc = src_acc + ((cj * gd) // gs) * s_stride
            elif gd % gs == 0:
                k = gd // gs
                m = dst.shape[0]
                dst = np.repeat(dst, k)
                src_acc = np.repeat(src_acc, k) + (
                    np.repeat(cj * k, k) +
                    np.tile(np.arange(k, dtype=np.int64), m)) * s_stride
            else:
                raise _NeedsFallback(
                    f"incommensurate groups on axis {a.underlying!r}")
        else:
            # axis absent on dst: consume the full (deduplicated) src range
            k = a.size
            m = dst.shape[0]
            dst = np.repeat(dst, k)
            src_acc = np.repeat(src_acc, k) + np.tile(
                np.arange(k, dtype=np.int64), m) * s_stride
    return s_base + src_acc, d_base + dst


def compile_unroll(lg: LogicalGraph) -> "CompiledPGT":
    """Unroll a logical graph straight into CSR arrays.

    Drop ids are allocated leaf-by-leaf in ``lg.leaves()`` order with
    C-order instance coordinates — the exact creation order of
    :func:`unroll_dict` — so the two representations are index-compatible
    and scheduling tie-breaks agree.  Loop-carried graphs (iteration
    aliasing) fall back to the dict path and are converted.
    """
    from .pgt import KIND_APP, KIND_DATA, CompiledPGT, InstanceGroup

    lg.validate()
    leaves = lg.leaves()
    if any(c.loop_entry or c.loop_exit for c in leaves):
        return CompiledPGT.from_dict_pgt(unroll_dict(lg))

    resolver = AxisResolver(lg)
    axes_of: Dict[str, List[Axis]] = {
        c.name: resolver.leaf_axes(c.name) for c in leaves}

    groups: List[InstanceGroup] = []
    base_of: Dict[str, int] = {}
    base = 0
    for c in leaves:
        axes = axes_of[c.name]
        sizes = tuple(a.size for a in axes)
        base_of[c.name] = base
        if c.kind is Kind.DATA:
            groups.append(InstanceGroup(
                name=c.name, base=base, sizes=sizes, kind=KIND_DATA,
                app=None, payload_kind=c.payload_kind, execution_time=0.0,
                data_volume=float(c.data_volume), error_threshold=0.0,
                params=dict(c.params)))
        else:
            groups.append(InstanceGroup(
                name=c.name, base=base, sizes=sizes, kind=KIND_APP,
                app=c.app, payload_kind="memory",
                execution_time=float(c.execution_time), data_volume=0.0,
                error_threshold=c.error_threshold, params=dict(c.params)))
        base += groups[-1].count
    n = base

    kind = np.empty(n, dtype=np.uint8)
    ex = np.zeros(n, dtype=np.float64)
    vol = np.zeros(n, dtype=np.float64)
    for g in groups:
        kind[g.base:g.base + g.count] = g.kind
        ex[g.base:g.base + g.count] = g.execution_time
        vol[g.base:g.base + g.count] = g.data_volume

    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    strs: List[np.ndarray] = []
    for e in lg.edges:
        try:
            s_ids, d_ids = _expand_edge(
                axes_of[e.src], axes_of[e.dst],
                base_of[e.src], base_of[e.dst])
        except _NeedsFallback:
            return CompiledPGT.from_dict_pgt(unroll_dict(lg))
        srcs.append(s_ids)
        dsts.append(d_ids)
        strs.append(np.full(s_ids.shape[0], e.streaming, dtype=bool))

    if srcs:
        esrc = np.concatenate(srcs)
        edst = np.concatenate(dsts)
        estr = np.concatenate(strs)
        # dedup (parallel logical edges / grouped fan-in overlap), like the
        # dict path's seen-set; canonical order is (src, dst)
        key = (esrc * np.int64(n) + edst) * 2 + estr
        _, first = np.unique(key, return_index=True)
        esrc, edst, estr = esrc[first], edst[first], estr[first]
    else:
        esrc = np.empty(0, dtype=np.int64)
        edst = np.empty(0, dtype=np.int64)
        estr = np.empty(0, dtype=bool)

    return CompiledPGT(lg.name, groups, kind, ex, vol, esrc, edst, estr)


def unroll(lg: LogicalGraph) -> "CompiledPGT":
    """LG -> array-based physical graph template (the default path)."""
    return compile_unroll(lg)
