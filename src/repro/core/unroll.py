"""LG -> Physical Graph Template translation (paper §3.4, step 2).

"The second step unrolls the logical graph by first creating all necessary
Drop specifications ... and second establishing directed edges amongst these
Drop specifications."

Unrolling model
---------------
Every *leaf* construct survives to a set of physical instances indexed by the
**axes** contributed by its enclosing containers:

* ``Scatter(K)``     -> axis of size K,
* ``Loop(T)``        -> axis of size T,
* ``Gather(g)``      -> collapses the innermost axis K -> K/g; each surviving
  index q covers underlying coordinates ``[q*g, (q+1)*g)``,
* ``GroupBy``        -> the corner turn: drops the *outer* scatter axis and
  keeps the *inner* one; each instance consumes every outer coordinate.

Edges between leaves connect instance-wise by **joining on underlying scatter
coordinates**: shared axes align, a dst-range (Gather) fans in, a missing axis
on the dst side (GroupBy / graph-level reduce) consumes the full range, a
missing axis on the src side broadcasts.  Loop-carried Data nodes are aliased:
iteration ``t``'s ``loop_entry`` *is* iteration ``t-1``'s ``loop_exit`` drop
("new Data Drops created in each iteration", paper §2.3), and a ``loop_exit``
consumed *outside* its loop contributes only the final iteration's value —
flows crossing the loop boundary shed the loop axis.

Both the reference dict path (:func:`unroll_dict`) and the vectorized array
path (:func:`unroll` -> :class:`~repro.core.pgt.CompiledPGT`) implement the
same semantics; the array path expresses iteration aliasing as index
substitution on block-diagonal per-iteration edge maps instead of
per-instance dict walks.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .constructs import Construct, Kind
from .logical import GraphValidationError, LogicalGraph


# ---------------------------------------------------------------------------
# Physical Graph Template
# ---------------------------------------------------------------------------


@dataclass
class DropSpec:
    """A Drop specification — a PGT node (not yet bound to resources)."""

    uid: str
    kind: str                      # "app" | "data"
    construct: str                 # originating construct name
    oid: Tuple[int, ...]           # instance coordinates
    app: Optional[str] = None
    payload_kind: str = "memory"
    execution_time: float = 0.0
    data_volume: float = 0.0
    error_threshold: float = 0.0
    params: Dict[str, Any] = field(default_factory=dict)
    partition: int = -1            # logical partition (paper §3.4 step 3)
    node: Optional[str] = None     # physical node (paper §3.5)

    def weight(self) -> float:
        """Cost-model weight: runtime for apps, volume for data."""
        return self.execution_time if self.kind == "app" else 0.0


@dataclass
class PhysicalGraphTemplate:
    name: str
    drops: Dict[str, DropSpec] = field(default_factory=dict)
    edges: List[Tuple[str, str, bool]] = field(default_factory=list)
    _succ: Optional[Dict[str, List[str]]] = None
    _pred: Optional[Dict[str, List[str]]] = None

    def add_drop(self, spec: DropSpec) -> None:
        if spec.uid in self.drops:
            raise GraphValidationError(f"duplicate drop uid {spec.uid!r}")
        self.drops[spec.uid] = spec
        self._succ = self._pred = None
        self.__dict__.pop("_sched_arrays", None)

    def add_edge(self, src: str, dst: str, streaming: bool = False) -> None:
        self.edges.append((src, dst, streaming))
        self._succ = self._pred = None
        self.__dict__.pop("_sched_arrays", None)

    # -- adjacency --------------------------------------------------------------
    def _build_adj(self) -> None:
        succ: Dict[str, List[str]] = {u: [] for u in self.drops}
        pred: Dict[str, List[str]] = {u: [] for u in self.drops}
        for s, d, _ in self.edges:
            succ[s].append(d)
            pred[d].append(s)
        self._succ, self._pred = succ, pred

    def successors(self, uid: str) -> List[str]:
        if self._succ is None:
            self._build_adj()
        return self._succ[uid]  # type: ignore[index]

    def predecessors(self, uid: str) -> List[str]:
        if self._pred is None:
            self._build_adj()
        return self._pred[uid]  # type: ignore[index]

    def roots(self) -> List[str]:
        if self._pred is None:
            self._build_adj()
        return [u for u, p in self._pred.items() if not p]  # type: ignore[union-attr]

    def topological_order(self) -> List[str]:
        if self._pred is None:
            self._build_adj()
        indeg = {u: len(p) for u, p in self._pred.items()}  # type: ignore[union-attr]
        stack = [u for u, d in indeg.items() if d == 0]
        order: List[str] = []
        while stack:
            u = stack.pop()
            order.append(u)
            for v in self._succ[u]:  # type: ignore[index]
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != len(self.drops):
            raise GraphValidationError("physical graph contains a cycle")
        return order

    def __len__(self) -> int:
        return len(self.drops)


# ---------------------------------------------------------------------------
# Axes
# ---------------------------------------------------------------------------


@dataclass
class Axis:
    """A surviving instance axis of a leaf construct.

    ``underlying`` is the contributing Scatter/Loop construct name;
    ``size`` the number of surviving indices; ``group`` the number of
    underlying coordinates covered by one surviving index (Gather collapse).
    """

    underlying: str
    underlying_size: int
    size: int
    group: int = 1   # surviving index q covers [q*group, (q+1)*group)

    def to_index(self, coord: int) -> int:
        return coord // self.group

    def to_coords(self, index: int) -> range:
        return range(index * self.group, (index + 1) * self.group)


class AxisResolver:
    """Resolve the surviving axes of every leaf construct.

    Scatter and Loop ancestors *contribute* axes.  Gather and GroupBy
    *transform* the axes of their **incoming flow** (paper Fig. 3 draws them
    as siblings consuming the scattered branches; they may equally be nested
    inside the Scatter — both spellings resolve identically here):

    * Gather(g): innermost incoming axis K -> K/g (fan-in g per instance),
    * GroupBy:   corner turn — drop the outer of the last two incoming axes,
      keep the inner (each instance consumes the full outer range).

    The incoming flow of a container is taken from the edge whose source is
    outside the container subtree and carries the most axes (the most
    specific producer — broadcast side-inputs don't define the flow shape).
    """

    def __init__(self, lg: LogicalGraph) -> None:
        self.lg = lg
        self._leaf_cache: Dict[str, List[Axis]] = {}
        self._cont_cache: Dict[Optional[str], List[Axis]] = {}
        self._resolving: set = set()

    # -- public ----------------------------------------------------------
    def leaf_axes(self, leaf: str) -> List[Axis]:
        if leaf not in self._leaf_cache:
            c = self.lg.constructs[leaf]
            self._leaf_cache[leaf] = list(self._container_axes(c.parent))
        return self._leaf_cache[leaf]

    # -- internals ----------------------------------------------------------
    def _subtree_leaves(self, name: str) -> List[str]:
        out: List[str] = []
        stack = [name]
        while stack:
            n = stack.pop()
            for ch in self.lg.children(n):
                if ch.is_container():
                    stack.append(ch.name)
                else:
                    out.append(ch.name)
        return out

    def _incoming_axes(self, name: str) -> List[Axis]:
        inside = set(self._subtree_leaves(name))
        best: Optional[List[Axis]] = None
        for e in self.lg.edges:
            if e.dst in inside and e.src not in inside:
                axes = self._flow_axes(e.src, name)
                if best is None or len(axes) > len(best):
                    best = axes
        if best is None:
            raise GraphValidationError(
                f"{name!r} has no incoming flow to aggregate")
        return list(best)

    def _flow_axes(self, src: str, container: str) -> List[Axis]:
        """Axes the flow from ``src`` contributes to ``container``.

        A ``loop_exit`` crossing its loop boundary leaves the loop axis
        behind: the loop emits exactly one (final-iteration) value (paper
        §2.3), so a Gather/GroupBy *outside* the loop aggregates over the
        remaining (scatter) axes, not over iterations.  The matching
        coordinate pin happens at unroll time (``exit_pin``).
        """
        axes = list(self.leaf_axes(src))
        c = self.lg.constructs[src]
        if c.kind is Kind.DATA and c.loop_exit:
            loops = [a for a in self.lg.ancestors(src)
                     if a.kind is Kind.LOOP]
            if loops:
                loop_name = loops[-1].name
                anc = {a.name for a in self.lg.ancestors(container)}
                if loop_name not in anc:
                    axes = [a for a in axes if a.underlying != loop_name]
        return axes

    def _container_axes(self, name: Optional[str]) -> List[Axis]:
        if name in self._cont_cache:
            return self._cont_cache[name]
        if name is None:
            return []
        if name in self._resolving:
            raise GraphValidationError(
                f"cyclic aggregation through container {name!r}")
        self._resolving.add(name)
        try:
            c = self.lg.constructs[name]
            if c.kind is Kind.SCATTER:
                axes = self._container_axes(c.parent) + [
                    Axis(c.name, c.num_of_copies, c.num_of_copies)]
            elif c.kind is Kind.LOOP:
                axes = self._container_axes(c.parent) + [
                    Axis(c.name, c.num_of_iterations, c.num_of_iterations)]
            elif c.kind is Kind.GATHER:
                axes = self._incoming_axes(name)
                if not axes:
                    raise GraphValidationError(
                        f"Gather {c.name!r} has no incoming axis to collapse")
                last = axes[-1]
                g = c.num_of_inputs
                if last.size % g:
                    raise GraphValidationError(
                        f"Gather {c.name!r}: fan-in {g} does not divide "
                        f"branch count {last.size}")
                axes[-1] = Axis(last.underlying, last.underlying_size,
                                last.size // g, last.group * g)
            elif c.kind is Kind.GROUPBY:
                axes = self._incoming_axes(name)
                if len(axes) < 2:
                    raise GraphValidationError(
                        f"GroupBy {c.name!r} needs two incoming axes "
                        "(nested Scatters)")
                # corner turn: drop the outer axis, keep the inner
                axes = axes[:-2] + [axes[-1]]
            else:  # pragma: no cover - validated earlier
                raise GraphValidationError(
                    f"{name!r} is not a container")
        finally:
            self._resolving.discard(name)
        self._cont_cache[name] = axes
        return axes


def leaf_axes(lg: LogicalGraph, leaf: str) -> List[Axis]:
    """Compute the surviving axes of a leaf (convenience wrapper)."""
    return AxisResolver(lg).leaf_axes(leaf)


# ---------------------------------------------------------------------------
# Unroll
# ---------------------------------------------------------------------------


def _uid(name: str, idx: Tuple[int, ...]) -> str:
    return name if not idx else f"{name}#{'.'.join(map(str, idx))}"


@dataclass
class _Carry:
    """Loop-carry record for one ``loop_entry`` leaf."""

    exit: str            # the loop_exit construct that carries into it
    loop: str            # the (innermost) Loop construct name
    pos: Optional[int]   # index of the loop axis within the entry's axes


def _carried_loops(lg: LogicalGraph, leaves: Sequence[Construct],
                   axes_of: Dict[str, List[Axis]]) -> Dict[str, "_Carry"]:
    """Resolve and validate loop-carried entry/exit pairs (entry-keyed).

    Shared by the dict oracle and the vectorized path so both reject the
    same ill-formed graphs: duplicate carriers, chained carries (an exit
    that is itself a carried entry — its t>0 instances would alias drops
    that were never created), and entry/exit axis misalignment (the alias
    substitutes surviving indices by axis name, which silently produced
    dangling uids when sizes or Gather groupings differed).
    """
    carries: Dict[str, _Carry] = {}
    for c in leaves:
        if not (c.kind is Kind.DATA and c.loop_exit):
            continue
        entry = c.params.get("carries")
        if not entry or entry not in lg.constructs:
            raise GraphValidationError(
                f"loop_exit {c.name!r} must name its 'carries' entry")
        e = lg.constructs[entry]
        if not e.loop_entry:
            raise GraphValidationError(
                f"{entry!r} is not marked loop_entry")
        loops = [a for a in lg.ancestors(c.name) if a.kind is Kind.LOOP]
        if not loops:
            raise GraphValidationError(
                f"loop_exit {c.name!r} is outside any Loop")
        if entry in carries:
            raise GraphValidationError(
                f"loop_entry {entry!r} carried by both "
                f"{carries[entry].exit!r} and {c.name!r}")
        la = loops[-1].name
        pos = None
        for i, ax in enumerate(axes_of[entry]):
            if ax.underlying == la:
                pos = i
                break
        carries[entry] = _Carry(exit=c.name, loop=la, pos=pos)
    for entry, car in carries.items():
        if car.exit in carries:
            raise GraphValidationError(
                f"chained loop carry: exit {car.exit!r} is itself a "
                "carried loop_entry")
        if car.pos is None:
            continue
        ent_ax = {a.underlying: a for a in axes_of[entry]}
        for a in axes_of[car.exit]:
            b = ent_ax.get(a.underlying)
            if b is None or b.size != a.size or b.group != a.group:
                raise GraphValidationError(
                    f"loop carry {entry!r} <- {car.exit!r}: axis "
                    f"{a.underlying!r} does not align between entry and "
                    "exit instances")
    return carries


def unroll_dict(lg: LogicalGraph) -> PhysicalGraphTemplate:
    """Reference dict-of-DropSpec unroll (the seed path).

    Kept as the semantic oracle for the vectorized CSR path (see
    :func:`unroll`), including loop-carried graphs, whose iteration
    aliasing the array path expresses as index substitution.
    """
    lg.validate()
    pgt = PhysicalGraphTemplate(name=lg.name)

    leaves = lg.leaves()
    resolver = AxisResolver(lg)
    axes_of: Dict[str, List[Axis]] = {
        c.name: resolver.leaf_axes(c.name) for c in leaves}

    carries = _carried_loops(lg, leaves, axes_of)

    # --- instantiate drops ------------------------------------------------------
    # alias: (construct, idx) -> uid actually used
    alias: Dict[Tuple[str, Tuple[int, ...]], str] = {}

    for c in leaves:
        axes = axes_of[c.name]
        car = carries.get(c.name)
        lp = car.pos if car is not None else None
        for idx in itertools.product(*(range(a.size) for a in axes)):
            if lp is not None and idx[lp] > 0:
                # entry at iteration t>0 aliases exit at t-1
                exit_name = car.exit
                prev = list(idx)
                prev[lp] -= 1
                # exit axes may be ordered differently; align by axis name
                e_axes = axes_of[exit_name]
                coordmap = {axes[i].underlying: prev[i]
                            for i in range(len(axes))}
                e_idx = tuple(coordmap[a.underlying] for a in e_axes)
                alias[(c.name, idx)] = _uid(exit_name, e_idx)
                continue
            uid = _uid(c.name, idx)
            if c.kind is Kind.DATA:
                spec = DropSpec(uid=uid, kind="data", construct=c.name,
                                oid=idx, payload_kind=c.payload_kind,
                                data_volume=float(c.data_volume),
                                params=dict(c.params))
            else:
                spec = DropSpec(uid=uid, kind="app", construct=c.name,
                                oid=idx, app=c.app,
                                execution_time=float(c.execution_time),
                                error_threshold=c.error_threshold,
                                params=dict(c.params))
            pgt.add_drop(spec)

    def resolve(name: str, idx: Tuple[int, ...]) -> str:
        return alias.get((name, idx), _uid(name, idx))

    # --- connect edges -----------------------------------------------------------
    seen: set = set()
    for e in lg.edges:
        s_axes, d_axes = axes_of[e.src], axes_of[e.dst]
        d_axis_names = {a.underlying for a in d_axes}
        src_c = lg.constructs[e.src]
        # loop_exit -> consumer outside the loop: only the FINAL iteration's
        # exit drop leaves the loop (the paper's loop produces one result).
        exit_pin: Dict[str, int] = {}
        if src_c.kind is Kind.DATA and src_c.loop_exit:
            loops = [a for a in lg.ancestors(e.src) if a.kind is Kind.LOOP]
            if loops and loops[-1].name not in d_axis_names:
                exit_pin[loops[-1].name] = loops[-1].num_of_iterations - 1
        for d_idx in itertools.product(*(range(a.size) for a in d_axes)):
            if (e.dst, d_idx) in alias:
                # loop-entry instances at t>0 are pure aliases of exit[t-1];
                # nothing is ever produced *into* them directly.
                continue
            # constraints: underlying coords covered by this dst instance
            constraints: Dict[str, Iterable[int]] = {
                a.underlying: a.to_coords(i)
                for a, i in zip(d_axes, d_idx)}
            # enumerate matching src coordinates per src axis
            coord_ranges = []
            for a in s_axes:
                if a.underlying in exit_pin:
                    coords: Iterable[int] = (exit_pin[a.underlying],)
                else:
                    coords = constraints.get(a.underlying,
                                             range(a.underlying_size))
                coord_ranges.append(coords)
            dst_uid = resolve(e.dst, d_idx)
            for combo in itertools.product(*coord_ranges):
                s_idx = tuple(a.to_index(c)
                              for a, c in zip(s_axes, combo))
                src_uid = resolve(e.src, s_idx)
                key = (src_uid, dst_uid, e.streaming)
                if key in seen or src_uid == dst_uid:
                    continue
                seen.add(key)
                pgt.add_edge(src_uid, dst_uid, e.streaming)
    # sanity: the PGT must be a DAG (validated LGs always are, but aliasing
    # of loop-carried drops could surface user errors)
    pgt.topological_order()
    return pgt


# ---------------------------------------------------------------------------
# Vectorized unroll -> CompiledPGT (CSR arrays)
# ---------------------------------------------------------------------------


class _NeedsFallback(Exception):
    """Raised when an edge pattern has no closed-form array expansion."""


def _strides_of(sizes: Sequence[int]) -> List[int]:
    """C-order strides for ``sizes`` (innermost stride 1)."""
    out: List[int] = []
    acc = 1
    for s in reversed(sizes):
        out.append(acc)
        acc *= s
    out.reverse()
    return out


def _expand_edge(s_axes: List[Axis], d_axes: List[Axis],
                 s_base: int, d_base: int,
                 pin: Optional[Dict[str, int]] = None):
    """Vectorized instance-wise edge expansion for one logical edge.

    Mirrors the per-instance join of :func:`unroll_dict`: shared underlying
    axes align (with Gather fan-in/fan-out via the group ratios), an axis
    missing on the dst side is consumed in full, an axis missing on the src
    side broadcasts.  ``pin`` fixes a src axis to one surviving index
    instead of consuming it (the ``exit_pin``: only the final iteration's
    loop_exit leaves the loop).  Returns (src_ids, dst_ids) int64 arrays.
    """
    d_sizes = [a.size for a in d_axes]
    nd = 1
    for s in d_sizes:
        nd *= s
    d_strides = _strides_of(d_sizes)
    dmap = {a.underlying: (a, j) for j, a in enumerate(d_axes)}

    s_strides = _strides_of([a.size for a in s_axes])

    dst = np.arange(nd, dtype=np.int64)
    src_acc = np.zeros(nd, dtype=np.int64)
    for a, s_stride in zip(s_axes, s_strides):
        if pin is not None and a.underlying in pin:
            src_acc = src_acc + pin[a.underlying] * s_stride
            continue
        hit = dmap.get(a.underlying)
        if hit is not None:
            da, j = hit
            cj = (dst // d_strides[j]) % d_sizes[j]
            gd, gs = da.group, a.group
            if gs % gd == 0:
                # dst instance covers one src index (or a sub-block of one)
                src_acc = src_acc + ((cj * gd) // gs) * s_stride
            elif gd % gs == 0:
                k = gd // gs
                m = dst.shape[0]
                dst = np.repeat(dst, k)
                src_acc = np.repeat(src_acc, k) + (
                    np.repeat(cj * k, k) +
                    np.tile(np.arange(k, dtype=np.int64), m)) * s_stride
            else:
                raise _NeedsFallback(
                    f"incommensurate groups on axis {a.underlying!r}")
        else:
            # axis absent on dst: consume the full (deduplicated) src range
            k = a.size
            m = dst.shape[0]
            dst = np.repeat(dst, k)
            src_acc = np.repeat(src_acc, k) + np.tile(
                np.arange(k, dtype=np.int64), m) * s_stride
    return s_base + src_acc, d_base + dst


def compile_unroll(lg: LogicalGraph) -> "CompiledPGT":
    """Unroll a logical graph straight into CSR arrays.

    Drop ids are allocated leaf-by-leaf in ``lg.leaves()`` order with
    C-order instance coordinates — the exact creation order of
    :func:`unroll_dict` — so the two representations are index-compatible
    and scheduling tie-breaks agree.

    Loop-carried graphs are array-native too: a ``loop_entry`` group is
    instantiated with its loop axis collapsed to size 1 (only iteration
    0 exists — t>0 instances are pure aliases of the exit at t-1), and
    every logical edge touching a carried leaf is expanded once over the
    full per-iteration index space, then rewritten in place — the
    block-diagonal per-iteration edge maps fall out of the linear index
    arithmetic:

    * rows *into* an aliased entry at t>0 are dropped (nothing is ever
      produced into an alias),
    * rows *out of* an aliased entry at t>0 substitute the exit's drop id
      at t-1 (axes aligned by underlying construct name),
    * a ``loop_exit`` consumed outside its loop is pinned to the final
      iteration (``exit_pin``) instead of consuming the loop range.

    Edge patterns with no closed-form array expansion (incommensurate
    Gather groups) still fall back to the dict path and are converted.
    """
    from .pgt import KIND_APP, KIND_DATA, CompiledPGT, InstanceGroup

    lg.validate()
    leaves = lg.leaves()

    resolver = AxisResolver(lg)
    axes_of: Dict[str, List[Axis]] = {
        c.name: resolver.leaf_axes(c.name) for c in leaves}
    carries = _carried_loops(lg, leaves, axes_of)

    full_sizes: Dict[str, List[int]] = {
        c.name: [a.size for a in axes_of[c.name]] for c in leaves}
    full_strides: Dict[str, List[int]] = {
        name: _strides_of(s) for name, s in full_sizes.items()}

    groups: List[InstanceGroup] = []
    base_of: Dict[str, int] = {}
    base = 0
    for c in leaves:
        sizes = list(full_sizes[c.name])
        car = carries.get(c.name)
        if car is not None and car.pos is not None:
            # only iteration 0 of a carried entry is materialised
            sizes[car.pos] = 1
        sizes_t = tuple(sizes)
        base_of[c.name] = base
        if c.kind is Kind.DATA:
            groups.append(InstanceGroup(
                name=c.name, base=base, sizes=sizes_t, kind=KIND_DATA,
                app=None, payload_kind=c.payload_kind, execution_time=0.0,
                data_volume=float(c.data_volume), error_threshold=0.0,
                params=dict(c.params)))
        else:
            groups.append(InstanceGroup(
                name=c.name, base=base, sizes=sizes_t, kind=KIND_APP,
                app=c.app, payload_kind="memory",
                execution_time=float(c.execution_time), data_volume=0.0,
                error_threshold=c.error_threshold, params=dict(c.params)))
        base += groups[-1].count
    n = base

    kind = np.empty(n, dtype=np.uint8)
    ex = np.zeros(n, dtype=np.float64)
    vol = np.zeros(n, dtype=np.float64)
    for g in groups:
        kind[g.base:g.base + g.count] = g.kind
        ex[g.base:g.base + g.count] = g.execution_time
        vol[g.base:g.base + g.count] = g.data_volume

    def drop_loop_digit(lin: np.ndarray, name: str, pos: int) -> np.ndarray:
        """Full-axes linear index -> instantiated index of a carried entry
        (remove the loop digit; caller guarantees its coordinate is 0)."""
        st = full_strides[name][pos]
        sz = full_sizes[name][pos]
        return (lin // (st * sz)) * st + lin % st

    # expansion arithmetic runs in int64 (safe for any index products);
    # the *stored* per-edge results are narrowed to int32 whenever the
    # drop count fits — at the 10M tier this halves the peak footprint
    # of the accumulated edge lists
    idx_dtype = np.int32 if n <= np.iinfo(np.int32).max else np.int64
    srcs: List[np.ndarray] = []
    dsts: List[np.ndarray] = []
    strs: List[np.ndarray] = []
    # per-logical-edge expansion emits each (src, dst) pair at most once
    # (unlike the dict path's coordinate walk, the index arithmetic never
    # revisits a pair), so the global dedup pass is only needed when two
    # logical edges could collide (duplicate logical connections) or when
    # iteration aliasing rewrites ids (conservative)
    seen_pairs: set = set()
    need_dedup = bool(carries)
    for e in lg.edges:
        pair = (e.src, e.dst, e.streaming)
        need_dedup = need_dedup or pair in seen_pairs
        seen_pairs.add(pair)
        s_axes, d_axes = axes_of[e.src], axes_of[e.dst]
        # exit_pin: a loop_exit consumed outside its loop contributes only
        # the final iteration (same rule as the dict path)
        pin: Optional[Dict[str, int]] = None
        src_c = lg.constructs[e.src]
        if src_c.kind is Kind.DATA and src_c.loop_exit:
            loops = [a for a in lg.ancestors(e.src) if a.kind is Kind.LOOP]
            d_axis_names = {a.underlying for a in d_axes}
            if loops and loops[-1].name not in d_axis_names:
                last_t = loops[-1].num_of_iterations - 1
                for a in s_axes:
                    if a.underlying == loops[-1].name:
                        pin = {a.underlying: a.to_index(last_t)}
                        break
        try:
            s_lin, d_lin = _expand_edge(s_axes, d_axes, 0, 0, pin)
        except _NeedsFallback:
            return CompiledPGT.from_dict_pgt(unroll_dict(lg))

        # destination side: an aliased entry at t>0 receives nothing
        d_car = carries.get(e.dst)
        if d_car is not None and d_car.pos is not None:
            st = full_strides[e.dst][d_car.pos]
            sz = full_sizes[e.dst][d_car.pos]
            keep = (d_lin // st) % sz == 0
            if not keep.all():
                s_lin, d_lin = s_lin[keep], d_lin[keep]
            d_ids = base_of[e.dst] + drop_loop_digit(
                d_lin, e.dst, d_car.pos)
        else:
            d_ids = base_of[e.dst] + d_lin

        # source side: entry instances at t>0 alias the exit at t-1
        s_car = carries.get(e.src)
        if s_car is not None and s_car.pos is not None:
            st = full_strides[e.src][s_car.pos]
            sz = full_sizes[e.src][s_car.pos]
            t = (s_lin // st) % sz
            s_ids = base_of[e.src] + drop_loop_digit(
                s_lin, e.src, s_car.pos)
            sub = t > 0
            if sub.any():
                ent_axes = axes_of[e.src]
                pos_of = {a.underlying: i for i, a in enumerate(ent_axes)}
                ent_strides = full_strides[e.src]
                s_sub = s_lin[sub]
                ex_lin = np.zeros(s_sub.shape[0], dtype=np.int64)
                for a, stx in zip(axes_of[s_car.exit],
                                  full_strides[s_car.exit]):
                    if a.underlying == s_car.loop:
                        coord = t[sub] - 1
                    else:
                        i = pos_of[a.underlying]
                        coord = (s_sub // ent_strides[i]) \
                            % full_sizes[e.src][i]
                    ex_lin = ex_lin + coord * stx
                s_ids[sub] = base_of[s_car.exit] + ex_lin
        else:
            s_ids = base_of[e.src] + s_lin

        # aliasing can surface degenerate self-edges; the dict path skips
        # them (src_uid == dst_uid)
        if s_car is not None or d_car is not None:
            ok = s_ids != d_ids
            if not ok.all():
                s_ids, d_ids = s_ids[ok], d_ids[ok]

        srcs.append(s_ids.astype(idx_dtype, copy=False))
        dsts.append(d_ids.astype(idx_dtype, copy=False))
        strs.append(np.full(s_ids.shape[0], e.streaming, dtype=bool))

    if srcs:
        # release each chunk list as soon as its concatenation exists:
        # peak memory is one extra copy of one array, not of all three
        esrc = np.concatenate(srcs)
        srcs.clear()
        edst = np.concatenate(dsts)
        dsts.clear()
        estr = np.concatenate(strs)
        strs.clear()
        if need_dedup:
            # dedup (parallel logical edges / alias rewrites), like the
            # dict path's seen-set; canonical order is (src, dst).  The
            # packed key widens explicitly — int32 storage must not make
            # the key arithmetic wrap
            key = (esrc.astype(np.int64) * np.int64(n)
                   + edst) * 2 + estr
            _, first = np.unique(key, return_index=True)
            esrc, edst, estr = esrc[first], edst[first], estr[first]
    else:
        esrc = np.empty(0, dtype=np.int32)
        edst = np.empty(0, dtype=np.int32)
        estr = np.empty(0, dtype=bool)

    levels: Optional[np.ndarray] = None
    if not carries and all(g.count > 0 for g in groups):
        # Loop-free expansions are acyclic by construction (instance edges
        # follow the validated logical DAG), and every instance of a leaf
        # sits at the leaf's own longest-path depth: each instance
        # receives at least one predecessor instance per logical in-edge
        # (shared axes align, missing axes broadcast or consume — never an
        # empty join).  So the Kahn levels collapse to a leaf-graph pass +
        # one repeat, skipping the O(V+E) validation walk entirely.
        leaf_lv = {c.name: 0 for c in leaves}
        indeg = {c.name: 0 for c in leaves}
        succ: Dict[str, List[str]] = {c.name: [] for c in leaves}
        for e in lg.edges:
            succ[e.src].append(e.dst)
            indeg[e.dst] += 1
        queue = [name for name, d in indeg.items() if d == 0]
        while queue:
            u = queue.pop()
            for v in succ[u]:
                if leaf_lv[u] + 1 > leaf_lv[v]:
                    leaf_lv[v] = leaf_lv[u] + 1
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(v)
        # int32 to match the vectorized Kahn's level dtype (level depth
        # is bounded by the drop count, which fits int32 by construction)
        levels = np.repeat(
            np.fromiter((leaf_lv[g.name] for g in groups), dtype=np.int32,
                        count=len(groups)),
            np.fromiter((g.count for g in groups), dtype=np.int64,
                        count=len(groups)))

    return CompiledPGT(lg.name, groups, kind, ex, vol, esrc, edst, estr,
                       levels=levels)


def unroll(lg: LogicalGraph) -> "CompiledPGT":
    """LG -> array-based physical graph template (the default path)."""
    return compile_unroll(lg)
