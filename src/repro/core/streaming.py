"""Ring-buffer chunk table for compiled streaming execution.

The object engine implements the paper's §4/Fig. 10 streaming-consumer
contract directly: ``DataDrop.write`` hands every chunk to each streaming
consumer's ``on_stream_chunk`` as it lands.  The compiled engine has no
drop objects to call back into — this module gives it the equivalent
substrate: one bounded ring of chunk references per *active* streaming
edge, sitting beside ``CompiledSession``'s dense payload table.

An edge is **active** when all of the following hold:

* ``edge_streaming`` is set on it (carried from the logical graph),
* the source is a data drop and the destination an app drop (the only
  combination the object engine honours — see ``unroll``/``_wire``),
* the destination's registered app function is *streaming-marked*
  (``func.streaming`` truthy, e.g. via ``register_app(name,
  streaming=True)``).  A non-marked consumer on a streaming edge simply
  ignores chunks in the object engine, so it stays a plain batch
  dependency here too — that is contract, not degradation.

Every ``CompiledSession._write_idx``/``write`` on a ringed source pushes
the value into each of its rings.  Rings are bounded
(``StreamConfig.ring_capacity``); a full ring blocks the producer
(backpressure) until the consumer drains — the compiled analogue of the
object engine delivering chunks synchronously inside ``write``.

Cursors are *totals*: ``wcur[e]`` chunks pushed, ``rcur[e]`` consumed;
``wcur - rcur`` is the ring occupancy and ``rcur % capacity`` the next
slot to read.  Cursors and buffered chunks live on the session (not the
per-run dispatch lane), so a timed-out ``execute_frontier`` resumes
mid-stream, and recovery can invalidate them explicitly
(:meth:`StreamTable.invalidate` — see ``docs/streaming.md``).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional

import numpy as np

from .pgt import KIND_APP, KIND_DATA, CompiledPGT
from .session import ST_INIT

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .session import CompiledSession


class StreamAbort(Exception):
    """Raised out of a blocked ``push`` when the run is shutting down.

    ``execute_frontier`` re-raises it as a resumable timeout; buffered
    chunks and cursors survive on the session for the next attempt.
    """


@dataclass(frozen=True)
class StreamConfig:
    """Knobs for the compiled streaming lane.

    ``enabled=False`` degrades streaming edges back to batch
    dependencies (the pre-PR-9 behaviour) — the engine then emits the
    ``exec.streaming_edges_degraded`` counter and a one-time warning.
    """
    enabled: bool = True
    ring_capacity: int = 64          # chunks buffered per edge before backpressure
    backpressure_poll_s: float = 0.05  # wait granularity while a ring is full
    shutdown_grace_s: float = 5.0    # consumer-thread join budget at lane shutdown

    def validate(self) -> "StreamConfig":
        if self.ring_capacity < 1:
            raise ValueError("StreamConfig.ring_capacity must be >= 1")
        if self.backpressure_poll_s <= 0:
            raise ValueError("StreamConfig.backpressure_poll_s must be > 0")
        if self.shutdown_grace_s <= 0:
            raise ValueError("StreamConfig.shutdown_grace_s must be > 0")
        return self


def streaming_candidates(pgt: CompiledPGT) -> np.ndarray:
    """Edge ids of data→app streaming edges (before the func-mark filter)."""
    if not pgt.num_edges or not pgt.edge_streaming.any():
        return np.empty(0, dtype=np.int64)
    mask = (pgt.edge_streaming
            & (pgt.kind_arr[pgt.edge_src] == KIND_DATA)
            & (pgt.kind_arr[pgt.edge_dst] == KIND_APP))
    return np.flatnonzero(mask).astype(np.int64)


def active_stream_edges(pgt: CompiledPGT) -> np.ndarray:
    """Candidate edges whose consumer app function is streaming-marked."""
    cand = streaming_candidates(pgt)
    if not cand.size:
        return cand
    from .managers import _APP_REGISTRY  # lazy: avoid import cycle
    keep: List[int] = []
    marked: Dict[int, bool] = {}   # consumer idx -> streaming-marked?
    for e in cand.tolist():
        dst = int(pgt.edge_dst[e])
        ok = marked.get(dst)
        if ok is None:
            name = pgt.app_of(dst)
            func = _APP_REGISTRY.get(name) if name else None
            ok = bool(getattr(func, "streaming", False))
            marked[dst] = ok
        if ok:
            keep.append(e)
    return np.asarray(keep, dtype=np.int64)


class StreamTable:
    """Per-active-streaming-edge chunk rings + cursors.

    One instance per :class:`CompiledSession` (``session.stream``),
    created lazily by ``CompiledSession.enable_streaming``.  All mutable
    state is guarded by one condition variable — chunks are coarse
    (application-level values), so a single lock is not a bottleneck.
    """

    def __init__(self, session: "CompiledSession", edge_ids: np.ndarray,
                 config: StreamConfig) -> None:
        pgt = session.pgt
        self.session = session
        self.config = config.validate()
        self.capacity = int(config.ring_capacity)
        self.edge_ids = edge_ids                       # global edge ids
        self.src = pgt.edge_src[edge_ids].astype(np.int64)
        self.dst = pgt.edge_dst[edge_ids].astype(np.int64)
        self.n_edges = int(edge_ids.shape[0])
        self.chunks = np.full((self.n_edges, self.capacity), None,
                              dtype=object)
        self.wcur = np.zeros(self.n_edges, dtype=np.int64)  # total pushed
        self.rcur = np.zeros(self.n_edges, dtype=np.int64)  # total consumed
        # fast membership masks over all drops
        n = pgt.num_drops
        self.is_src = np.zeros(n, dtype=bool)
        self.is_src[self.src] = True
        self.is_consumer = np.zeros(n, dtype=bool)
        self.is_consumer[self.dst] = True
        # drop idx -> local edge ids
        self.rings_of_src: Dict[int, List[int]] = {}
        self.edges_of_dst: Dict[int, List[int]] = {}
        for k in range(self.n_edges):
            self.rings_of_src.setdefault(int(self.src[k]), []).append(k)
            self.edges_of_dst.setdefault(int(self.dst[k]), []).append(k)
        # stream vs batch in-degree split (diagnostic + tests)
        self.stream_in_deg = np.zeros(n, dtype=np.int64)
        np.add.at(self.stream_in_deg, self.dst, 1)
        # coordination
        self.cond = threading.Condition()
        self._attached = False        # a dispatch lane is consuming
        self._shutdown = False
        # stale-lane write fence: bumped by fence() when a lane shuts down
        # with consumer threads still alive; refs minted under an older
        # generation refuse to mutate rings/payloads afterwards
        self.generation = 0
        self.deadline = float("inf")  # run deadline, set by attach()
        self.on_first_chunk: Optional[Callable[[int], None]] = None
        self.on_backpressure: Optional[Callable[[int, int, float], None]] = None
        # persistent per-consumer app refs (cross-chunk state; survives
        # resumable timeouts, reset by recovery invalidation)
        self.app_refs: Dict[int, Any] = {}
        # stats
        self.backpressure_waits = 0
        self.chunks_pushed = 0
        self.chunks_dropped = 0       # unconsumed pushes with no lane attached

    # ------------------------------------------------------------------
    # construction helper
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, session: "CompiledSession",
              config: Optional[StreamConfig] = None
              ) -> Optional["StreamTable"]:
        """Build the table for a session, or None if no active edges.

        Seeds already written through ``session.write`` *before* the
        table existed (direct ``execute_frontier`` callers) are
        reconciled: each untouched ring whose source payload is present
        receives that payload as its first chunk.
        """
        edge_ids = active_stream_edges(session.pgt)
        if not edge_ids.size:
            return None
        tbl = cls(session, edge_ids, config or StreamConfig())
        for k in range(tbl.n_edges):
            s_idx = int(tbl.src[k])
            if session.payload_present[s_idx] and tbl.wcur[k] == 0:
                tbl.chunks[k, 0] = session.payloads[s_idx]
                tbl.wcur[k] = 1
                tbl.chunks_pushed += 1
        return tbl

    # ------------------------------------------------------------------
    # producer side
    # ------------------------------------------------------------------
    def push(self, src_idx: int, value: Any) -> None:
        """Append ``value`` to every ring fed by drop ``src_idx``.

        Blocks (backpressure) while a ring is full and a dispatch lane
        is attached; without a lane the oldest chunk is overwritten and
        counted in ``chunks_dropped`` (nothing is consuming — blocking
        would deadlock the caller).
        """
        rings = self.rings_of_src.get(int(src_idx))
        if not rings:
            return
        state = self.session.drop_state
        activate: List[int] = []
        with self.cond:
            for k in rings:
                dst = int(self.dst[k])
                if state[dst] != ST_INIT:
                    continue       # consumer already terminal: discard
                waited = 0.0
                while self.wcur[k] - self.rcur[k] >= self.capacity:
                    if self._shutdown or not self._attached:
                        if self._shutdown:
                            raise StreamAbort(
                                f"stream push to ring {k} aborted")
                        # no consumer running: keep the newest chunks
                        self.rcur[k] += 1
                        self.chunks_dropped += 1
                        break
                    if time.monotonic() > self.deadline:
                        raise StreamAbort(
                            f"stream push to ring {k} blocked past the "
                            "run deadline (backpressure)")
                    self.backpressure_waits += 1
                    cb = self.on_backpressure
                    if cb is not None:
                        cb(int(src_idx), dst, waited)
                    self.cond.wait(self.config.backpressure_poll_s)
                    waited += self.config.backpressure_poll_s
                    if state[dst] != ST_INIT:
                        break
                if state[dst] != ST_INIT:
                    continue
                first = self.wcur[k] == self.rcur[k]
                self.chunks[k, int(self.wcur[k]) % self.capacity] = value
                self.wcur[k] += 1
                self.chunks_pushed += 1
                if first and dst not in activate:
                    activate.append(dst)
            self.cond.notify_all()
        cb = self.on_first_chunk
        if cb is not None:
            for dst in activate:
                cb(dst)

    # ------------------------------------------------------------------
    # consumer side (called by the dispatch lane, under ``self.cond``)
    # ------------------------------------------------------------------
    def pop_ready_locked(self, dst_idx: int):
        """Pop one buffered chunk for a consumer: ``(local_edge, seq,
        value)`` or None.  Caller must hold ``self.cond``."""
        for k in self.edges_of_dst.get(int(dst_idx), ()):
            if self.rcur[k] < self.wcur[k]:
                slot = int(self.rcur[k]) % self.capacity
                value = self.chunks[k, slot]
                self.chunks[k, slot] = None
                seq = int(self.rcur[k])
                self.rcur[k] += 1
                self.cond.notify_all()   # wake producers blocked on full
                return k, seq, value
        return None

    def pending_chunks(self, dst_idx: int) -> int:
        with self.cond:
            return int(sum(self.wcur[k] - self.rcur[k]
                           for k in self.edges_of_dst.get(int(dst_idx), ())))

    # ------------------------------------------------------------------
    # lane lifecycle
    # ------------------------------------------------------------------
    def attach(self, on_first_chunk: Callable[[int], None],
               on_backpressure: Optional[Callable] = None,
               deadline: float = float("inf")) -> None:
        with self.cond:
            self._attached = True
            self._shutdown = False
            self.on_first_chunk = on_first_chunk
            self.on_backpressure = on_backpressure
            self.deadline = deadline

    def detach(self) -> None:
        with self.cond:
            self._attached = False
            self.on_first_chunk = None
            self.on_backpressure = None
            self.cond.notify_all()

    def shutdown(self) -> None:
        """Abort blocked producers (resumable timeout / interrupt)."""
        with self.cond:
            self._shutdown = True
            self.cond.notify_all()

    def fence(self) -> int:
        """Invalidate every outstanding lane reference: refs minted under an
        older generation raise :class:`StreamAbort` on write and their
        consumer loops exit at the next wakeup.  Called when a lane shuts
        down with wedged consumer threads still alive, so a thread that
        eventually unwedges cannot mutate rings or payloads behind a
        resumable reopen."""
        with self.cond:
            self.generation += 1
            self.cond.notify_all()
            return self.generation

    # ------------------------------------------------------------------
    # recovery integration
    # ------------------------------------------------------------------
    def invalidate(self, lost_mask: np.ndarray) -> int:
        """Reset rings touched by a recovery pass.

        ``lost_mask`` is a boolean mask over drops that were reset to
        INIT (lost data + producers being re-run).  For every ring whose
        source will re-stream or whose consumer restarts, cursors drop
        back to zero and buffered chunks are cleared; the consumer's
        persistent app ref (cross-chunk state) is discarded so the
        re-delivered stream is consumed from scratch.  Rings whose
        consumer already completed are left alone — late re-pushes are
        discarded by ``push``'s terminal-state check.

        Root sources (no producer to re-run) that keep their payload are
        re-seeded with it as a single chunk, mirroring the object
        engine's one ``write`` per root seed.

        Returns the number of rings reset.
        """
        state = self.session.drop_state
        reset = 0
        with self.cond:
            for k in range(self.n_edges):
                s_idx, dst = int(self.src[k]), int(self.dst[k])
                if not (lost_mask[s_idx] or lost_mask[dst]):
                    continue
                if state[dst] != ST_INIT:
                    continue       # completed consumer: keep its result
                self.wcur[k] = 0
                self.rcur[k] = 0
                self.chunks[k, :] = None
                self.app_refs.pop(dst, None)
                reset += 1
                if (not lost_mask[s_idx]
                        and self.session.payload_present[s_idx]):
                    # durable source that is NOT re-running: re-seed
                    self.chunks[k, 0] = self.session.payloads[s_idx]
                    self.wcur[k] = 1
            self.cond.notify_all()
        return reset

    def expand_lost(self, lost: np.ndarray) -> np.ndarray:
        """Grow a recovery lost-set so partially-consumed streams replay.

        A consumer that is being reset (``dst`` in ``lost``) with
        consumed chunks (``rcur > 0``) cannot replay them from the ring
        — they are gone.  The only way to re-deliver the same chunk
        sequence is to re-run the producing apps, so the source data
        drop and its COMPLETED producers join the lost set (transitively
        pulling any of *their* inputs that are no longer readable, same
        durability rule as ``CompiledFaultManager.lost_set``).  Root
        sources (no producers) are instead re-seeded by
        :meth:`invalidate`.
        """
        if not self.n_edges:
            return lost
        s = self.session
        pgt = s.pgt
        from .session import PK_FILE, ST_COMPLETED
        in_indptr, in_cols = pgt.in_csr()
        lost_set = set(int(i) for i in lost.tolist())
        frontier: List[int] = []

        def _add(idx: int) -> None:
            if idx not in lost_set:
                lost_set.add(idx)
                frontier.append(idx)

        with self.cond:
            for k in range(self.n_edges):
                s_idx, dst = int(self.src[k]), int(self.dst[k])
                if dst in lost_set and int(self.rcur[k]) > 0:
                    preds = in_cols[in_indptr[s_idx]:in_indptr[s_idx + 1]]
                    if preds.size:
                        _add(s_idx)
        while frontier:
            idx = frontier.pop()
            if pgt.kind_arr[idx] == KIND_DATA:
                # data being re-written: re-run its completed producers
                preds = in_cols[in_indptr[idx]:in_indptr[idx + 1]]
                for p in preds.tolist():
                    if s.drop_state[p] == ST_COMPLETED:
                        _add(int(p))
            else:
                # app being re-run: its inputs must be readable
                preds = in_cols[in_indptr[idx]:in_indptr[idx + 1]]
                for p in preds.tolist():
                    if (s.drop_state[p] == ST_COMPLETED
                            and not s.payload_present[p]
                            and s.payload_kind[p] != PK_FILE):
                        _add(int(p))
        if len(lost_set) == lost.shape[0]:
            return lost
        return np.fromiter(sorted(lost_set), dtype=np.int64,
                           count=len(lost_set))
