"""Compiled-graph templates — translate once, run per-observation.

The paper's managers are *resident* services: a pipeline shape is
translated once and executed for every observation (MUSER runs the same
graph per correlator frame; "SKA shakes hands with Summit" reuses one
translated graph across the whole campaign).  Our ``Pipeline`` was
one-shot — every ``run()`` paid full translate+map — and translate
dominates every tier below 100k drops.

This module amortises that cost:

* :func:`structural_hash` — a canonical digest of a logical graph plus
  the translate/mapping parameters that shape the physical graph
  (algorithm, dop, deadline, cluster layout).  Two structurally
  identical requests hash identically regardless of construction order.
* :class:`GraphTemplate` — a translated **and mapped**
  :class:`~repro.core.pgt.CompiledPGT` captured together with its
  precomputed per-node drop-id slices and warmed CSR caches.
  :meth:`GraphTemplate.materialize` re-instantiates a runnable
  :class:`~repro.core.session.CompiledSession` in O(drops): the CSR
  topology, weights, partition labels, node placement and node slices
  are *shared copy-on-write* (they are never mutated by execution);
  only the per-session state — the int8 state array, the dense payload
  table, the error map — is freshly allocated.
* :class:`TemplateCache` — a bounded LRU of templates keyed by
  structural hash (what :class:`repro.core.manager.EngineManager`
  serves sessions from).

The division of labour mirrors ``node_manager.py``'s
``getTemplates``/``materializeTemplate`` in the upstream DALiuGE
daemon hierarchy.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence

import numpy as np

from . import partition as partition_mod
from .logical import LogicalGraph
from .mapping import NodeInfo, map_partitions
from .pgt import CompiledPGT
from .session import CompiledSession
from .unroll import unroll


def translate_lg(lg: LogicalGraph, algorithm: str = "min_time",
                 dop: int = 8,
                 deadline: Optional[float] = None) -> CompiledPGT:
    """Stage 4 (translate): unroll + partition one logical graph.

    The single implementation behind ``Pipeline.translate`` and
    ``GraphTemplate.build`` — both one-shot runs and cached templates
    produce byte-identical physical graphs for the same inputs."""
    pgt = unroll(lg)
    if algorithm == "min_time":
        partition_mod.min_time(pgt, dop=dop)
    elif algorithm == "min_res":
        dl = deadline if deadline is not None else float("inf")
        partition_mod.min_res(pgt, deadline=dl, dop=dop)
    elif algorithm == "none":
        if isinstance(pgt, CompiledPGT):
            pgt.partition = np.arange(len(pgt), dtype=np.int32)
        else:
            for i, spec in enumerate(pgt.drops.values()):
                spec.partition = i
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return pgt


def structural_hash(lg: LogicalGraph, *, algorithm: str = "min_time",
                    dop: int = 8, deadline: Optional[float] = None,
                    nodes: Sequence[NodeInfo] = (),
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Canonical digest of (logical graph, translate params, cluster).

    Everything that shapes the translated+mapped physical graph goes
    into the key: the constructs and edges (sorted, so construction
    order does not matter), the partitioning algorithm and its
    parameters, and the node layout the mapper placed onto.  Values
    that are not JSON-serialisable fall back to ``repr`` — stable
    within a process, which is the cache's lifetime.
    """
    doc = lg.to_json()
    canonical = {
        "name": doc["name"],
        "constructs": sorted(doc["constructs"],
                             key=lambda c: c.get("name", "")),
        "edges": sorted((e["src"], e["dst"], bool(e.get("streaming")))
                        for e in doc["edges"]),
        "translate": {"algorithm": algorithm, "dop": dop,
                      "deadline": deadline},
        "nodes": [(n.name, n.island) for n in nodes],
        "extra": extra or {},
    }
    blob = json.dumps(canonical, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


class GraphTemplate:
    """One translated+mapped physical graph, ready to instantiate.

    Immutable after :meth:`build` — every array it holds is shared by
    all sessions materialised from it, so nothing here may be written
    by execution (``tests/test_serving.py`` proves sessions of one
    template stay fully isolated).
    """

    __slots__ = ("key", "name", "pgt", "node_slices", "cross_node_edges",
                 "translate_s", "map_s", "built_at", "hits",
                 "materializations")

    def __init__(self, key: str, pgt: CompiledPGT,
                 node_slices: Dict[str, np.ndarray],
                 cross_node_edges: int,
                 translate_s: float, map_s: float) -> None:
        self.key = key
        self.name = pgt.name
        self.pgt = pgt
        self.node_slices = node_slices
        self.cross_node_edges = cross_node_edges
        self.translate_s = translate_s
        self.map_s = map_s
        self.built_at = time.monotonic()
        self.hits = 0                 # cache lookups served by this entry
        self.materializations = 0     # sessions instantiated from it

    @property
    def num_drops(self) -> int:
        return self.pgt.num_drops

    @classmethod
    def build(cls, lg: LogicalGraph, nodes: Sequence[NodeInfo], *,
              algorithm: str = "min_time", dop: int = 8,
              deadline: Optional[float] = None,
              key: Optional[str] = None) -> "GraphTemplate":
        """Translate + map one logical graph into a reusable template.

        Pays the full cold path once — unroll, partition, partition->node
        mapping, per-node slice argsort — and warms every lazy CSR cache
        so concurrent sessions never race to build them."""
        if key is None:
            key = structural_hash(lg, algorithm=algorithm, dop=dop,
                                  deadline=deadline, nodes=nodes)
        t0 = time.monotonic()
        pgt = translate_lg(lg, algorithm=algorithm, dop=dop,
                           deadline=deadline)
        translate_s = time.monotonic() - t0
        tm = time.monotonic()
        map_partitions(pgt, nodes)
        map_s = time.monotonic() - tm
        # the deploy argsort, paid once per shape instead of per session
        from .managers import _node_slices
        node_slices = _node_slices(pgt)
        if pgt.num_edges:
            cross = int((pgt.node_ids[pgt.edge_src]
                         != pgt.node_ids[pgt.edge_dst]).sum())
        else:
            cross = 0
        # warm the lazy caches shared by every future session: two
        # concurrent first-touch builds would compute identical arrays
        # (benign), but would still duplicate the work
        pgt.out_csr_with_eid()
        pgt.in_csr_with_eid()
        pgt.in_degrees()
        pgt.group_idx_arr()
        return cls(key, pgt, node_slices, cross, translate_s, map_s)

    def materialize(self, session_id: str, master: Any = None,
                    bus: Any = None) -> CompiledSession:
        """Instantiate a fresh runnable session in O(drops).

        No re-translate, no re-map, no argsort: the session shares the
        template's CSR topology, placement and node slices, and only
        allocates what execution mutates — the state array, the payload
        table and the error map.  With ``master`` the session is
        registered on the Node Drop Managers exactly as
        ``deploy_compiled`` would (same slices, no per-session sort).
        """
        session = CompiledSession(session_id, self.pgt, bus=bus)
        session.deploy()
        if master is not None:
            nms = master.node_managers()
            for name, indices in self.node_slices.items():
                nms[name].register_compiled(session, indices)
            master._sessions[session_id] = session
        else:
            session.node_slices = dict(self.node_slices)
        session.cross_node_edges = self.cross_node_edges
        self.materializations += 1
        return session


class TemplateCache:
    """Bounded LRU of :class:`GraphTemplate` keyed by structural hash.

    Thread-safe for lookup/insert; building a missing template happens
    *outside* the lock (translate can take seconds at the 1M tier and
    must not block cache hits for other shapes), so two threads racing
    on the same cold key may both build — the first insert wins and the
    duplicate is discarded, which is wasteful but correct.
    """

    def __init__(self, max_templates: int = 8,
                 metrics: Optional[Any] = None) -> None:
        if max_templates < 1:
            raise ValueError("max_templates must be >= 1")
        self.max_templates = max_templates
        self._entries: "OrderedDict[str, GraphTemplate]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional MetricsRegistry mirror of the counters above (the
        # ints stay authoritative — stats() reads them either way)
        if metrics is not None:
            self._m_hits = metrics.counter("templates.hits")
            self._m_misses = metrics.counter("templates.misses")
            self._m_evictions = metrics.counter("templates.evictions")
        else:
            self._m_hits = self._m_misses = self._m_evictions = None

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def lookup(self, key: str) -> Optional[GraphTemplate]:
        with self._lock:
            tpl = self._entries.get(key)
            if tpl is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                tpl.hits += 1
                if self._m_hits is not None:
                    self._m_hits.inc()
            return tpl

    def insert(self, tpl: GraphTemplate) -> GraphTemplate:
        """Insert (first writer wins); returns the cached instance."""
        with self._lock:
            cached = self._entries.get(key := tpl.key)
            if cached is not None:
                # lost the build race: serve the incumbent
                self._entries.move_to_end(key)
                return cached
            self._entries[key] = tpl
            self.misses += 1
            if self._m_misses is not None:
                self._m_misses.inc()
            while len(self._entries) > self.max_templates:
                self._entries.popitem(last=False)
                self.evictions += 1
                if self._m_evictions is not None:
                    self._m_evictions.inc()
            return tpl

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"templates": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}
