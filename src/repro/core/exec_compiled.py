"""Compiled execution — the deploy+execute fast path over ``CompiledPGT``.

PR 1 lifted the *translate* stage onto flat numpy arrays (``CompiledPGT``);
this module lifts stages 5–6 the same way, completing the paper's
data-activated regime for *executable* graphs: no per-drop Python ``Drop``
objects, no thread-pool futures, no per-event callback chains.

* **Deploy** (``MasterDropManager.deploy_compiled``) validates placement
  and hands each Node Drop Manager an *index slice* of the CSR arrays —
  one ``argsort`` over ``node_ids`` instead of one ``_instantiate`` call
  per DropSpec.

* **Execute** (:func:`execute_frontier`) is a frontier scheduler: drop
  state lives in a single int8 array on the :class:`CompiledSession`,
  readiness in a ``pending_inputs`` in-degree counter array.  Execution
  proceeds wave-by-wave — complete all ready data drops, fire all runnable
  apps of the frontier (one batched dispatch per node, with vectorised
  fast paths for ``noop``/``identity``/``sleep`` and the app registry
  invoked only for apps with real Python work), then advance every
  successor's in-degree with one ``np.add.at`` per wave.

Semantics contract (the object engine in ``drop.py``/``session.py`` is
the oracle; ``tests/test_exec_equiv.py`` enforces it):

* a data drop COMPLETES when all producers resolved and none errored,
  ERRORs as soon as any producer errored;
* an app runs when all inputs are resolved and the errored fraction is
  within its error threshold ``t`` (paper Fig. 7), consuming only the
  COMPLETED inputs sorted by ``(oid, uid)``; otherwise it ERRORs;
* payload values are write-once at wave granularity; memory payloads live
  in the session's dense table.

Streaming edges run chunk-granular (PR 9): writes to a ringed source
data drop land in per-edge chunk rings (``core/streaming.py``) and a
dedicated consumer thread per streaming consumer processes them while
the producer is still running — the paper's §4/Fig. 10 data-activated
contract, previously object-engine-only.  Pure-batch subgraphs are
untouched: the lane only exists when the graph has *active* streaming
edges, and only stream-producing apps leave the vectorised fast paths.

Deliberate divergences (documented in ``docs/execute.md``): waves run
single-threaded (``sleep`` apps in one wave cost ``max(seconds)``, i.e.
ideal parallelism), and no per-drop *success* events are published on
the hot path — that is the point.  Observability is opt-in and
array-native instead: per-drop timeline stamps, chunk spans and
wave-granular metrics via ``core/telemetry.py`` (``TelemetryConfig``),
while session lifecycle and drop *failures* do surface on the session
``EventBus`` (see ``docs/observability.md``).
"""
from __future__ import annotations

import threading
import time
import traceback
import warnings
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from .managers import _APP_REGISTRY, BUILTIN_FAST_APPS, get_app
from .pgt import (KIND_APP, KIND_DATA, CompiledPGT, csr_gather,
                  csr_gather_with_counts)
from .procpool import WorkerLost
from .session import (PK_FILE, PK_NULL, ST_COMPLETED, ST_ERROR, ST_INIT,
                      CompiledDropRef, CompiledSession)
from .streaming import StreamAbort, StreamConfig, StreamTable

# per-drop dispatch codes (apps only; data drops never dispatch)
CODE_PYTHON = 0      # registry app with real Python work
CODE_NONE = 1        # no app function: complete, write nothing
CODE_NOOP = 2        # write None to all outputs
CODE_IDENTITY = 3    # forward the single input (or the input list)
CODE_SLEEP = 4       # sleep, then write None to all outputs

_FAST_CODE = {"noop": CODE_NOOP, "identity": CODE_IDENTITY,
              "sleep": CODE_SLEEP}


def _dispatch_code(app: Optional[str]) -> int:
    """Dispatch code for one app name.  A fast code applies only while
    the registry entry still IS the built-in implementation — users may
    re-register 'noop'/'identity'/'sleep', and the object oracle would
    run their function, so the compiled engine must too."""
    if not app:
        return CODE_NONE
    code = _FAST_CODE.get(app, CODE_PYTHON)
    if code != CODE_PYTHON and \
            _APP_REGISTRY.get(app) is not BUILTIN_FAST_APPS.get(app):
        return CODE_PYTHON
    return code


class _WaveTimeout(Exception):
    """Raised mid-wave when the execution deadline expires.

    Safe to abort anywhere: the scheduler derives its counters from the
    state array on entry, so a partially-processed wave (some drops
    terminal, some still INIT) resumes exactly where it stopped."""


class ExecHooks:
    """Scheduler extension points — the one hooks protocol shared by
    ``Pipeline.execute``, :func:`execute_frontier` and
    ``launch/serve.py`` (consumed by :mod:`repro.core.resilience` too).

    * ``on_wave(session, completed, total)`` — called at the top of every
      wave, when all drop state is consistent (everything terminal or
      INIT, no in-flight work).  May raise to abort the run; the state
      array stays resumable.
    * ``python_runner(ctx, ids)`` — replaces the sequential registry-app
      loop for the wave's Python apps (``ctx`` is the ``_Dispatch``;
      ``ids`` are node-sorted and may span nodes).  Must leave every id
      terminal, or raise ``_WaveTimeout`` past ``ctx.deadline``.
    * ``on_stream_chunk(session, src_uid, dst_uid, seq)`` — one call per
      chunk *consumed* by a streaming consumer (compiled lane) or per
      chunk *delivered* by ``DataDrop.write`` (object engine).  Runs on
      the consumer's thread; an exception marks that consumer ERROR.
    * ``on_backpressure(session, src_uid, dst_uid, waited_s)`` — a
      producer is blocked on a full chunk ring (compiled lane only; the
      object engine delivers chunks synchronously inside ``write`` and
      never queues them).
    """

    __slots__ = ("on_wave", "python_runner", "on_stream_chunk",
                 "on_backpressure")

    def __init__(self, on_wave=None, python_runner=None,
                 on_stream_chunk=None, on_backpressure=None) -> None:
        self.on_wave = on_wave
        self.python_runner = python_runner
        self.on_stream_chunk = on_stream_chunk
        self.on_backpressure = on_backpressure


# shared with pgt.py (kept as module aliases — the scheduler's hot loop
# and the resilience closure gather CSR rows the same way)
_gather = csr_gather
_gather_with_counts = csr_gather_with_counts


def node_batches(pgt: CompiledPGT, ids: np.ndarray) -> List[np.ndarray]:
    """Split drop ids into per-placement-node batches (stable order).

    Shared by the default threaded wave dispatch below and the
    resilience runner's speculative dispatch (same argsort-and-split)."""
    nodes = pgt.node_ids[ids]
    order = np.argsort(nodes, kind="stable")
    run = ids[order]
    bounds = np.flatnonzero(np.diff(nodes[order])) + 1
    return np.split(run, bounds)


# ---------------------------------------------------------------------------
# Registry-app shims — what an app function sees instead of real Drops
# ---------------------------------------------------------------------------


class _DataRef(CompiledDropRef):
    """Duck-types the slice of ``DataDrop`` that app functions consume:
    ``read()``/``write()`` against the session's dense payload table
    (uid/node/read come from the shared row view)."""

    __slots__ = ()

    @property
    def meta(self) -> Dict[str, Any]:
        return _drop_meta(self.s.pgt, self.idx)

    def write(self, value: Any) -> None:
        self.s._write_idx(self.idx, value)

    def nbytes(self) -> int:
        v = self.s.payloads[self.idx]
        return int(getattr(v, "nbytes", 0))


class _FencedDataRef(_DataRef):
    """Output ref handed to streaming chunk handlers: writes are fenced by
    the ``StreamTable`` generation, so a wedged consumer thread from a
    shut-down lane that eventually unwedges cannot mutate payloads/rings
    behind a resumable reopen."""

    __slots__ = ("tbl", "gen")

    def __init__(self, session: CompiledSession, idx: int,
                 tbl: StreamTable, gen: int) -> None:
        super().__init__(session, idx)
        self.tbl = tbl
        self.gen = gen

    def write(self, value: Any) -> None:
        if self.tbl.generation != self.gen:
            raise StreamAbort(
                f"stale stream-lane write fenced (lane generation {self.gen}, "
                f"table at {self.tbl.generation})")
        super().write(value)


class _AppRef(CompiledDropRef):
    """Duck-types the slice of ``AppDrop`` an app function consumes
    (``app.meta`` with oid/construct/params, ``app.uid``, ``app.node``,
    and ``app.scratch`` — the per-drop scratch dict streaming handlers
    use for cross-chunk accumulation, mirroring ``AppDrop.scratch``)."""

    __slots__ = ("_meta", "scratch")

    def __init__(self, session: CompiledSession, idx: int) -> None:
        super().__init__(session, idx)
        self._meta: Optional[Dict[str, Any]] = None
        self.scratch: Dict[str, Any] = {}

    @property
    def meta(self) -> Dict[str, Any]:
        if self._meta is None:
            m = _drop_meta(self.s.pgt, self.idx)
            m["execution_time"] = float(self.s.pgt.exec_arr[self.idx])
            self._meta = m
        return self._meta


class _StreamAppRef(_AppRef):
    """The persistent app ref a streaming consumer sees across chunks.

    Stored in ``StreamTable.app_refs`` so ``app.scratch`` survives
    resumable timeouts; recovery invalidation discards it (the consumer
    re-accumulates from the re-delivered stream).  ``outputs`` lets a
    chunk handler emit downstream chunks incrementally."""

    __slots__ = ("outputs", "gen")

    def __init__(self, session: CompiledSession, idx: int,
                 outputs: List[_DataRef], gen: int = 0) -> None:
        super().__init__(session, idx)
        self.outputs = outputs
        self.gen = gen


def _drop_meta(pgt: CompiledPGT, idx: int) -> Dict[str, Any]:
    # same layout NodeDropManager._instantiate builds for real Drops
    return {"oid": pgt.oid_of(idx), "construct": pgt.group_of(idx).name,
            **pgt.params_of(idx)}


# ---------------------------------------------------------------------------
# Batched per-node dispatch
# ---------------------------------------------------------------------------


class _Dispatch:
    """Precomputed dispatch tables + the per-wave app execution logic."""

    def __init__(self, session: CompiledSession,
                 hooks: Optional[ExecHooks] = None,
                 executors: Optional[Dict[str, Any]] = None,
                 stream_table: Optional[StreamTable] = None) -> None:
        pgt = session.pgt
        self.s = session
        self.pgt = pgt
        self.hooks = hooks
        # node name -> thread pool: Python-app waves spanning several
        # nodes overlap (one worker task per node batch); None/empty
        # keeps the sequential in-thread dispatch
        self.executors = executors or {}
        # process-backed executors (ProcExecutor: has run_batch) get their
        # Python-app batches shipped to the node's worker process
        self.proc_nodes = {name for name, ex in self.executors.items()
                           if hasattr(ex, "run_batch")}
        n = pgt.num_drops
        self.out_indptr, self.out_cols, _ = pgt.out_csr_with_eid()
        self.in_indptr, self.in_cols, in_eid = pgt.in_csr_with_eid()
        self.in_deg = pgt.in_degrees()
        # oracle contract: streaming inputs live in app.streaming_inputs,
        # never in app.inputs, so they are invisible to the batch input
        # list (AppDrop.execute builds ok_inputs from self.inputs only).
        # This holds whether or not a chunk lane is active: in degraded
        # (batch) mode the edge is still a dependency, just not a readable
        # batch input.  in_stream is aligned with in_cols; stream_cons
        # marks apps with >= 1 streaming in-edge so fast paths skip them.
        self.in_stream: Optional[np.ndarray] = None
        self.stream_cons: Optional[np.ndarray] = None
        if pgt.has_streaming_edges():
            sm = pgt.edge_streaming & \
                (pgt.kind_arr[pgt.edge_src] == KIND_DATA) & \
                (pgt.kind_arr[pgt.edge_dst] == KIND_APP)
            if sm.any():
                self.in_stream = sm[in_eid]
                cons = np.zeros(n, dtype=bool)
                cons[pgt.edge_dst[sm]] = True
                self.stream_cons = cons
        gidx = pgt.group_idx_arr()
        if len(pgt.groups):
            gcode = np.fromiter(
                (_dispatch_code(g.app) for g in pgt.groups),
                dtype=np.int8, count=len(pgt.groups))
            self.app_code = gcode[gidx]
            gthr = np.fromiter((g.error_threshold for g in pgt.groups),
                               dtype=np.float64, count=len(pgt.groups))
            self.thr = pgt.err_arr if pgt.err_arr is not None \
                else gthr[gidx]
        else:
            self.app_code = np.zeros(n, dtype=np.int8)
            self.thr = np.zeros(n, dtype=np.float64)
        # the vectorised noop/identity fast paths write only the payload
        # table; graphs with file-backed payloads take the per-app path so
        # spill files appear exactly as the object engine would write them
        self.fast_ok = not bool((session.payload_kind == PK_FILE).any())
        # apps writing into ringed stream sources must take the registry
        # path: every chunk has to go through _write_idx (the vectorised
        # fast paths bulk-write the payload table and would skip rings)
        self.stream = stream_table
        if stream_table is not None and stream_table.n_edges:
            prod = np.zeros(n, dtype=bool)
            feeds_ring = stream_table.is_src[pgt.edge_dst]
            if feeds_ring.any():
                prod[pgt.edge_src[feeds_ring]] = True
            self.stream_prod: Optional[np.ndarray] = prod
        else:
            self.stream_prod = None
        self.deadline = float("inf")   # set per run by execute_frontier
        # telemetry (off unless the session carries a Timeline/registry):
        # fast paths stamp whole batches, _run_python stamps per app
        self.tl = session.timeline
        self.wave = 0                  # current wave index, for stamps
        self.m_batches = None          # Counter("exec.dispatch_batches")

    # -- wave entry ---------------------------------------------------------
    def dispatch(self, run_ids: np.ndarray) -> None:
        """Fire all runnable apps of one wave.

        Sleep apps are handled wave-wide first (the whole wave runs
        concurrently in the object engine, so one ``max(seconds)`` sleep
        models it — NOT one per node); everything else goes out as one
        batched dispatch per node.  Registry (Python) apps of the whole
        wave are dispatched together, node-sorted, so a resilience runner
        can overlap per-node batches and speculate across nodes."""
        if run_ids.size == 0:
            return
        codes = self.codes_of(run_ids)
        sleep_ids = run_ids[codes == CODE_SLEEP]
        if sleep_ids.size:
            self._sleep_batch(sleep_ids)
            run_ids = run_ids[codes != CODE_SLEEP]
            if run_ids.size == 0:
                return
        nodes = self.pgt.node_ids[run_ids]
        order = np.lexsort((run_ids, nodes))
        run = run_ids[order]
        bounds = np.flatnonzero(np.diff(nodes[order])) + 1
        batches = np.split(run, bounds)
        if self.m_batches is not None:
            self.m_batches.inc(len(batches))
        python_parts = [self._dispatch_batch(batch) for batch in batches]
        self._run_python_batch(np.concatenate(python_parts))

    def codes_of(self, ids: np.ndarray) -> np.ndarray:
        """Dispatch codes for a batch, with stream producers forced onto
        the registry path (their writes must push chunks one by one)."""
        codes = self.app_code[ids]
        if self.stream_prod is not None:
            codes = np.where(self.stream_prod[ids] & (codes != CODE_NONE),
                             CODE_PYTHON, codes)
        if self.stream_cons is not None:
            # apps with streaming in-edges must take the registry path:
            # the vectorised fast paths read the raw in-CSR and would
            # treat the streaming edge as a readable batch input
            codes = np.where(self.stream_cons[ids] & (codes != CODE_NONE),
                             CODE_PYTHON, codes)
        return codes

    def _stamp_batch(self, ids: np.ndarray, t0: float) -> None:
        """Timeline-stamp a terminal fast-path batch (end = now)."""
        if self.tl is not None and ids.size:
            self.tl.stamp_batch(ids, t0, time.monotonic(), self.wave)

    def _dispatch_batch(self, batch: np.ndarray) -> np.ndarray:
        """Run the fast-path apps of one per-node batch; return the
        registry (Python) apps for the wave-wide dispatch."""
        codes = self.codes_of(batch)
        t0 = time.monotonic() if self.tl is not None else 0.0
        none_ids = batch[codes == CODE_NONE]
        if none_ids.size:
            self.s.drop_state[none_ids] = ST_COMPLETED
            self._stamp_batch(none_ids, t0)
        noop_ids = batch[codes == CODE_NOOP]
        if noop_ids.size:
            self._write_none_outputs(noop_ids)
        ident_ids = batch[codes == CODE_IDENTITY]
        if ident_ids.size:
            self._identity_batch(ident_ids)
        return batch[codes == CODE_PYTHON]

    def _run_python_batch(self, ids: np.ndarray) -> None:
        """Registry-path dispatch, deadline-checked per app (a wide wave
        of Python apps must not overshoot the execution timeout).

        A resilience ``python_runner`` hook takes over the whole per-node
        batch (threaded dispatch, retries, straggler speculation);
        otherwise, with node executors available, per-node batches run
        concurrently on the node thread pools — the object engine's wave
        parallelism, which the plain sequential loop used to serialise."""
        if ids.size and self.hooks is not None \
                and self.hooks.python_runner is not None:
            self.hooks.python_runner(self, ids)
            return
        if self.executors and ids.size and (self.proc_nodes or ids.size > 1):
            self._run_python_threaded(ids)
            return
        self._run_python_seq(ids)

    def _run_python_seq(self, ids: np.ndarray) -> None:
        for i in ids.tolist():
            if time.monotonic() > self.deadline:
                raise _WaveTimeout
            self._run_python(i)

    def _run_python_threaded(self, ids: np.ndarray) -> None:
        """Overlap the wave's per-node batches on the node thread pools.

        Every app still lands in a terminal state exactly as on the
        sequential path (``_run_python`` catches app exceptions); batches
        on nodes without an executor (or unplaced drops) run inline.  A
        deadline overrun in any batch surfaces as one ``_WaveTimeout``
        after all batches stopped — the state array stays resumable."""
        batches = node_batches(self.pgt, ids)
        if len(batches) <= 1 and not self.proc_nodes:
            self._run_python_seq(ids)
            return
        node_ids = self.pgt.node_ids
        names = self.pgt.node_names
        futures = []
        inline: List[np.ndarray] = []
        for batch in batches:
            nid = int(node_ids[int(batch[0])])
            ex = self.executors.get(names[nid]) if nid >= 0 else None
            if ex is None:
                inline.append(batch)
            elif hasattr(ex, "run_batch"):
                # process-backed node: ship the batch to the worker, except
                # stream producers/consumers — their chunk-granular writes
                # must land in the parent's rings as they happen
                keep = np.ones(batch.size, dtype=bool)
                if self.stream_prod is not None:
                    keep &= ~self.stream_prod[batch]
                if self.stream_cons is not None:
                    keep &= ~self.stream_cons[batch]
                local = batch[~keep]
                remote = batch[keep]
                if local.size:
                    inline.append(local)
                if remote.size:
                    futures.append(
                        ex.submit(self._run_proc_batch, remote, ex, nid))
            else:
                futures.append(ex.submit(self._run_python_seq, batch))
        timed_out = False
        lost: List[str] = []
        for batch in inline:
            try:
                self._run_python_seq(batch)
            except _WaveTimeout:
                timed_out = True     # keep draining; workers stop on the
                #                      same deadline within one app each
        for f in futures:
            try:
                f.result()
            except _WaveTimeout:
                timed_out = True
            except WorkerLost as wl:
                lost.extend(wl.nodes)
        if lost:
            # takes precedence over a deadline overrun: drops on the lost
            # node(s) can never finish without recovery
            raise WorkerLost(sorted(set(lost)))
        if timed_out:
            raise _WaveTimeout

    # -- fast paths ---------------------------------------------------------
    def _write_none_outputs(self, ids: np.ndarray,
                            t0: Optional[float] = None) -> None:
        """noop semantics: write ``None`` to every output, complete.
        ``t0`` carries a caller's earlier start stamp (the sleep batch
        starts *before* it sleeps)."""
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        s = self.s
        start = (time.monotonic() if t0 is None else t0) \
            if self.tl is not None else 0.0
        dsts = _gather(self.out_indptr, self.out_cols, ids)
        if dsts.size:
            s.payloads[dsts] = None
            s.payload_present[dsts] = True
        s.drop_state[ids] = ST_COMPLETED
        self._stamp_batch(ids, start)

    def _sleep_batch(self, ids: np.ndarray) -> None:
        """One wave of sleeps runs concurrently in the object engine; the
        compiled engine models ideal parallelism: sleep the max once.

        On the registry fallback (file payloads present) each app sleeps
        individually inside ``_run_python`` — no batched sleep on top."""
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        t0 = time.monotonic() if self.tl is not None else None
        secs = max(self._sleep_seconds(i) for i in ids.tolist())
        if secs > 0:
            remaining = self.deadline - time.monotonic()
            if secs > remaining:
                time.sleep(max(remaining, 0.0))
                raise _WaveTimeout
            time.sleep(secs)
        self._write_none_outputs(ids, t0)

    def _sleep_seconds(self, i: int) -> float:
        ov = self.pgt._params_override.get(i)
        if ov is not None and "seconds" in ov:
            return float(ov["seconds"])
        return float(self.pgt.group_of(i).params.get("seconds", 0.001))

    def _identity_batch(self, ids: np.ndarray) -> None:
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        t0 = time.monotonic() if self.tl is not None else 0.0
        s = self.s
        single = ids[self.in_deg[ids] == 1]
        # multi-input: general list semantics via the registry path
        self._run_python_batch(ids[self.in_deg[ids] != 1])
        if single.size == 0:
            return
        preds = self.in_cols[self.in_indptr[single]]
        completed = s.drop_state[preds] == ST_COMPLETED
        readable = s.payload_present[preds] | \
            (s.payload_kind[preds] == PK_NULL)
        hard = completed & ~readable     # absent payload -> PayloadError
        self._run_python_batch(single[hard])
        fast = ~hard
        vals = np.empty(single.size, dtype=object)
        easy = completed & readable
        vals[easy] = s.payloads[preds[easy]]
        # errored input tolerated by t: ok_inputs == [] -> identity of []
        for k in np.flatnonzero(~completed).tolist():
            vals[k] = []
        fast_ids = single[fast]
        dsts, cnt = _gather_with_counts(self.out_indptr, self.out_cols,
                                        fast_ids)
        if dsts.size:
            s.payloads[dsts] = np.repeat(vals[fast], cnt)
            s.payload_present[dsts] = True
        s.drop_state[fast_ids] = ST_COMPLETED
        self._stamp_batch(fast_ids, t0)

    # -- general path: the app registry -------------------------------------
    def app_call(self, i: int, out_ref=_DataRef):
        """(func, in_refs, out_refs, app_ref) for registry app ``i``.

        ``func`` is None for no-app drops (complete without work).  The
        resilience runner passes a staging ``out_ref`` so speculative
        duplicates buffer writes instead of touching the payload table."""
        s = self.s
        pgt = self.pgt
        name = pgt.app_of(i)
        func = get_app(name) if name else None
        if func is None:
            return None, [], [], None
        lo, hi = self.in_indptr[i], self.in_indptr[i + 1]
        ins = self.in_cols[lo:hi]
        if self.in_stream is not None:
            # streaming in-edges are dependencies, not batch inputs
            # (the oracle keeps them in app.streaming_inputs)
            ins = ins[~self.in_stream[lo:hi]]
        ok = ins[s.drop_state[ins] == ST_COMPLETED]
        refs = [_DataRef(s, int(j)) for j in ok]
        # deterministic input order (the object engine sorts by
        # (oid, uid) regardless of wiring order)
        refs.sort(key=lambda r: (pgt.oid_of(r.idx), pgt.uid_of(r.idx)))
        outs = [out_ref(s, int(j)) for j in
                self.out_cols[self.out_indptr[i]:self.out_indptr[i + 1]]]
        return func, refs, outs, _AppRef(s, int(i))

    def _run_python(self, i: int) -> None:
        s = self.s
        t0 = time.monotonic() if self.tl is not None else 0.0
        try:
            func, refs, outs, app = self.app_call(i)
            if func is not None:
                if getattr(func, "streaming", False):
                    # streaming-marked func on the batch path (streaming
                    # disabled, or wired batch-only): chunks were never
                    # delivered; run only the finalizer, as the object
                    # oracle's AppDrop.execute does
                    fin = getattr(func, "finish", None)
                    if fin is not None:
                        fin(refs, outs, app)
                else:
                    func(refs, outs, app)
            s.drop_state[i] = ST_COMPLETED
        except _WaveTimeout:
            raise
        except StreamAbort:
            # a chunk push aborted (run shutting down / past deadline):
            # resumable, not an app failure
            raise _WaveTimeout
        except Exception:  # noqa: BLE001 - app failures become drop ERRORs
            s.drop_state[i] = ST_ERROR
            s.record_error(i, traceback.format_exc(limit=8))
        if self.tl is not None:
            self.tl.stamp(int(i), t0, time.monotonic(), self.wave)

    # -- process-backed dispatch (ProcExecutor mailbox) ----------------------
    def proc_spec(self, i: int) -> Dict[str, Any]:
        """Self-contained work order for registry app ``i``: the function
        object (pickled by reference — the worker resolves it via module
        re-import), pre-read COMPLETED inputs in oracle order, and output
        drop indices.  A parent-side failure (unknown app) is returned as
        ``{"parent_tb": ...}`` so the caller errors the drop locally."""
        s, pgt = self.s, self.pgt
        i = int(i)
        spec: Dict[str, Any] = {"idx": i, "uid": pgt.uid_of(i)}
        try:
            name = pgt.app_of(i)
            func = get_app(name) if name else None
        except Exception:  # noqa: BLE001 - registry miss -> drop ERROR
            spec["parent_tb"] = traceback.format_exc(limit=8)
            return spec
        spec["func"] = func
        if func is None:
            return spec
        meta = _drop_meta(pgt, i)
        meta["execution_time"] = float(pgt.exec_arr[i])
        spec["meta"] = meta
        lo, hi = self.in_indptr[i], self.in_indptr[i + 1]
        ins = self.in_cols[lo:hi]
        if self.in_stream is not None:
            ins = ins[~self.in_stream[lo:hi]]
        ok = ins[s.drop_state[ins] == ST_COMPLETED]
        order = sorted((int(j) for j in ok),
                       key=lambda j: (pgt.oid_of(j), pgt.uid_of(j)))
        inputs = []
        for j in order:
            value, err = None, None
            try:
                value = s._read_idx(j)
            except Exception as exc:  # noqa: BLE001 - re-raised at read()
                err = f"{type(exc).__name__}: {exc}"
            inputs.append((pgt.uid_of(j), _drop_meta(pgt, j), value, err))
        spec["inputs"] = inputs
        spec["outputs"] = [
            (int(j), pgt.uid_of(int(j)), _drop_meta(pgt, int(j)))
            for j in self.out_cols[self.out_indptr[i]:self.out_indptr[i + 1]]]
        return spec

    def _run_proc_batch(self, batch: np.ndarray, ex: Any, nid: int) -> None:
        """Ship one node batch to its worker process and apply the reply.

        Raises :class:`WorkerLost` if the worker dies (caller drains all
        batches first) and ``_WaveTimeout`` on budget exhaustion — drops
        the worker never reached stay INIT, so the run is resumable."""
        s = self.s
        specs: List[Dict[str, Any]] = []
        for i in batch.tolist():
            spec = self.proc_spec(i)
            tb = spec.get("parent_tb")
            if tb is not None:
                t = time.monotonic()
                s.drop_state[i] = ST_ERROR
                s.record_error(i, tb)
                if self.tl is not None:
                    self.tl.stamp(int(i), t, t, self.wave, node=nid)
            else:
                specs.append(spec)
        budget = self.deadline - time.monotonic()
        if budget <= 0:
            raise _WaveTimeout
        results = ex.run_batch(specs, budget)
        if self._apply_proc_results(results, nid):
            raise _WaveTimeout

    def _apply_proc_results(self, results: List[Dict[str, Any]],
                            nid: int) -> bool:
        """Replay worker results into the session; True if any timed out.

        Concurrent calls (one per node thread) touch row-disjoint state,
        the same contract as the threaded in-process dispatch.  Worker
        stamps are CLOCK_MONOTONIC, comparable across Linux processes, so
        they merge into the Timeline unadjusted."""
        s = self.s
        timed_out = False
        for r in results:
            i = int(r["idx"])
            status = r["status"]
            if status == "timeout":
                timed_out = True
                continue
            if status == "ok":
                try:
                    for j, v in r["writes"]:
                        s._write_idx(int(j), v)
                    s.drop_state[i] = ST_COMPLETED
                except Exception:  # noqa: BLE001 - replay failure -> ERROR
                    s.drop_state[i] = ST_ERROR
                    s.record_error(i, traceback.format_exc(limit=8))
            else:
                s.drop_state[i] = ST_ERROR
                s.record_error(i, r["tb"])
            if self.tl is not None:
                t1 = r.get("t1", time.monotonic())
                self.tl.stamp(i, r.get("t0", t1), t1, self.wave, node=nid)
        return timed_out


# ---------------------------------------------------------------------------
# The streaming dispatch lane
# ---------------------------------------------------------------------------


_degrade_warned = False   # one-time process warning (reset in tests)


def _warn_degraded(n_edges: int) -> None:
    global _degrade_warned
    if not _degrade_warned:
        _degrade_warned = True
        warnings.warn(
            f"{n_edges} active streaming edge(s) degraded to batch "
            "dependencies (streaming disabled for this run); consumers "
            "will not receive chunks — see docs/streaming.md",
            RuntimeWarning, stacklevel=3)


class _StreamLane:
    """Per-run chunk-consumption lane over a session's ``StreamTable``.

    One daemon thread per *activated* streaming consumer: the first
    chunk landing in any of a consumer's rings spawns its thread, which
    drains chunks (``func(value, app)`` per chunk) concurrently with the
    wave loop still dispatching producers — that concurrency IS the
    producer/consumer overlap the streaming tier measures.  When the
    scheduler later finds the consumer frontier-ready (all inputs
    terminal — the oracle's resolution condition), ``finalize_wave``
    waits for the thread to drain and run the func's optional
    ``finish(ok_inputs, outputs, app)``, leaving the drop terminal.

    Run-scoped state only (threads, resolved set, first-activity
    stamps); cursors, buffered chunks and per-consumer ``app.scratch``
    live on the :class:`StreamTable` and survive resumable timeouts.
    """

    def __init__(self, ctx: _Dispatch, table: StreamTable) -> None:
        self.ctx = ctx
        self.s = ctx.s
        self.table = table
        # lane generation: if shutdown leaves a consumer thread alive it
        # fences the table, and refs/loops of this generation go inert
        self.gen = table.generation
        self.join_grace = float(table.config.shutdown_grace_s)
        self.hooks = ctx.hooks
        self.threads: Dict[int, threading.Thread] = {}
        self.done: Dict[int, threading.Event] = {}
        self.resolved: set = set()
        self.first_t0: Dict[int, float] = {}
        self.errored: Dict[int, str] = {}
        self.chunks_processed = 0
        self.m_chunks = None          # Counter("exec.stream_chunks")
        self._shutdown = False

    # -- lifecycle ----------------------------------------------------------
    def attach(self) -> None:
        tbl = self.table
        on_bp = None
        hk = self.hooks
        if hk is not None and hk.on_backpressure is not None:
            user_bp = hk.on_backpressure
            pgt, s = self.ctx.pgt, self.s

            def on_bp(src: int, dst: int, waited: float) -> None:
                user_bp(s, pgt.uid_of(src), pgt.uid_of(dst), waited)

        tbl.attach(self.activate, on_bp, deadline=self.ctx.deadline)
        # resume: consumers with chunks buffered from a previous attempt
        # start draining immediately
        with tbl.cond:
            pend = [d for d, ks in tbl.edges_of_dst.items()
                    if self.s.drop_state[d] == ST_INIT
                    and any(tbl.rcur[k] < tbl.wcur[k] for k in ks)]
        for d in pend:
            self.activate(d)

    def shutdown(self) -> None:
        """Stop consumer threads; buffered chunks + cursors persist.

        Joins get one shared ``shutdown_grace_s`` budget.  A consumer
        wedged in its chunk handler survives the join — previously it
        leaked silently and could still mutate rings/payloads after a
        resumable reopen.  Now every survivor is reported by consumer uid
        and the table generation is fenced: the survivor's refs raise
        ``StreamAbort`` on write and its loop exits at the next wakeup."""
        tbl = self.table
        tbl.shutdown()            # unblocks producers stuck in push
        with tbl.cond:
            self._shutdown = True
            tbl.cond.notify_all()
        deadline = time.monotonic() + self.join_grace
        survivors: List[int] = []
        for c, t in list(self.threads.items()):
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                survivors.append(c)
        if survivors:
            uids = [self.ctx.pgt.uid_of(c) for c in survivors]
            warnings.warn(
                f"{len(survivors)} stream consumer thread(s) still alive "
                f"{self.join_grace:.1f}s after lane shutdown "
                f"(consumers: {uids}); fencing stale-lane writes",
                RuntimeWarning, stacklevel=2)
            tbl.fence()
        tbl.detach()

    # -- activation (first chunk) -------------------------------------------
    def activate(self, c: int) -> None:
        c = int(c)
        with self.table.cond:
            if self._shutdown or c in self.threads:
                return
            t = threading.Thread(target=self._consume, args=(c,),
                                 name=f"stream-consume-{c}", daemon=True)
            self.threads[c] = t
        t.start()

    def app_ref(self, c: int) -> _StreamAppRef:
        ref = self.table.app_refs.get(c)
        if ref is None or ref.gen != self.gen:
            ctx = self.ctx
            outs = [_FencedDataRef(self.s, int(j), self.table, self.gen)
                    for j in
                    ctx.out_cols[ctx.out_indptr[c]:ctx.out_indptr[c + 1]]]
            fresh = _StreamAppRef(self.s, c, outs, gen=self.gen)
            if ref is not None:
                # cross-chunk accumulation survives lane turnover; only
                # the fenced output refs are re-minted per generation
                fresh.scratch = ref.scratch
            self.table.app_refs[c] = fresh
            ref = fresh
        return ref

    # -- the consumer thread ------------------------------------------------
    def _consume(self, c: int) -> None:
        tbl = self.table
        s = self.s
        pgt = self.ctx.pgt
        name = pgt.app_of(c)
        func = _APP_REGISTRY.get(name) if name else None
        ref = self.app_ref(c)
        hk = self.hooks
        on_chunk = hk.on_stream_chunk if hk is not None else None
        while True:
            with tbl.cond:
                if self._shutdown or tbl.generation != self.gen:
                    return        # lane shut down / fenced as stale
                if s.drop_state[c] != ST_INIT:
                    return        # gate-failed or cancelled externally
                item = tbl.pop_ready_locked(c)
                if item is None:
                    if c in self.resolved:
                        break     # drained + resolved -> finalize
                    tbl.cond.wait(0.05)
                    continue
            k, seq, value = item
            t0 = time.monotonic()
            self.first_t0.setdefault(c, t0)
            if c not in self.errored:
                try:
                    if func is not None:
                        func(value, ref)
                    if on_chunk is not None:
                        on_chunk(s, pgt.uid_of(int(tbl.src[k])),
                                 pgt.uid_of(c), seq)
                except StreamAbort:
                    return        # downstream push aborted: resumable
                except Exception:  # noqa: BLE001 - consumer becomes ERROR
                    # keep draining (discarding) so producers unblock
                    self.errored[c] = traceback.format_exc(limit=8)
            t1 = time.monotonic()
            self.chunks_processed += 1
            if self.m_chunks is not None:
                self.m_chunks.inc()
            tl = self.ctx.tl
            if tl is not None:
                tl.stamp_chunk(c, seq, t0, t1)
        self._finalize(c)

    def _finalize(self, c: int) -> None:
        if self.table.generation != self.gen:
            return                # fenced: a fresh lane owns this consumer
        s = self.s
        ctx = self.ctx
        t0 = self.first_t0.get(c, time.monotonic())
        tb = self.errored.get(c)
        if tb is not None:
            s.drop_state[c] = ST_ERROR
            s.record_error(c, tb)
        else:
            try:
                func, refs, outs, _ = ctx.app_call(c)
                fin = getattr(func, "finish", None) \
                    if func is not None else None
                if fin is not None:
                    fin(refs, outs, self.app_ref(c))
                s.drop_state[c] = ST_COMPLETED
            except Exception:  # noqa: BLE001 - finaliser failure -> ERROR
                s.drop_state[c] = ST_ERROR
                s.record_error(c, traceback.format_exc(limit=8))
        if ctx.tl is not None:
            ctx.tl.stamp(c, t0, time.monotonic(), ctx.wave)
        ev = self.done.get(c)
        if ev is not None:
            ev.set()

    # -- scheduler side -----------------------------------------------------
    def finalize_wave(self, ids: np.ndarray) -> None:
        """Resolve frontier-ready streaming consumers and wait for each
        to finalize (drain + ``finish``).  Raises ``_WaveTimeout`` past
        the run deadline — consumed state persists on the table."""
        wait_for = []
        spawn = []
        with self.table.cond:
            for c in ids.tolist():
                c = int(c)
                ev = self.done.get(c)
                if ev is None:
                    ev = self.done[c] = threading.Event()
                self.resolved.add(c)
                if c not in self.threads:
                    # producers are terminal: chunk counts are final
                    if any(self.table.rcur[k] < self.table.wcur[k]
                           for k in self.table.edges_of_dst.get(c, ())):
                        spawn.append(c)
                    else:
                        wait_for.append((c, ev, True))   # finalize inline
                        continue
                wait_for.append((c, ev, False))
            self.table.cond.notify_all()
        for c in spawn:
            self.activate(c)
        for c, ev, inline in wait_for:
            if inline:
                self._finalize(c)
                continue
            while not ev.wait(0.1):
                if time.monotonic() > self.ctx.deadline:
                    raise _WaveTimeout

    def cancel(self, ids: np.ndarray) -> None:
        """Wake threads of consumers the threshold gate just ERRORed;
        they observe the terminal state and exit without finalizing."""
        with self.table.cond:
            for c in ids.tolist():
                self.resolved.add(int(c))
            self.table.cond.notify_all()


# ---------------------------------------------------------------------------
# The frontier scheduler
# ---------------------------------------------------------------------------


def execute_frontier(session: CompiledSession,
                     timeout: float = 60.0,
                     hooks: Optional[ExecHooks] = None,
                     executors: Optional[Dict[str, Any]] = None,
                     stream: Union[StreamConfig, bool, None] = None) -> bool:
    """Run a deployed :class:`CompiledSession` to completion, wave-by-wave.

    ``executors`` (node name -> thread pool, e.g.
    ``MasterDropManager.node_executors()``) lets registry-app waves that
    span several nodes overlap; without it Python apps run sequentially
    in the calling thread.  Vectorised fast paths are unaffected.

    ``stream`` controls the chunk-granular streaming lane: ``None``
    (default) auto-enables it when the graph has active streaming edges,
    a :class:`StreamConfig` enables it with explicit knobs, ``False``
    degrades streaming edges to batch dependencies — emitting the
    ``exec.streaming_edges_degraded`` counter and a one-time warning.

    Resume-aware: ``pending_inputs`` and the errored-predecessor counters
    are derived from the *current* state array, so a session restored from
    a checkpoint (or pre-seeded with completed drops) continues from
    exactly where it left off.  The same property makes ``hooks.on_wave``
    free to abort the run (fault injection) — recovery resets state rows
    and simply calls ``execute_frontier`` again.

    Returns True when every drop reached a terminal state within
    ``timeout``; on timeout the session is left RUNNING and False is
    returned (the engine reports state "TIMEOUT").
    """
    pgt = session.pgt
    n = pgt.num_drops
    session.start()
    if n == 0:
        if hooks is not None and hooks.on_wave is not None:
            hooks.on_wave(session, 0, 0)
        session.finish()
        return True
    state = session.drop_state
    kind = pgt.kind_arr

    # streaming lane setup — must precede _Dispatch so stream-producing
    # apps are routed off the vectorised fast paths.  Pure-batch graphs
    # take the `not has_streaming_edges()` exit and allocate nothing.
    stream_cfg: Optional[StreamConfig] = None
    if isinstance(stream, StreamConfig):
        stream_cfg = stream
        stream = stream.enabled
    enabled = stream is None or bool(stream)
    tbl: Optional[StreamTable] = None
    if pgt.has_streaming_edges():
        if enabled:
            tbl = session.enable_streaming(stream_cfg)
        else:
            from .streaming import active_stream_edges
            n_active = session.stream.n_edges if session.stream is not None \
                else int(active_stream_edges(pgt).size)
            if n_active:
                _warn_degraded(n_active)
                if session.metrics is not None:
                    session.metrics.counter(
                        "exec.streaming_edges_degraded").inc(n_active)

    in_deg = pgt.in_degrees()
    ctx = _Dispatch(session, hooks, executors, stream_table=tbl)
    out_indptr, out_cols = ctx.out_indptr, ctx.out_cols

    # readiness counters, derived from current state (fresh start or resume)
    src_state = state[pgt.edge_src]
    terminal_edges = src_state != ST_INIT
    # int32 counters throughout (in_degrees is int32): at the 10M tier
    # the three per-drop counter arrays stay at 40MB each, not 80MB
    if terminal_edges.any():
        pending = in_deg - np.bincount(
            pgt.edge_dst[terminal_edges], minlength=n).astype(np.int32)
        err_preds = np.bincount(
            pgt.edge_dst[src_state == ST_ERROR],
            minlength=n).astype(np.int32)
    else:
        pending = in_deg.copy()
        err_preds = np.zeros(n, dtype=np.int32)

    frontier = np.flatnonzero((pending == 0) & (state == ST_INIT))
    remaining = int((state == ST_INIT).sum())
    deadline = time.monotonic() + timeout
    ctx.deadline = deadline   # enforced mid-wave too (wide Python waves)

    lane: Optional[_StreamLane] = None
    if tbl is not None and tbl.n_edges:
        lane = _StreamLane(ctx, tbl)
        bp_start = tbl.backpressure_waits

    # telemetry: wave/frontier metrics at wave granularity, per-drop
    # stamps in the dispatch fast paths.  Resumed sessions keep wave
    # numbers monotone by continuing past the highest stamped index.
    tl = session.timeline
    reg = session.metrics
    if reg is not None:
        from .telemetry import FRONTIER_BUCKETS
        m_waves = reg.counter("exec.waves")
        m_front = reg.histogram("exec.frontier_size", FRONTIER_BUCKETS)
        ctx.m_batches = reg.counter("exec.dispatch_batches")
    wave_no = tl.max_wave + 1 if tl is not None else 0

    if lane is not None:
        if reg is not None:
            lane.m_chunks = reg.counter("exec.stream_chunks")
        lane.attach()

    try:
        while frontier.size:
            if time.monotonic() > deadline:
                return False
            if hooks is not None and hooks.on_wave is not None:
                # state is consistent here (all drops terminal or INIT);
                # any exception raised by the hook leaves the session
                # resumable (the finally below parks the stream lane too)
                hooks.on_wave(session, n - remaining, n)
            ctx.wave = wave_no
            if reg is not None:
                m_waves.inc()
                m_front.observe(float(frontier.size))
            wave_t0 = time.monotonic() if tl is not None else 0.0

            # 1. complete all ready data drops of the wave (vectorised)
            data_ids = frontier[kind[frontier] == KIND_DATA]
            if data_ids.size:
                bad = err_preds[data_ids] > 0
                state[data_ids[~bad]] = ST_COMPLETED
                errs = data_ids[bad]
                if errs.size:
                    state[errs] = ST_ERROR
                    for i in errs.tolist():
                        session.record_error(i, "producer errored")
                if tl is not None:
                    tl.stamp_batch(data_ids, wave_t0, time.monotonic(),
                                   wave_no)

            # 2. fire all runnable apps (threshold gate, then per-node
            # batches; frontier-ready streaming consumers go to the lane)
            app_ids = frontier[kind[frontier] != KIND_DATA]
            if app_ids.size:
                n_in = in_deg[app_ids]
                nerr = err_preds[app_ids]
                frac_err = nerr / np.maximum(n_in, 1)
                fail = frac_err > ctx.thr[app_ids]
                failed = app_ids[fail]
                if failed.size:
                    state[failed] = ST_ERROR
                    for i, ne, ni in zip(failed.tolist(),
                                         nerr[fail].tolist(),
                                         n_in[fail].tolist()):
                        session.record_error(i, (
                            f"{ne}/{ni} inputs errored > "
                            f"t={float(ctx.thr[i])}"))
                    if tl is not None:
                        tl.stamp_batch(failed, wave_t0, time.monotonic(),
                                       wave_no)
                run_ids = app_ids[~fail]
                stream_ready = None
                if lane is not None:
                    is_sc = tbl.is_consumer[run_ids]
                    if is_sc.any():
                        stream_ready = run_ids[is_sc]
                        run_ids = run_ids[~is_sc]
                    if failed.size:
                        fsc = tbl.is_consumer[failed]
                        if fsc.any():
                            lane.cancel(failed[fsc])
                try:
                    ctx.dispatch(run_ids)
                    if stream_ready is not None:
                        # batch apps of the wave have fired; now wait for
                        # the wave's streaming consumers to drain+finish
                        lane.finalize_wave(stream_ready)
                except _WaveTimeout:
                    # mid-wave abort: skip the in-degree advance;
                    # counters are re-derived from the state on resume
                    return False

            remaining -= int(frontier.size)
            wave_no += 1

            # 3. advance in-degrees: one np.add.at per wave
            succ = _gather(out_indptr, out_cols, frontier)
            if succ.size:
                np.add.at(pending, succ, -1)
                errored = frontier[state[frontier] == ST_ERROR]
                if errored.size:
                    np.add.at(err_preds,
                              _gather(out_indptr, out_cols, errored), 1)
                cand = np.unique(succ)
                frontier = cand[(pending[cand] == 0)
                                & (state[cand] == ST_INIT)]
            else:
                frontier = np.empty(0, dtype=np.int64)
    finally:
        if lane is not None:
            lane.shutdown()
            if reg is not None:
                delta = tbl.backpressure_waits - bp_start
                if delta:
                    reg.counter(
                        "exec.stream_backpressure_waits").inc(delta)

    if remaining == 0:
        if hooks is not None and hooks.on_wave is not None:
            # final wave report: progress consumers observe completed ==
            # total exactly once.  A hook exception here still leaves the
            # session resumable (all drops terminal, finish() not called);
            # the resilient loop's fired-fraction set prevents re-firing.
            hooks.on_wave(session, n, n)
        if reg is not None:
            # count_nonzero on the int8 state is ~10x cheaper than a
            # bincount (which upcasts to intp first)
            n_err = int(np.count_nonzero(state == ST_ERROR))
            reg.counter("exec.drops_completed").inc(n - n_err)
            reg.counter("exec.drops_errored").inc(n_err)
        session.finish()
        return True
    return False
