"""Compiled execution — the deploy+execute fast path over ``CompiledPGT``.

PR 1 lifted the *translate* stage onto flat numpy arrays (``CompiledPGT``);
this module lifts stages 5–6 the same way, completing the paper's
data-activated regime for *executable* graphs: no per-drop Python ``Drop``
objects, no thread-pool futures, no per-event callback chains.

* **Deploy** (``MasterDropManager.deploy_compiled``) validates placement
  and hands each Node Drop Manager an *index slice* of the CSR arrays —
  one ``argsort`` over ``node_ids`` instead of one ``_instantiate`` call
  per DropSpec.

* **Execute** (:func:`execute_frontier`) is a frontier scheduler: drop
  state lives in a single int8 array on the :class:`CompiledSession`,
  readiness in a ``pending_inputs`` in-degree counter array.  Execution
  proceeds wave-by-wave — complete all ready data drops, fire all runnable
  apps of the frontier (one batched dispatch per node, with vectorised
  fast paths for ``noop``/``identity``/``sleep`` and the app registry
  invoked only for apps with real Python work), then advance every
  successor's in-degree with one ``np.add.at`` per wave.

Semantics contract (the object engine in ``drop.py``/``session.py`` is
the oracle; ``tests/test_exec_equiv.py`` enforces it):

* a data drop COMPLETES when all producers resolved and none errored,
  ERRORs as soon as any producer errored;
* an app runs when all inputs are resolved and the errored fraction is
  within its error threshold ``t`` (paper Fig. 7), consuming only the
  COMPLETED inputs sorted by ``(oid, uid)``; otherwise it ERRORs;
* payload values are write-once at wave granularity; memory payloads live
  in the session's dense table.

Deliberate divergences (documented in ``docs/execute.md``): waves run
single-threaded (``sleep`` apps in one wave cost ``max(seconds)``, i.e.
ideal parallelism), streaming edges are treated as batch dependencies,
and no per-drop *success* events are published on the hot path — that is
the point.  Observability is opt-in and array-native instead: per-drop
timeline stamps and wave-granular metrics via ``core/telemetry.py``
(``TelemetryConfig``), while session lifecycle and drop *failures* do
surface on the session ``EventBus`` (see ``docs/observability.md``).
"""
from __future__ import annotations

import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .managers import _APP_REGISTRY, BUILTIN_FAST_APPS, get_app
from .pgt import (KIND_DATA, CompiledPGT, csr_gather,
                  csr_gather_with_counts)
from .session import (PK_FILE, PK_NULL, ST_COMPLETED, ST_ERROR, ST_INIT,
                      CompiledDropRef, CompiledSession)

# per-drop dispatch codes (apps only; data drops never dispatch)
CODE_PYTHON = 0      # registry app with real Python work
CODE_NONE = 1        # no app function: complete, write nothing
CODE_NOOP = 2        # write None to all outputs
CODE_IDENTITY = 3    # forward the single input (or the input list)
CODE_SLEEP = 4       # sleep, then write None to all outputs

_FAST_CODE = {"noop": CODE_NOOP, "identity": CODE_IDENTITY,
              "sleep": CODE_SLEEP}


def _dispatch_code(app: Optional[str]) -> int:
    """Dispatch code for one app name.  A fast code applies only while
    the registry entry still IS the built-in implementation — users may
    re-register 'noop'/'identity'/'sleep', and the object oracle would
    run their function, so the compiled engine must too."""
    if not app:
        return CODE_NONE
    code = _FAST_CODE.get(app, CODE_PYTHON)
    if code != CODE_PYTHON and \
            _APP_REGISTRY.get(app) is not BUILTIN_FAST_APPS.get(app):
        return CODE_PYTHON
    return code


class _WaveTimeout(Exception):
    """Raised mid-wave when the execution deadline expires.

    Safe to abort anywhere: the scheduler derives its counters from the
    state array on entry, so a partially-processed wave (some drops
    terminal, some still INIT) resumes exactly where it stopped."""


class ExecHooks:
    """Scheduler extension points (consumed by :mod:`repro.core.resilience`).

    * ``on_wave(session, completed, total)`` — called at the top of every
      wave, when all drop state is consistent (everything terminal or
      INIT, no in-flight work).  May raise to abort the run; the state
      array stays resumable.
    * ``python_runner(ctx, ids)`` — replaces the sequential registry-app
      loop for the wave's Python apps (``ctx`` is the ``_Dispatch``;
      ``ids`` are node-sorted and may span nodes).  Must leave every id
      terminal, or raise ``_WaveTimeout`` past ``ctx.deadline``.
    """

    __slots__ = ("on_wave", "python_runner")

    def __init__(self, on_wave=None, python_runner=None) -> None:
        self.on_wave = on_wave
        self.python_runner = python_runner


# shared with pgt.py (kept as module aliases — the scheduler's hot loop
# and the resilience closure gather CSR rows the same way)
_gather = csr_gather
_gather_with_counts = csr_gather_with_counts


def node_batches(pgt: CompiledPGT, ids: np.ndarray) -> List[np.ndarray]:
    """Split drop ids into per-placement-node batches (stable order).

    Shared by the default threaded wave dispatch below and the
    resilience runner's speculative dispatch (same argsort-and-split)."""
    nodes = pgt.node_ids[ids]
    order = np.argsort(nodes, kind="stable")
    run = ids[order]
    bounds = np.flatnonzero(np.diff(nodes[order])) + 1
    return np.split(run, bounds)


# ---------------------------------------------------------------------------
# Registry-app shims — what an app function sees instead of real Drops
# ---------------------------------------------------------------------------


class _DataRef(CompiledDropRef):
    """Duck-types the slice of ``DataDrop`` that app functions consume:
    ``read()``/``write()`` against the session's dense payload table
    (uid/node/read come from the shared row view)."""

    __slots__ = ()

    @property
    def meta(self) -> Dict[str, Any]:
        return _drop_meta(self.s.pgt, self.idx)

    def write(self, value: Any) -> None:
        self.s._write_idx(self.idx, value)

    def nbytes(self) -> int:
        v = self.s.payloads[self.idx]
        return int(getattr(v, "nbytes", 0))


class _AppRef(CompiledDropRef):
    """Duck-types the slice of ``AppDrop`` an app function consumes
    (``app.meta`` with oid/construct/params, ``app.uid``, ``app.node``)."""

    __slots__ = ("_meta",)

    def __init__(self, session: CompiledSession, idx: int) -> None:
        super().__init__(session, idx)
        self._meta: Optional[Dict[str, Any]] = None

    @property
    def meta(self) -> Dict[str, Any]:
        if self._meta is None:
            m = _drop_meta(self.s.pgt, self.idx)
            m["execution_time"] = float(self.s.pgt.exec_arr[self.idx])
            self._meta = m
        return self._meta


def _drop_meta(pgt: CompiledPGT, idx: int) -> Dict[str, Any]:
    # same layout NodeDropManager._instantiate builds for real Drops
    return {"oid": pgt.oid_of(idx), "construct": pgt.group_of(idx).name,
            **pgt.params_of(idx)}


# ---------------------------------------------------------------------------
# Batched per-node dispatch
# ---------------------------------------------------------------------------


class _Dispatch:
    """Precomputed dispatch tables + the per-wave app execution logic."""

    def __init__(self, session: CompiledSession,
                 hooks: Optional[ExecHooks] = None,
                 executors: Optional[Dict[str, Any]] = None) -> None:
        pgt = session.pgt
        self.s = session
        self.pgt = pgt
        self.hooks = hooks
        # node name -> thread pool: Python-app waves spanning several
        # nodes overlap (one worker task per node batch); None/empty
        # keeps the sequential in-thread dispatch
        self.executors = executors or {}
        n = pgt.num_drops
        self.out_indptr, self.out_cols, _ = pgt.out_csr_with_eid()
        self.in_indptr, self.in_cols, _ = pgt.in_csr_with_eid()
        self.in_deg = pgt.in_degrees()
        gidx = pgt.group_idx_arr()
        if len(pgt.groups):
            gcode = np.fromiter(
                (_dispatch_code(g.app) for g in pgt.groups),
                dtype=np.int8, count=len(pgt.groups))
            self.app_code = gcode[gidx]
            gthr = np.fromiter((g.error_threshold for g in pgt.groups),
                               dtype=np.float64, count=len(pgt.groups))
            self.thr = pgt.err_arr if pgt.err_arr is not None \
                else gthr[gidx]
        else:
            self.app_code = np.zeros(n, dtype=np.int8)
            self.thr = np.zeros(n, dtype=np.float64)
        # the vectorised noop/identity fast paths write only the payload
        # table; graphs with file-backed payloads take the per-app path so
        # spill files appear exactly as the object engine would write them
        self.fast_ok = not bool((session.payload_kind == PK_FILE).any())
        self.deadline = float("inf")   # set per run by execute_frontier
        # telemetry (off unless the session carries a Timeline/registry):
        # fast paths stamp whole batches, _run_python stamps per app
        self.tl = session.timeline
        self.wave = 0                  # current wave index, for stamps
        self.m_batches = None          # Counter("exec.dispatch_batches")

    # -- wave entry ---------------------------------------------------------
    def dispatch(self, run_ids: np.ndarray) -> None:
        """Fire all runnable apps of one wave.

        Sleep apps are handled wave-wide first (the whole wave runs
        concurrently in the object engine, so one ``max(seconds)`` sleep
        models it — NOT one per node); everything else goes out as one
        batched dispatch per node.  Registry (Python) apps of the whole
        wave are dispatched together, node-sorted, so a resilience runner
        can overlap per-node batches and speculate across nodes."""
        if run_ids.size == 0:
            return
        codes = self.app_code[run_ids]
        sleep_ids = run_ids[codes == CODE_SLEEP]
        if sleep_ids.size:
            self._sleep_batch(sleep_ids)
            run_ids = run_ids[codes != CODE_SLEEP]
            if run_ids.size == 0:
                return
        nodes = self.pgt.node_ids[run_ids]
        order = np.lexsort((run_ids, nodes))
        run = run_ids[order]
        bounds = np.flatnonzero(np.diff(nodes[order])) + 1
        batches = np.split(run, bounds)
        if self.m_batches is not None:
            self.m_batches.inc(len(batches))
        python_parts = [self._dispatch_batch(batch) for batch in batches]
        self._run_python_batch(np.concatenate(python_parts))

    def _stamp_batch(self, ids: np.ndarray, t0: float) -> None:
        """Timeline-stamp a terminal fast-path batch (end = now)."""
        if self.tl is not None and ids.size:
            self.tl.stamp_batch(ids, t0, time.monotonic(), self.wave)

    def _dispatch_batch(self, batch: np.ndarray) -> np.ndarray:
        """Run the fast-path apps of one per-node batch; return the
        registry (Python) apps for the wave-wide dispatch."""
        codes = self.app_code[batch]
        t0 = time.monotonic() if self.tl is not None else 0.0
        none_ids = batch[codes == CODE_NONE]
        if none_ids.size:
            self.s.drop_state[none_ids] = ST_COMPLETED
            self._stamp_batch(none_ids, t0)
        noop_ids = batch[codes == CODE_NOOP]
        if noop_ids.size:
            self._write_none_outputs(noop_ids)
        ident_ids = batch[codes == CODE_IDENTITY]
        if ident_ids.size:
            self._identity_batch(ident_ids)
        return batch[codes == CODE_PYTHON]

    def _run_python_batch(self, ids: np.ndarray) -> None:
        """Registry-path dispatch, deadline-checked per app (a wide wave
        of Python apps must not overshoot the execution timeout).

        A resilience ``python_runner`` hook takes over the whole per-node
        batch (threaded dispatch, retries, straggler speculation);
        otherwise, with node executors available, per-node batches run
        concurrently on the node thread pools — the object engine's wave
        parallelism, which the plain sequential loop used to serialise."""
        if ids.size and self.hooks is not None \
                and self.hooks.python_runner is not None:
            self.hooks.python_runner(self, ids)
            return
        if self.executors and ids.size > 1:
            self._run_python_threaded(ids)
            return
        self._run_python_seq(ids)

    def _run_python_seq(self, ids: np.ndarray) -> None:
        for i in ids.tolist():
            if time.monotonic() > self.deadline:
                raise _WaveTimeout
            self._run_python(i)

    def _run_python_threaded(self, ids: np.ndarray) -> None:
        """Overlap the wave's per-node batches on the node thread pools.

        Every app still lands in a terminal state exactly as on the
        sequential path (``_run_python`` catches app exceptions); batches
        on nodes without an executor (or unplaced drops) run inline.  A
        deadline overrun in any batch surfaces as one ``_WaveTimeout``
        after all batches stopped — the state array stays resumable."""
        batches = node_batches(self.pgt, ids)
        if len(batches) <= 1:
            self._run_python_seq(ids)
            return
        node_ids = self.pgt.node_ids
        names = self.pgt.node_names
        futures = []
        inline: List[np.ndarray] = []
        for batch in batches:
            nid = int(node_ids[int(batch[0])])
            ex = self.executors.get(names[nid]) if nid >= 0 else None
            if ex is None:
                inline.append(batch)
            else:
                futures.append(ex.submit(self._run_python_seq, batch))
        timed_out = False
        for batch in inline:
            try:
                self._run_python_seq(batch)
            except _WaveTimeout:
                timed_out = True     # keep draining; workers stop on the
                #                      same deadline within one app each
        for f in futures:
            try:
                f.result()
            except _WaveTimeout:
                timed_out = True
        if timed_out:
            raise _WaveTimeout

    # -- fast paths ---------------------------------------------------------
    def _write_none_outputs(self, ids: np.ndarray,
                            t0: Optional[float] = None) -> None:
        """noop semantics: write ``None`` to every output, complete.
        ``t0`` carries a caller's earlier start stamp (the sleep batch
        starts *before* it sleeps)."""
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        s = self.s
        start = (time.monotonic() if t0 is None else t0) \
            if self.tl is not None else 0.0
        dsts = _gather(self.out_indptr, self.out_cols, ids)
        if dsts.size:
            s.payloads[dsts] = None
            s.payload_present[dsts] = True
        s.drop_state[ids] = ST_COMPLETED
        self._stamp_batch(ids, start)

    def _sleep_batch(self, ids: np.ndarray) -> None:
        """One wave of sleeps runs concurrently in the object engine; the
        compiled engine models ideal parallelism: sleep the max once.

        On the registry fallback (file payloads present) each app sleeps
        individually inside ``_run_python`` — no batched sleep on top."""
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        t0 = time.monotonic() if self.tl is not None else None
        secs = max(self._sleep_seconds(i) for i in ids.tolist())
        if secs > 0:
            remaining = self.deadline - time.monotonic()
            if secs > remaining:
                time.sleep(max(remaining, 0.0))
                raise _WaveTimeout
            time.sleep(secs)
        self._write_none_outputs(ids, t0)

    def _sleep_seconds(self, i: int) -> float:
        ov = self.pgt._params_override.get(i)
        if ov is not None and "seconds" in ov:
            return float(ov["seconds"])
        return float(self.pgt.group_of(i).params.get("seconds", 0.001))

    def _identity_batch(self, ids: np.ndarray) -> None:
        if not self.fast_ok:
            self._run_python_batch(ids)
            return
        t0 = time.monotonic() if self.tl is not None else 0.0
        s = self.s
        single = ids[self.in_deg[ids] == 1]
        # multi-input: general list semantics via the registry path
        self._run_python_batch(ids[self.in_deg[ids] != 1])
        if single.size == 0:
            return
        preds = self.in_cols[self.in_indptr[single]]
        completed = s.drop_state[preds] == ST_COMPLETED
        readable = s.payload_present[preds] | \
            (s.payload_kind[preds] == PK_NULL)
        hard = completed & ~readable     # absent payload -> PayloadError
        self._run_python_batch(single[hard])
        fast = ~hard
        vals = np.empty(single.size, dtype=object)
        easy = completed & readable
        vals[easy] = s.payloads[preds[easy]]
        # errored input tolerated by t: ok_inputs == [] -> identity of []
        for k in np.flatnonzero(~completed).tolist():
            vals[k] = []
        fast_ids = single[fast]
        dsts, cnt = _gather_with_counts(self.out_indptr, self.out_cols,
                                        fast_ids)
        if dsts.size:
            s.payloads[dsts] = np.repeat(vals[fast], cnt)
            s.payload_present[dsts] = True
        s.drop_state[fast_ids] = ST_COMPLETED
        self._stamp_batch(fast_ids, t0)

    # -- general path: the app registry -------------------------------------
    def app_call(self, i: int, out_ref=_DataRef):
        """(func, in_refs, out_refs, app_ref) for registry app ``i``.

        ``func`` is None for no-app drops (complete without work).  The
        resilience runner passes a staging ``out_ref`` so speculative
        duplicates buffer writes instead of touching the payload table."""
        s = self.s
        pgt = self.pgt
        name = pgt.app_of(i)
        func = get_app(name) if name else None
        if func is None:
            return None, [], [], None
        ins = self.in_cols[self.in_indptr[i]:self.in_indptr[i + 1]]
        ok = ins[s.drop_state[ins] == ST_COMPLETED]
        refs = [_DataRef(s, int(j)) for j in ok]
        # deterministic input order (the object engine sorts by
        # (oid, uid) regardless of wiring order)
        refs.sort(key=lambda r: (pgt.oid_of(r.idx), pgt.uid_of(r.idx)))
        outs = [out_ref(s, int(j)) for j in
                self.out_cols[self.out_indptr[i]:self.out_indptr[i + 1]]]
        return func, refs, outs, _AppRef(s, int(i))

    def _run_python(self, i: int) -> None:
        s = self.s
        t0 = time.monotonic() if self.tl is not None else 0.0
        try:
            func, refs, outs, app = self.app_call(i)
            if func is not None:
                func(refs, outs, app)
            s.drop_state[i] = ST_COMPLETED
        except Exception:  # noqa: BLE001 - app failures become drop ERRORs
            s.drop_state[i] = ST_ERROR
            s.record_error(i, traceback.format_exc(limit=8))
        if self.tl is not None:
            self.tl.stamp(int(i), t0, time.monotonic(), self.wave)


# ---------------------------------------------------------------------------
# The frontier scheduler
# ---------------------------------------------------------------------------


def execute_frontier(session: CompiledSession,
                     timeout: float = 60.0,
                     hooks: Optional[ExecHooks] = None,
                     executors: Optional[Dict[str, Any]] = None) -> bool:
    """Run a deployed :class:`CompiledSession` to completion, wave-by-wave.

    ``executors`` (node name -> thread pool, e.g.
    ``MasterDropManager.node_executors()``) lets registry-app waves that
    span several nodes overlap; without it Python apps run sequentially
    in the calling thread.  Vectorised fast paths are unaffected.

    Resume-aware: ``pending_inputs`` and the errored-predecessor counters
    are derived from the *current* state array, so a session restored from
    a checkpoint (or pre-seeded with completed drops) continues from
    exactly where it left off.  The same property makes ``hooks.on_wave``
    free to abort the run (fault injection) — recovery resets state rows
    and simply calls ``execute_frontier`` again.

    Returns True when every drop reached a terminal state within
    ``timeout``; on timeout the session is left RUNNING and False is
    returned (the engine reports state "TIMEOUT").
    """
    pgt = session.pgt
    n = pgt.num_drops
    session.start()
    if n == 0:
        if hooks is not None and hooks.on_wave is not None:
            hooks.on_wave(session, 0, 0)
        session.finish()
        return True
    state = session.drop_state
    kind = pgt.kind_arr
    in_deg = pgt.in_degrees()
    ctx = _Dispatch(session, hooks, executors)
    out_indptr, out_cols = ctx.out_indptr, ctx.out_cols

    # readiness counters, derived from current state (fresh start or resume)
    src_state = state[pgt.edge_src]
    terminal_edges = src_state != ST_INIT
    # int32 counters throughout (in_degrees is int32): at the 10M tier
    # the three per-drop counter arrays stay at 40MB each, not 80MB
    if terminal_edges.any():
        pending = in_deg - np.bincount(
            pgt.edge_dst[terminal_edges], minlength=n).astype(np.int32)
        err_preds = np.bincount(
            pgt.edge_dst[src_state == ST_ERROR],
            minlength=n).astype(np.int32)
    else:
        pending = in_deg.copy()
        err_preds = np.zeros(n, dtype=np.int32)

    frontier = np.flatnonzero((pending == 0) & (state == ST_INIT))
    remaining = int((state == ST_INIT).sum())
    deadline = time.monotonic() + timeout
    ctx.deadline = deadline   # enforced mid-wave too (wide Python waves)

    # telemetry: wave/frontier metrics at wave granularity, per-drop
    # stamps in the dispatch fast paths.  Resumed sessions keep wave
    # numbers monotone by continuing past the highest stamped index.
    tl = session.timeline
    reg = session.metrics
    if reg is not None:
        from .telemetry import FRONTIER_BUCKETS
        m_waves = reg.counter("exec.waves")
        m_front = reg.histogram("exec.frontier_size", FRONTIER_BUCKETS)
        ctx.m_batches = reg.counter("exec.dispatch_batches")
    wave_no = tl.max_wave + 1 if tl is not None else 0

    while frontier.size:
        if time.monotonic() > deadline:
            return False
        if hooks is not None and hooks.on_wave is not None:
            # state is consistent here (all drops terminal or INIT); any
            # exception raised by the hook leaves the session resumable
            hooks.on_wave(session, n - remaining, n)
        ctx.wave = wave_no
        if reg is not None:
            m_waves.inc()
            m_front.observe(float(frontier.size))
        wave_t0 = time.monotonic() if tl is not None else 0.0

        # 1. complete all ready data drops of the wave (vectorised)
        data_ids = frontier[kind[frontier] == KIND_DATA]
        if data_ids.size:
            bad = err_preds[data_ids] > 0
            state[data_ids[~bad]] = ST_COMPLETED
            errs = data_ids[bad]
            if errs.size:
                state[errs] = ST_ERROR
                for i in errs.tolist():
                    session.record_error(i, "producer errored")
            if tl is not None:
                tl.stamp_batch(data_ids, wave_t0, time.monotonic(),
                               wave_no)

        # 2. fire all runnable apps (threshold gate, then per-node batches)
        app_ids = frontier[kind[frontier] != KIND_DATA]
        if app_ids.size:
            n_in = in_deg[app_ids]
            nerr = err_preds[app_ids]
            frac_err = nerr / np.maximum(n_in, 1)
            fail = frac_err > ctx.thr[app_ids]
            failed = app_ids[fail]
            if failed.size:
                state[failed] = ST_ERROR
                for i, ne, ni in zip(failed.tolist(), nerr[fail].tolist(),
                                     n_in[fail].tolist()):
                    session.record_error(i, (
                        f"{ne}/{ni} inputs errored > "
                        f"t={float(ctx.thr[i])}"))
                if tl is not None:
                    tl.stamp_batch(failed, wave_t0, time.monotonic(),
                                   wave_no)
            try:
                ctx.dispatch(app_ids[~fail])
            except _WaveTimeout:
                # mid-wave abort: skip the in-degree advance; counters
                # are re-derived from the state array on resume
                return False

        remaining -= int(frontier.size)
        wave_no += 1

        # 3. advance in-degrees: one np.add.at per wave
        succ = _gather(out_indptr, out_cols, frontier)
        if succ.size:
            np.add.at(pending, succ, -1)
            errored = frontier[state[frontier] == ST_ERROR]
            if errored.size:
                np.add.at(err_preds,
                          _gather(out_indptr, out_cols, errored), 1)
            cand = np.unique(succ)
            frontier = cand[(pending[cand] == 0) & (state[cand] == ST_INIT)]
        else:
            frontier = np.empty(0, dtype=np.int64)

    if remaining == 0:
        if hooks is not None and hooks.on_wave is not None:
            # final wave report: progress consumers observe completed ==
            # total exactly once.  A hook exception here still leaves the
            # session resumable (all drops terminal, finish() not called);
            # the resilient loop's fired-fraction set prevents re-firing.
            hooks.on_wave(session, n, n)
        if reg is not None:
            # count_nonzero on the int8 state is ~10x cheaper than a
            # bincount (which upcasts to intp first)
            n_err = int(np.count_nonzero(state == ST_ERROR))
            reg.counter("exec.drops_completed").inc(n - n_err)
            reg.counter("exec.drops_errored").inc(n_err)
        session.finish()
        return True
    return False
