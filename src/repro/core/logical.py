"""Logical Graph Templates and Logical Graphs (paper §3.2–§3.3).

An LGT is a resource-oblivious description of a pipeline.  Providing concrete
parameter values turns it into a Logical Graph (LGR) — "the only difference
between LGT and LGR are those parameter values filled in by the project PI".

Validation (paper §3.4 step 1, "analogous to the syntax checking done by
compilers"):
  * no cycles (DALiuGE does not allow cycles in the Logical Graph),
  * edges respect the Data<->Component linking rule,
  * GroupBy must sit inside nested Scatter constructs,
  * container nesting is well-formed,
  * Gather fan-in divides the number of incoming branches.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from .constructs import CONTAINER_KINDS, Construct, Kind, LogicalEdge


class GraphValidationError(ValueError):
    pass


@dataclass
class LogicalGraphTemplate:
    """A named, versioned LGT (paper: versioned repository of LGTs)."""

    name: str
    version: str = "0"
    constructs: Dict[str, Construct] = field(default_factory=dict)
    edges: List[LogicalEdge] = field(default_factory=list)
    # user-specifiable parameters (filled at Select & Parametrise, §3.3)
    parameters: Dict[str, Any] = field(default_factory=dict)

    # -- construction -----------------------------------------------------
    def add(self, c: Construct) -> Construct:
        if c.name in self.constructs:
            raise GraphValidationError(f"duplicate construct {c.name!r}")
        if c.parent is not None and c.parent not in self.constructs:
            raise GraphValidationError(
                f"parent {c.parent!r} of {c.name!r} not defined yet")
        self.constructs[c.name] = c
        return c

    def connect(self, src: str, dst: str, streaming: bool = False) -> None:
        for n in (src, dst):
            if n not in self.constructs:
                raise GraphValidationError(f"unknown construct {n!r}")
        self.edges.append(LogicalEdge(src, dst, streaming))

    # -- helpers -------------------------------------------------------------
    def ancestors(self, name: str) -> List[Construct]:
        """Chain of enclosing containers, outermost first."""
        chain: List[Construct] = []
        cur = self.constructs[name].parent
        while cur is not None:
            c = self.constructs[cur]
            chain.append(c)
            cur = c.parent
        return list(reversed(chain))

    def children(self, name: str) -> List[Construct]:
        return [c for c in self.constructs.values() if c.parent == name]

    def leaves(self) -> List[Construct]:
        return [c for c in self.constructs.values() if not c.is_container()]

    # -- validation (§3.4 step 1) ------------------------------------------------
    def validate(self) -> None:
        self._validate_nesting()
        self._validate_linking()
        self._validate_acyclic()
        self._validate_groupby()
        self._validate_loops()

    def _validate_nesting(self) -> None:
        for c in self.constructs.values():
            seen: Set[str] = set()
            cur = c.parent
            while cur is not None:
                if cur in seen:
                    raise GraphValidationError(
                        f"container cycle at {cur!r}")
                seen.add(cur)
                parent = self.constructs.get(cur)
                if parent is None:
                    raise GraphValidationError(
                        f"{c.name!r} has unknown parent {cur!r}")
                if not parent.is_container():
                    raise GraphValidationError(
                        f"{c.name!r} nested in non-container {cur!r}")
                cur = parent.parent

    def _validate_linking(self) -> None:
        for e in self.edges:
            s, d = self.constructs[e.src], self.constructs[e.dst]
            if s.is_container() or d.is_container():
                raise GraphValidationError(
                    f"edges must connect leaf constructs: {e.src}->{e.dst}")
            if s.kind == d.kind:
                raise GraphValidationError(
                    "linking rule violated (Data<->Component only): "
                    f"{e.src}({s.kind.value}) -> {e.dst}({d.kind.value})")

    def _validate_acyclic(self) -> None:
        # Loop-carried back edges are *not* edges in the LGT (the body is
        # replicated at unroll time), so the LGT must be a DAG outright.
        adj: Dict[str, List[str]] = {n: [] for n in self.constructs}
        for e in self.edges:
            adj[e.src].append(e.dst)
        WHITE, GREY, BLACK = 0, 1, 2
        color = {n: WHITE for n in adj}

        def dfs(n: str) -> None:
            color[n] = GREY
            for m in adj[n]:
                if color[m] == GREY:
                    raise GraphValidationError(f"cycle detected through {m!r}")
                if color[m] == WHITE:
                    dfs(m)
            color[n] = BLACK

        for n in adj:
            if color[n] == WHITE:
                dfs(n)

    def _validate_groupby(self) -> None:
        """GroupBy must be used in conjunction with nested Scatters (§3.2).

        The structural check (two incoming scatter axes) happens at unroll
        time via ``AxisResolver``, because GroupBy may be spelled either
        nested inside the Scatters or as a sibling consuming their flow.
        Here we only check it is not a root with no flow at all.
        """
        for c in self.constructs.values():
            if c.kind is not Kind.GROUPBY:
                continue
            inside = {x.name for x in self.constructs.values()
                      if self._inside(x.name, c.name)}
            has_in = any(e.dst in inside and e.src not in inside
                         for e in self.edges)
            nested = any(a.kind is Kind.SCATTER
                         for a in self.ancestors(c.name))
            if not has_in and not nested:
                raise GraphValidationError(
                    f"GroupBy {c.name!r} requires nested Scatter constructs "
                    "or incoming scattered flow")

    def _inside(self, leaf: str, container: str) -> bool:
        cur = self.constructs[leaf].parent
        while cur is not None:
            if cur == container:
                return True
            cur = self.constructs[cur].parent
        return False

    def _validate_loops(self) -> None:
        for c in self.constructs.values():
            if c.kind is Kind.LOOP and c.num_of_iterations < 1:
                raise GraphValidationError(
                    f"Loop {c.name!r} needs num_of_iterations >= 1")
            if (c.loop_entry or c.loop_exit):
                if c.kind is not Kind.DATA:
                    raise GraphValidationError(
                        f"loop_entry/exit only valid on Data: {c.name!r}")
                anc = self.ancestors(c.name)
                if not any(a.kind is Kind.LOOP for a in anc):
                    raise GraphValidationError(
                        f"{c.name!r} marked loop-carried outside a Loop")

    # -- Select & Parametrise (§3.3) -----------------------------------------
    def parametrise(self, **values: Any) -> "LogicalGraph":
        """Fill user parameters -> LogicalGraph.

        Parameters are referenced by constructs via ``params`` entries of the
        form ``{"$param": "<name>"}`` or by the template-level defaults.
        """
        unknown = set(values) - set(self.parameters)
        if unknown:
            raise GraphValidationError(
                f"unknown parameters {sorted(unknown)}; "
                f"template declares {sorted(self.parameters)}")
        resolved = {**self.parameters, **values}
        lg = LogicalGraph(
            name=self.name, version=self.version,
            constructs={k: copy.deepcopy(v)
                        for k, v in self.constructs.items()},
            edges=list(self.edges), parameters=resolved)
        for c in lg.constructs.values():
            for attr in ("num_of_copies", "num_of_inputs",
                         "num_of_iterations", "data_volume",
                         "execution_time"):
                v = c.params.get(f"${attr}")
                if isinstance(v, str):
                    if v not in resolved:
                        raise GraphValidationError(
                            f"{c.name!r} references undefined parameter {v!r}")
                    setattr(c, attr, resolved[v])
        lg.validate()
        return lg

    # -- serialisation ---------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name, "version": self.version,
            "constructs": [c.to_json() for c in self.constructs.values()],
            "edges": [e.to_json() for e in self.edges],
            "parameters": self.parameters,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "LogicalGraphTemplate":
        lgt = cls(name=d["name"], version=d.get("version", "0"),
                  parameters=d.get("parameters", {}))
        for cd in d["constructs"]:
            lgt.add(Construct.from_json(cd))
        for ed in d["edges"]:
            lgt.edges.append(LogicalEdge.from_json(ed))
        return lgt


class LogicalGraph(LogicalGraphTemplate):
    """An LGT with all parameters bound (paper §3.3)."""
