"""Compact array-based Physical Graph Template (the translate fast path).

The paper's headline regime is logical graphs that unroll into *millions* of
drops; a dict-of-``DropSpec`` representation spends microseconds per drop on
Python hashing and attribute access and caps translation at ~10^5 drops.
``CompiledPGT`` stores the same physical graph as parallel numpy arrays:

* **drops** — ``kind`` / ``exec_time`` / ``data_volume`` / ``weight`` /
  ``partition`` / ``node`` as flat arrays indexed by a dense int drop id
  (creation order — identical to the dict path's insertion order),
* **edges** — COO ``edge_src`` / ``edge_dst`` / ``edge_streaming`` int32
  arrays with lazily-built CSR adjacency (``indptr`` + column indices) in
  both directions,
* **instance groups** — one record per logical-graph leaf holding the
  shared metadata (construct name, app, payload kind, params) and the axis
  sizes, so per-drop strings/dicts (uids, oids, params) are *derived on
  demand* instead of materialised up front.

The classic dict/DropSpec API (``pgt.drops[uid]``, ``pgt.edges``,
``predecessors`` / ``successors`` / ``roots`` / ``topological_order``) is
exposed as lazy views, so the engine, graph_io, mapping and the managers
work unchanged; hot algorithms (partitioning, scheduling) dispatch on the
type and run vectorized.
"""
from __future__ import annotations

import bisect
from typing import (Any, Dict, Iterator, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from .logical import GraphValidationError

KIND_APP = 0
KIND_DATA = 1

_INT32_MAX = np.iinfo(np.int32).max


def _check_int32_capacity(num_drops: int, num_edges: int,
                          context: str) -> None:
    """Drop/edge ids are stored as int32 throughout the compiled path;
    beyond 2^31-1 of either the ids would silently wrap.  Raise with a
    clear message instead (the paper's regime tops out at tens of
    millions — two orders of magnitude of headroom)."""
    if num_drops > _INT32_MAX or num_edges > _INT32_MAX:
        raise GraphValidationError(
            f"{context}: {num_drops} drops / {num_edges} edges exceed the "
            f"int32 index capacity ({_INT32_MAX}); the compiled "
            "representation does not support graphs this large")


def _uid_str(name: str, idx: Tuple[int, ...]) -> str:
    return name if not idx else f"{name}#{'.'.join(map(str, idx))}"


def csr_gather_with_counts(indptr: np.ndarray, cols: np.ndarray,
                           ids: np.ndarray) -> Tuple[np.ndarray,
                                                     np.ndarray]:
    """Concatenated CSR rows for ``ids`` + per-id row lengths.

    The grouped-arange trick ``_kahn_levels`` uses, shared by the frontier
    scheduler (successor advance) and the resilience subsystem (upstream
    lineage closure over the reverse CSR)."""
    starts = indptr[ids]
    cnt = indptr[ids + 1] - starts
    total = int(cnt.sum())
    if total == 0:
        return np.empty(0, dtype=cols.dtype), cnt
    if total == ids.shape[0] and bool((cnt == 1).all()):
        # every row has exactly one entry (the dominant case for
        # in-adjacency): plain gather, no repeat/arange construction
        return cols[starts], cnt
    reps = np.repeat(starts - np.concatenate(([0], np.cumsum(cnt)[:-1])),
                     cnt)
    return cols[np.arange(total, dtype=np.int64) + reps], cnt


def csr_gather(indptr: np.ndarray, cols: np.ndarray,
               ids: np.ndarray) -> np.ndarray:
    return csr_gather_with_counts(indptr, cols, ids)[0]


def coo_to_csr(n: int, keys: np.ndarray,
               cols: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]:
    """COO edge list -> CSR: (indptr, cols sorted by key, permutation).

    ``keys`` are the row ids (source for out-adjacency, destination for
    in-adjacency); the returned permutation maps CSR position back to the
    original COO edge id so per-edge attributes can be gathered.
    """
    order = np.argsort(keys, kind="stable")
    counts = np.bincount(keys, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, cols[order], order


class InstanceGroup:
    """Shared metadata for all physical instances of one LG leaf."""

    __slots__ = ("name", "base", "sizes", "kind", "app", "payload_kind",
                 "execution_time", "data_volume", "error_threshold",
                 "params")

    def __init__(self, name: str, base: int, sizes: Tuple[int, ...],
                 kind: int, app: Optional[str], payload_kind: str,
                 execution_time: float, data_volume: float,
                 error_threshold: float, params: Dict[str, Any]) -> None:
        self.name = name
        self.base = base
        self.sizes = sizes
        self.kind = kind
        self.app = app
        self.payload_kind = payload_kind
        self.execution_time = execution_time
        self.data_volume = data_volume
        self.error_threshold = error_threshold
        self.params = params

    @property
    def count(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    def oid_of(self, local: int) -> Tuple[int, ...]:
        if not self.sizes:
            return ()
        out = []
        for s in reversed(self.sizes):
            out.append(local % s)
            local //= s
        return tuple(reversed(out))

    def local_of(self, oid: Sequence[int]) -> int:
        local = 0
        for s, i in zip(self.sizes, oid):
            local = local * s + i
        return local


class _LazyParams(dict):
    """Per-drop params dict that registers itself only on first mutation.

    Reads of ``spec.params`` (serialisation, deploy) allocate a transient
    copy and retain nothing on the PGT; writes install the dict into
    ``_params_override`` so they persist, matching ``DropSpec`` semantics.
    If another copy was registered first, the mutation is forwarded there
    too, so the registered dict stays authoritative.
    """

    __slots__ = ("_pgt", "_idx")

    def __init__(self, pgt: "CompiledPGT", idx: int, base: Dict[str, Any]):
        super().__init__(base)
        self._pgt = pgt
        self._idx = idx

    def _register(self) -> Optional["_LazyParams"]:
        reg = self._pgt._params_override.setdefault(self._idx, self)
        return None if reg is self else reg

    def __setitem__(self, k, v):
        super().__setitem__(k, v)
        reg = self._register()
        if reg is not None:
            dict.__setitem__(reg, k, v)

    def __delitem__(self, k):
        super().__delitem__(k)
        reg = self._register()
        if reg is not None:
            dict.__delitem__(reg, k)

    def update(self, *a, **kw):
        super().update(*a, **kw)
        reg = self._register()
        if reg is not None:
            dict.update(reg, *a, **kw)

    def setdefault(self, k, default=None):
        out = super().setdefault(k, default)
        reg = self._register()
        if reg is not None:
            dict.setdefault(reg, k, default)
        return out

    def pop(self, k, *default):
        out = super().pop(k, *default)
        reg = self._register()
        if reg is not None:
            dict.pop(reg, k, *default)
        return out

    def popitem(self):
        out = super().popitem()
        reg = self._register()
        if reg is not None:
            dict.pop(reg, out[0], None)
        return out

    def clear(self):
        super().clear()
        reg = self._register()
        if reg is not None:
            dict.clear(reg)


class DropView:
    """Lazy ``DropSpec``-compatible proxy over one row of a ``CompiledPGT``.

    Reads come straight from the arrays; writes to ``partition`` / ``node``
    / ``params`` write through, so code that mutates specs (the engine, the
    mapper, the managers) behaves exactly as with real ``DropSpec``s.
    """

    __slots__ = ("_p", "_i")

    def __init__(self, pgt: "CompiledPGT", idx: int) -> None:
        self._p = pgt
        self._i = idx

    # -- identity ---------------------------------------------------------
    @property
    def uid(self) -> str:
        return self._p.uid_of(self._i)

    @property
    def kind(self) -> str:
        return "data" if self._p.kind_arr[self._i] == KIND_DATA else "app"

    @property
    def construct(self) -> str:
        return self._p.group_of(self._i).name

    @property
    def oid(self) -> Tuple[int, ...]:
        return self._p.oid_of(self._i)

    @property
    def app(self) -> Optional[str]:
        return self._p.app_of(self._i)

    @property
    def payload_kind(self) -> str:
        return self._p.group_of(self._i).payload_kind

    @property
    def execution_time(self) -> float:
        return float(self._p.exec_arr[self._i])

    @property
    def data_volume(self) -> float:
        return float(self._p.vol_arr[self._i])

    @property
    def error_threshold(self) -> float:
        return float(self._p.err_arr[self._i]) if self._p.err_arr is not None \
            else self._p.group_of(self._i).error_threshold

    @property
    def params(self) -> Dict[str, Any]:
        return self._p.params_of(self._i)

    # -- mutable fields ------------------------------------------------------
    @property
    def partition(self) -> int:
        return int(self._p.partition[self._i])

    @partition.setter
    def partition(self, value: int) -> None:
        self._p.partition[self._i] = value

    @property
    def node(self) -> Optional[str]:
        nid = self._p.node_ids[self._i]
        return None if nid < 0 else self._p.node_names[nid]

    @node.setter
    def node(self, value: Optional[str]) -> None:
        self._p.set_node(self._i, value)

    # -- cost model -----------------------------------------------------------
    def weight(self) -> float:
        return float(self._p.weight_arr[self._i])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"DropView({self.uid!r}, kind={self.kind!r}, "
                f"partition={self.partition})")


class DropsView:
    """Read-mostly mapping view: uid -> DropView."""

    def __init__(self, pgt: "CompiledPGT") -> None:
        self._p = pgt

    def __len__(self) -> int:
        return self._p.num_drops

    def __iter__(self) -> Iterator[str]:
        for i in range(self._p.num_drops):
            yield self._p.uid_of(i)

    def __contains__(self, uid: object) -> bool:
        try:
            self._p.index_of(uid)  # type: ignore[arg-type]
            return True
        except KeyError:
            return False

    def __getitem__(self, uid: str) -> DropView:
        return DropView(self._p, self._p.index_of(uid))

    def get(self, uid: str, default: Any = None) -> Any:
        try:
            return self[uid]
        except KeyError:
            return default

    def keys(self) -> Iterator[str]:
        return iter(self)

    def values(self) -> Iterator[DropView]:
        for i in range(self._p.num_drops):
            yield DropView(self._p, i)

    def items(self) -> Iterator[Tuple[str, DropView]]:
        for i in range(self._p.num_drops):
            yield self._p.uid_of(i), DropView(self._p, i)


class EdgesView:
    """Read-only sequence view: (src_uid, dst_uid, streaming) tuples."""

    def __init__(self, pgt: "CompiledPGT") -> None:
        self._p = pgt

    def __len__(self) -> int:
        return self._p.num_edges

    def __getitem__(self, i: int) -> Tuple[str, str, bool]:
        p = self._p
        return (p.uid_of(int(p.edge_src[i])), p.uid_of(int(p.edge_dst[i])),
                bool(p.edge_streaming[i]))

    def __iter__(self) -> Iterator[Tuple[str, str, bool]]:
        p = self._p
        for i in range(p.num_edges):
            yield (p.uid_of(int(p.edge_src[i])),
                   p.uid_of(int(p.edge_dst[i])),
                   bool(p.edge_streaming[i]))


class CompiledPGT:
    """Array-backed Physical Graph Template (CSR adjacency).

    Build with :func:`repro.core.unroll.unroll` (vectorized), with
    :meth:`from_specs` (explicit drop list, e.g. deserialisation) or with
    :meth:`from_dict_pgt` (conversion from the legacy dict representation).
    """

    def __init__(self, name: str, groups: List[InstanceGroup],
                 kind_arr: np.ndarray, exec_arr: np.ndarray,
                 vol_arr: np.ndarray,
                 edge_src: np.ndarray, edge_dst: np.ndarray,
                 edge_streaming: np.ndarray,
                 err_arr: Optional[np.ndarray] = None,
                 uids: Optional[List[str]] = None,
                 oids: Optional[List[Tuple[int, ...]]] = None,
                 group_idx: Optional[np.ndarray] = None,
                 validate_dag: bool = True,
                 levels: Optional[np.ndarray] = None) -> None:
        self.name = name
        self.groups = groups
        self._group_idx = group_idx   # explicit per-drop group mapping
        self._group_bases = [g.base for g in groups]
        self._group_by_name = {g.name: g for g in groups}
        n = int(kind_arr.shape[0])
        _check_int32_capacity(n, int(edge_src.shape[0]),
                              f"CompiledPGT({name!r})")
        self.num_drops = n
        self.kind_arr = kind_arr
        self.exec_arr = exec_arr
        self.vol_arr = vol_arr
        self.err_arr = err_arr
        self.weight_arr = np.where(kind_arr == KIND_APP, exec_arr, 0.0)
        self.partition = np.full(n, -1, dtype=np.int32)
        self.node_ids = np.full(n, -1, dtype=np.int32)
        self.node_names: List[str] = []
        self._node_id_of: Dict[str, int] = {}
        self.edge_src = edge_src.astype(np.int32, copy=False)
        self.edge_dst = edge_dst.astype(np.int32, copy=False)
        self.edge_streaming = edge_streaming.astype(bool, copy=False)
        self.num_edges = int(edge_src.shape[0])
        # explicit-uid mode (deserialised graphs); None => derive from groups
        self._uids = uids
        self._oids = oids
        self._uid_map: Optional[Dict[str, int]] = None
        self._params_override: Dict[int, Dict[str, Any]] = {}
        self._has_streaming: Optional[bool] = None   # lazy edge scan
        # lazy CSR caches
        self._out: Optional[
            Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        self._in_eid: Optional[
            Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        self._indeg: Optional[np.ndarray] = None
        # precomputed longest-path levels (the vectorized unroll derives
        # them from the logical leaf DAG for loop-free graphs, whose
        # expansion is acyclic by construction — no Kahn pass needed)
        self._levels: Optional[np.ndarray] = levels
        self._order: Optional[np.ndarray] = None
        self._evol: Optional[np.ndarray] = None
        # merge hierarchy recorded by min_time (core/substrate.py); the
        # mapper consumes it instead of re-coarsening the partition graph
        self._partition_hierarchy = None
        if validate_dag and levels is None:
            self.topological_order_ids()   # raises on cycles

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @classmethod
    def from_specs(cls, name: str, specs: Sequence[Any],
                   edges: Sequence[Tuple[str, str, bool]],
                   validate_dag: bool = True) -> "CompiledPGT":
        """Build from explicit DropSpec-like records + uid-pair edges."""
        n = len(specs)
        kind = np.empty(n, dtype=np.uint8)
        ex = np.empty(n, dtype=np.float64)
        vol = np.empty(n, dtype=np.float64)
        err = np.empty(n, dtype=np.float64)
        uids: List[str] = []
        oids: List[Tuple[int, ...]] = []
        groups: List[InstanceGroup] = []
        group_idx = np.empty(n, dtype=np.int32)
        interned: Dict[Tuple[Any, ...], int] = {}
        uid_map: Dict[str, int] = {}
        partition = np.empty(n, dtype=np.int32)
        nodes: List[Optional[str]] = []
        params: Dict[int, Dict[str, Any]] = {}
        for i, s in enumerate(specs):
            kind[i] = KIND_DATA if s.kind == "data" else KIND_APP
            ex[i] = s.execution_time
            vol[i] = s.data_volume
            err[i] = s.error_threshold
            if s.uid in uid_map:
                raise GraphValidationError(
                    f"duplicate drop uid {s.uid!r}")
            uids.append(s.uid)
            oids.append(tuple(s.oid))
            uid_map[s.uid] = i
            # one shared group per distinct construct (numeric per-drop
            # fields live in the arrays; the group carries shared metadata)
            key = (s.construct, s.kind, s.app, s.payload_kind)
            gi = interned.get(key)
            if gi is None:
                gi = len(groups)
                interned[key] = gi
                groups.append(InstanceGroup(
                    name=s.construct, base=i, sizes=(), kind=int(kind[i]),
                    app=s.app, payload_kind=s.payload_kind,
                    execution_time=s.execution_time,
                    data_volume=s.data_volume,
                    error_threshold=s.error_threshold, params={}))
            group_idx[i] = gi
            if s.params:
                params[i] = dict(s.params)
            partition[i] = s.partition
            nodes.append(s.node)
        esrc = np.fromiter((uid_map[e[0]] for e in edges), dtype=np.int32,
                           count=len(edges))
        edst = np.fromiter((uid_map[e[1]] for e in edges), dtype=np.int32,
                           count=len(edges))
        estr = np.fromiter((bool(e[2]) for e in edges), dtype=bool,
                           count=len(edges))
        pgt = cls(name, groups, kind, ex, vol, esrc, edst, estr, err_arr=err,
                  uids=uids, oids=oids, group_idx=group_idx,
                  validate_dag=validate_dag)
        pgt._uid_map = uid_map
        pgt.partition = partition
        pgt._params_override = params
        for i, nd in enumerate(nodes):
            if nd is not None:
                pgt.set_node(i, nd)
        return pgt

    @classmethod
    def from_dict_pgt(cls, pgt: Any) -> "CompiledPGT":
        """Convert a legacy dict-based ``PhysicalGraphTemplate``."""
        return cls.from_specs(pgt.name, list(pgt.drops.values()),
                              list(pgt.edges))

    # ------------------------------------------------------------------
    # per-drop derived metadata
    # ------------------------------------------------------------------
    def group_of(self, idx: int) -> InstanceGroup:
        if self._group_idx is not None:
            return self.groups[int(self._group_idx[idx])]
        g = bisect.bisect_right(self._group_bases, idx) - 1
        return self.groups[g]

    def uid_of(self, idx: int) -> str:
        if self._uids is not None:
            return self._uids[idx]
        g = self.group_of(idx)
        return _uid_str(g.name, g.oid_of(idx - g.base))

    def oid_of(self, idx: int) -> Tuple[int, ...]:
        if self._oids is not None:
            return self._oids[idx]
        g = self.group_of(idx)
        return g.oid_of(idx - g.base)

    def app_of(self, idx: int) -> Optional[str]:
        return self.group_of(idx).app

    def params_of(self, idx: int) -> Dict[str, Any]:
        p = self._params_override.get(idx)
        if p is not None:
            return p
        # transient copy: nothing is retained unless the caller mutates it
        # (_LazyParams registers itself on first write) — million-drop
        # read-only passes (save_pgt) stay O(1) in retained memory
        return _LazyParams(self, idx, self.group_of(idx).params)

    def index_of(self, uid: str) -> int:
        if self._uids is not None and self._uid_map is None:
            self._uid_map = {u: i for i, u in enumerate(self._uids)}
        if self._uid_map is not None:
            try:
                return self._uid_map[uid]
            except KeyError:
                raise KeyError(uid) from None
        name, _, coord_s = uid.partition("#")
        g = self._group_by_name.get(name)
        if g is None:
            raise KeyError(uid)
        if not coord_s:
            if g.sizes:
                raise KeyError(uid)
            return g.base
        try:
            oid = tuple(int(c) for c in coord_s.split("."))
        except ValueError:
            raise KeyError(uid) from None
        if len(oid) != len(g.sizes) or any(
                i < 0 or i >= s for i, s in zip(oid, g.sizes)):
            raise KeyError(uid)
        return g.base + g.local_of(oid)

    def set_node(self, idx: int, node: Optional[str]) -> None:
        self.node_ids[idx] = -1 if node is None else self.node_id_for(node)

    def node_id_for(self, node: str) -> int:
        nid = self._node_id_of.get(node)
        if nid is None:
            nid = len(self.node_names)
            self.node_names.append(node)
            self._node_id_of[node] = nid
        return nid

    # ------------------------------------------------------------------
    # dict-compatible API (lazy views)
    # ------------------------------------------------------------------
    @property
    def drops(self) -> DropsView:
        return DropsView(self)

    @property
    def edges(self) -> EdgesView:
        return EdgesView(self)

    def __len__(self) -> int:
        return self.num_drops

    def successors(self, uid: Union[str, int]) -> List[str]:
        idx = uid if isinstance(uid, int) else self.index_of(uid)
        indptr, cols = self.out_csr()
        return [self.uid_of(int(c))
                for c in cols[indptr[idx]:indptr[idx + 1]]]

    def predecessors(self, uid: Union[str, int]) -> List[str]:
        idx = uid if isinstance(uid, int) else self.index_of(uid)
        indptr, cols = self.in_csr()
        return [self.uid_of(int(c))
                for c in cols[indptr[idx]:indptr[idx + 1]]]

    def roots(self) -> List[str]:
        return [self.uid_of(int(i)) for i in self.root_ids()]

    def topological_order(self) -> List[str]:
        return [self.uid_of(int(i)) for i in self.topological_order_ids()]

    # ------------------------------------------------------------------
    # vectorized graph kernels
    # ------------------------------------------------------------------
    def out_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """(indptr, dst_ids) adjacency sorted by source drop id."""
        indptr, cols, _ = self.out_csr_with_eid()
        return indptr, cols

    def out_csr_with_eid(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr, dst_ids, edge_ids): CSR plus the COO->CSR permutation,
        so per-edge attributes (cost, streaming) can be gathered in CSR
        order without re-sorting."""
        if self._out is None:
            self._out = coo_to_csr(self.num_drops, self.edge_src,
                                   self.edge_dst)
        return self._out

    def in_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """(indptr, src_ids) adjacency sorted by destination drop id."""
        indptr, cols, _ = self.in_csr_with_eid()
        return indptr, cols

    def in_csr_with_eid(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr, src_ids, edge_ids): reverse CSR plus the COO->CSR
        permutation, so per-edge attributes (streaming) can be gathered in
        incoming order — what the frontier scheduler consumes."""
        if self._in_eid is None:
            self._in_eid = coo_to_csr(self.num_drops, self.edge_dst,
                                      self.edge_src)
        return self._in_eid

    def has_streaming_edges(self) -> bool:
        """Whether any edge carries the streaming flag (cached — the
        frontier scheduler checks this per run, and templates share one
        pgt across many sessions)."""
        if self._has_streaming is None:
            self._has_streaming = bool(self.edge_streaming.any())
        return self._has_streaming

    def in_degrees(self) -> np.ndarray:
        """Per-drop incoming edge count (the frontier scheduler's
        ``pending_inputs`` seed)."""
        if self._indeg is None:
            # int32: in-degree <= num_edges, which the construction guard
            # bounds to int32 range (halves the 10M tier's counter memory)
            self._indeg = np.bincount(
                self.edge_dst, minlength=self.num_drops).astype(np.int32)
        return self._indeg

    def group_idx_arr(self) -> np.ndarray:
        """Per-drop index into ``self.groups`` as a flat int32 array.

        Memoised into ``_group_idx`` (``group_of`` then uses the direct
        lookup instead of bisect — same mapping, derived from the
        contiguous group bases)."""
        if self._group_idx is None:
            counts = np.fromiter((g.count for g in self.groups),
                                 dtype=np.int64, count=len(self.groups))
            self._group_idx = np.repeat(
                np.arange(len(self.groups), dtype=np.int32), counts)
        return self._group_idx

    def root_ids(self) -> np.ndarray:
        return np.flatnonzero(self.in_degrees() == 0)

    def topological_order_ids(self) -> np.ndarray:
        if self._order is None:
            if self._levels is not None:
                # level-major, ascending id within a level — exactly the
                # frontier order the vectorized Kahn emits
                self._order = np.argsort(self._levels, kind="stable")
            else:
                self._order, self._levels = _kahn_levels(
                    self.num_drops, self.edge_src, self.edge_dst)
        return self._order

    def topo_levels(self) -> np.ndarray:
        """Longest-path depth of every drop (vectorized Kahn)."""
        if self._levels is None:
            self.topological_order_ids()
        return self._levels  # type: ignore[return-value]

    def partition_index(self) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Sentinel-shifted dense partition index for bincount aggregation.

        Unassigned drops carry a negative sentinel partition (-1); like the
        dict path, the sentinel is a partition key in its own right.
        Returns ``(part, idx, shift, span)`` with ``idx = part + shift``
        guaranteed non-negative and ``span = idx.max() + 1``.
        """
        part = self.partition.astype(np.int64)
        if part.size == 0:
            return part, part, 0, 0
        shift = -int(min(part.min(), 0))
        idx = part + shift
        return part, idx, shift, int(idx.max()) + 1

    def partition_loads(
            self, weights: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(partition ids, per-partition aggregate of ``weights``) for all
        partitions that actually occur (drop count when weights is None)."""
        _, idx, shift, span = self.partition_index()
        if span == 0:
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.float64))
        counts = np.bincount(idx, minlength=span)
        present = counts > 0
        ids = np.flatnonzero(present) - shift
        if weights is None:
            agg = counts[present].astype(np.float64)
        else:
            agg = np.bincount(idx, weights=weights,
                              minlength=span)[present]
        return ids, agg

    def edge_volumes(self) -> np.ndarray:
        """Per-edge moved bytes: src volume for data sources, else dst's.
        Memoised — translate evaluates it once for the merge order and
        once per scheduling-array extraction."""
        if self._evol is None:
            if not self.vol_arr.any():
                self._evol = np.zeros(self.num_edges, dtype=np.float64)
            else:
                src_is_data = self.kind_arr[self.edge_src] == KIND_DATA
                self._evol = np.where(src_is_data,
                                      self.vol_arr[self.edge_src],
                                      self.vol_arr[self.edge_dst])
        return self._evol

    def partition_graph_arrays(self) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray, np.ndarray,
                                              np.ndarray, np.ndarray,
                                              np.ndarray]:
        """The partition-level graph as flat arrays (the mapper's input).

        Returns ``(ids, load, mem, count, eu, ev, ew)``:

        * ``ids``   — the partition labels that occur, sorted (the sentinel
          ``-1`` of unassigned drops is a partition key in its own right),
        * ``load`` / ``mem`` / ``count`` — per-partition aggregate app
          weight, data volume and drop count (``np.bincount`` over the
          sentinel-shifted dense index),
        * ``eu`` / ``ev`` / ``ew`` — the undirected partition-graph edge
          list: unique cross-partition pairs as indices into ``ids``
          (``eu < ev``) with summed edge volumes.

        One pass of bincounts + one ``np.unique`` over the cross edges —
        no per-partition or per-edge Python, which is what lets the
        mapper keep up with million-drop graphs.
        """
        _, idx, shift, span = self.partition_index()
        _check_int32_capacity(span, self.num_edges,
                              f"partition_graph_arrays({self.name!r})")
        if span == 0:
            e = np.empty(0, dtype=np.int64)
            z = np.empty(0, dtype=np.float64)
            return e, z, z.copy(), e.copy(), e.copy(), e.copy(), z.copy()
        counts_all = np.bincount(idx, minlength=span)
        present = counts_all > 0
        ids = np.flatnonzero(present) - shift
        load = np.bincount(idx, weights=self.weight_arr,
                           minlength=span)[present]
        mem = np.bincount(
            idx, weights=np.where(self.kind_arr == KIND_DATA,
                                  self.vol_arr, 0.0),
            minlength=span)[present]
        count = counts_all[present].astype(np.int64)
        npart = int(ids.size)
        dense = np.cumsum(present) - 1          # span -> dense index
        if self.num_edges:
            ps = dense[idx[self.edge_src]]
            pd = dense[idx[self.edge_dst]]
            cross = ps != pd
        else:
            cross = np.zeros(0, dtype=bool)
        if cross.any():
            lo = np.minimum(ps[cross], pd[cross]).astype(np.int64)
            hi = np.maximum(ps[cross], pd[cross]).astype(np.int64)
            key = lo * np.int64(npart) + hi
            uniq, inv = np.unique(key, return_inverse=True)
            ew = np.bincount(inv, weights=self.edge_volumes()[cross])
            eu = uniq // npart
            ev = uniq % npart
        else:
            eu = ev = np.empty(0, dtype=np.int64)
            ew = np.empty(0, dtype=np.float64)
        return ids, load, mem, count, eu, ev, ew


def _kahn_levels(n: int, esrc: np.ndarray,
                 edst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized level-synchronous Kahn: (topo order, longest-path level).

    Each round processes the whole zero-indegree frontier with numpy
    gathers, so the Python loop runs once per DAG *level*, not per node —
    and per-round work is proportional to the frontier's out-edges, not to
    the graph (deep graphs like unrolled loops have many small levels; a
    full-width bincount per level would make validation O(levels * n)).
    Raises on cycles.
    """
    if n == 0:
        return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int32))
    indeg = np.bincount(edst, minlength=n).astype(np.int32)
    order_e = np.argsort(esrc, kind="stable")
    sorted_dst = edst[order_e]
    counts = np.bincount(esrc, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    # int32 levels: the level count is bounded by the drop count, which
    # the construction guard keeps within int32 range
    levels = np.full(n, -1, dtype=np.int32)
    chunks: List[np.ndarray] = []
    frontier = np.flatnonzero(indeg == 0)
    level = 0
    done = 0
    while frontier.size:
        levels[frontier] = level
        chunks.append(frontier)
        done += frontier.size
        starts = indptr[frontier]
        cnt = indptr[frontier + 1] - starts
        total = int(cnt.sum())
        indeg[frontier] = -1          # mark processed
        if total:
            # grouped arange: positions of every out-edge of the frontier
            reps = np.repeat(starts - np.concatenate(
                ([0], np.cumsum(cnt)[:-1])), cnt)
            pos = np.arange(total, dtype=np.int64) + reps
            succ = sorted_dst[pos]
            if total < n >> 4:
                np.subtract.at(indeg, succ, 1)
                # only decremented nodes can have reached zero; unique
                # keeps the frontier sorted like flatnonzero would
                frontier = np.unique(succ[indeg[succ] == 0])
            else:
                indeg -= np.bincount(succ, minlength=n)
                frontier = np.flatnonzero(indeg == 0)
        else:
            frontier = np.empty(0, dtype=np.int64)
        level += 1
    if done != n:
        raise GraphValidationError("physical graph contains a cycle")
    return np.concatenate(chunks), levels
