"""Fault tolerance: stragglers, node failure, retries (paper §3.6 + §7).

The paper ships event-propagated failure with the error-tolerance threshold
``t`` (implemented in ``drop.AppDrop``) and lists node-failure migration as
future work ("dynamically migrating Drops from failed nodes to healthy ones
... in order to resume their execution there").  We implement it, plus
speculative straggler re-execution — both required for 1000+-node operation.

Recovery is lineage-based and safe because payloads are write-once: any lost
Drop can be reconstructed by re-running its producers, recursively, until
durable (file-backed) or surviving payloads are reached.
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Set

from .drop import (AppDrop, AppState, DataDrop, Drop, DropState,
                   FilePayload, MemoryPayload)
from .managers import MasterDropManager, NodeDropManager
from .mapping import NodeInfo
from .session import Session
from .unroll import PhysicalGraphTemplate


# ---------------------------------------------------------------------------
# Retry wrapper
# ---------------------------------------------------------------------------


def with_retries(fn: Callable, max_attempts: int = 3,
                 backoff: float = 0.0) -> Callable:
    """Wrap an app function with bounded retries (transient-failure guard)."""

    def wrapped(inputs: List[DataDrop], outputs: List[DataDrop],
                app: AppDrop) -> None:
        last: Optional[BaseException] = None
        for attempt in range(max_attempts):
            try:
                return fn(inputs, outputs, app)
            except Exception as exc:  # noqa: BLE001
                last = exc
                app.meta["retries"] = attempt + 1
                if backoff and attempt + 1 < max_attempts:
                    # back off only between attempts — sleeping after the
                    # final failure just delays the re-raise
                    time.sleep(backoff * (2 ** attempt))
        raise last  # type: ignore[misc]

    return wrapped


# ---------------------------------------------------------------------------
# Straggler mitigation — speculative re-execution
# ---------------------------------------------------------------------------


class StragglerWatcher:
    """Monitors RUNNING app drops; duplicates ones slower than
    ``factor`` x median completed duration.  First finisher commits; the
    loser's commit is a guarded no-op (requires idempotent apps — true for
    pure functions, which all JAX steps are)."""

    def __init__(self, session: Session, master: MasterDropManager,
                 factor: float = 3.0, min_runtime: float = 0.05,
                 poll: float = 0.02) -> None:
        self.session = session
        self.master = master
        self.factor = factor
        self.min_runtime = min_runtime
        self.poll = poll
        self.speculated: Set[str] = set()
        self.wins = 0
        self._stop = threading.Event()
        self._rr = 0                      # round-robin tie-break cursor
        self._started: Dict[str, float] = {}
        self._thread = threading.Thread(target=self._run, daemon=True)
        session.bus.subscribe_all(self._on_event)

    def _on_event(self, ev) -> None:
        if ev.type == "execStatus" and ev.data.get("status") == "RUNNING":
            self._started.setdefault(ev.source_uid, time.monotonic())

    def start(self) -> "StragglerWatcher":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()

    def _median_duration(self) -> Optional[float]:
        durs = [d.run_duration for d in self.session.drops.values()
                if isinstance(d, AppDrop) and d.run_duration is not None]
        return statistics.median(durs) if len(durs) >= 3 else None

    def _run(self) -> None:
        while not self._stop.is_set():
            self._stop.wait(self.poll)
            med = self._median_duration()
            if med is None:
                continue
            now = time.monotonic()
            threshold = max(self.factor * med, self.min_runtime)
            for uid, t0 in list(self._started.items()):
                if uid in self.speculated:
                    continue
                d = self.session.drops.get(uid)
                if (isinstance(d, AppDrop)
                        and d.exec_state is AppState.RUNNING
                        and now - t0 > threshold):
                    self.speculated.add(uid)
                    self._speculate(d)

    def _speculate(self, app: AppDrop) -> None:
        """Run a duplicate on the least-loaded other live node (round-robin
        among ties — always picking ``nms[0]`` piled every duplicate onto
        one node and made *it* the next straggler)."""
        nms = [nm for nm in self.master.node_managers().values()
               if nm.info.alive and nm.name != app.node]
        target = self._pick_target(nms)

        def dup() -> None:
            try:
                ok_inputs = [d for d in app.inputs
                             if d.state is DropState.COMPLETED]
                if app.func is not None:
                    app.func(ok_inputs, list(app.outputs), app)
                committed = app.commit_speculative()
                if committed:
                    self.wins += 1
            except Exception:  # noqa: BLE001 - loser may race on payloads
                pass

        if target is not None:
            target.executor.submit(dup)
        else:
            threading.Thread(target=dup, daemon=True).start()

    def _pick_target(self, nms: List[NodeDropManager]
                     ) -> Optional[NodeDropManager]:
        """Least-loaded candidate (RUNNING apps placed on it), rotating
        through ties so duplicates spread across equally-idle nodes."""
        if not nms:
            return None
        loads: Dict[str, int] = {}
        for d in self.session.drops.values():
            if (isinstance(d, AppDrop)
                    and d.exec_state is AppState.RUNNING and d.node):
                loads[d.node] = loads.get(d.node, 0) + 1
        low = min(loads.get(nm.name, 0) for nm in nms)
        tied = [nm for nm in nms if loads.get(nm.name, 0) == low]
        pick = tied[self._rr % len(tied)]
        self._rr += 1
        return pick


# ---------------------------------------------------------------------------
# Node failure + lineage recovery (paper §7 future work, implemented)
# ---------------------------------------------------------------------------


class FaultManager:
    def __init__(self, session: Session, pgt: PhysicalGraphTemplate,
                 master: MasterDropManager) -> None:
        self.session = session
        self.pgt = pgt
        self.master = master
        self.recovered: List[str] = []

    def fail_node(self, node: str) -> None:
        nm = self.master.node_managers()[node]
        nm.fail()

    def recover(self) -> List[str]:
        """Migrate Drops off dead nodes and re-execute lost lineage.

        1. Find drops placed on dead nodes.
        2. Lost set = non-terminal drops there + COMPLETED *memory* payload
           data drops there (memory died with the node).  File payloads
           survive (shared/durable storage).
        3. Extend the lost set upstream: a lost data drop's producers must
           re-run; extend downstream: consumers that already used lost data
           are fine (write-once), but not-yet-run consumers just wait.
        4. Re-map lost drops onto live nodes, reset state, re-trigger.
        """
        dead = {n for n, nm in self.master.node_managers().items()
                if not nm.info.alive}
        if not dead:
            return []
        lost: Set[str] = set()
        for uid, drop in self.session.drops.items():
            if drop.node not in dead:
                continue
            if (isinstance(drop, DataDrop) and not drop.producers):
                # root data drops are pipeline INPUTS: durable by contract
                # (they come from external storage, not from a producer we
                # could re-run).  Never reset them.
                continue
            if drop.state in (DropState.COMPLETED,):
                if (isinstance(drop, DataDrop)
                        and isinstance(drop.payload, MemoryPayload)):
                    lost.add(uid)          # volatile payload lost
                elif isinstance(drop, AppDrop):
                    pass                   # finished app: nothing to lose
            elif drop.state in (DropState.ERROR, DropState.CANCELLED,
                                DropState.SKIPPED, DropState.EXPIRED,
                                DropState.DELETED):
                pass
            else:
                lost.add(uid)              # was pending/running there

        # upstream closure: to recompute a lost data drop we re-run its
        # producers; a producer needs ITS inputs present - recurse on any
        # input whose payload is itself gone.
        frontier = list(lost)
        while frontier:
            uid = frontier.pop()
            drop = self.session.drops[uid]
            if isinstance(drop, DataDrop):
                for prod in drop.producers:
                    if prod.uid not in lost:
                        lost.add(prod.uid)
                        frontier.append(prod.uid)
            else:
                for inp in drop.inputs:  # type: ignore[union-attr]
                    payload_ok = (inp.state is DropState.COMPLETED
                                  and inp.payload.exists()
                                  and inp.node not in dead) or \
                                 (inp.state is DropState.COMPLETED
                                  and isinstance(inp.payload, FilePayload)
                                  and inp.payload.exists()) or \
                                 (not inp.producers)   # roots are durable
                    if not payload_ok and inp.uid not in lost:
                        lost.add(inp.uid)
                        frontier.append(inp.uid)

        # choose live nodes round-robin for migration
        live = [n for n, nm in self.master.node_managers().items()
                if nm.info.alive]
        if not live:
            raise RuntimeError("no live nodes left to migrate onto")
        nms = self.master.node_managers()

        for i, uid in enumerate(sorted(lost)):
            drop = self.session.drops[uid]
            target = live[i % len(live)]
            drop.node = target
            if isinstance(drop, AppDrop):
                drop.exec_state = AppState.NOT_RUN
                drop._state = DropState.INITIALIZED
                drop._resolved = {
                    u: e for u, e in drop._resolved.items()
                    if u not in lost}
                drop._executor = nms[target].executor
            else:
                assert isinstance(drop, DataDrop)
                drop._state = DropState.INITIALIZED
                drop.payload = type(drop.payload)() \
                    if isinstance(drop.payload, MemoryPayload) \
                    else drop.payload
                drop._finished_producers = sum(
                    1 for p in drop.producers if p.uid not in lost
                    and p.state is DropState.COMPLETED)
                drop._errored_producers = sum(
                    1 for p in drop.producers if p.uid not in lost
                    and p.state is DropState.ERROR)
            self.recovered.append(uid)

        # also: downstream apps that were waiting on lost drops must forget
        # their resolution record for them
        for uid, drop in self.session.drops.items():
            if isinstance(drop, AppDrop) and uid not in lost \
                    and drop.exec_state is AppState.NOT_RUN:
                for lost_uid in lost:
                    drop._resolved.pop(lost_uid, None)

        # the session is live again: clear its finished latch
        self.session.reopen()

        # re-trigger: completed surviving inputs re-fire to migrated apps;
        # migrated roots restart.
        for uid in sorted(lost):
            drop = self.session.drops[uid]
            if isinstance(drop, AppDrop):
                if not drop.inputs and not drop.streaming_inputs:
                    drop.trigger_root()
                else:
                    for inp in drop.inputs:
                        if inp.state is DropState.COMPLETED:
                            drop.on_input_completed(inp)
            else:
                assert isinstance(drop, DataDrop)
                if not drop.producers:
                    drop.set_completed()
        return self.recovered


# ---------------------------------------------------------------------------
# Elastic scaling — re-map a PGT onto a changed node set (beyond paper)
# ---------------------------------------------------------------------------


def elastic_remap(pgt: PhysicalGraphTemplate,
                  nodes: Sequence[NodeInfo]) -> Dict[int, str]:
    """Re-run the resource-mapping stage on the current live node set.

    Because the PGT partitioning stage is resource-oblivious (paper's
    two-phase scheduling), scaling up/down only repeats the cheap mapping
    step — this is the paper's decoupling paying off at run time.
    """
    from .mapping import map_partitions
    return map_partitions(pgt, [n for n in nodes if n.alive])
