"""Data Lifecycle Management (paper §1 item 4, §4.3).

"It integrates a data lifecycle management component within the execution
engine, keeping track of Drops and migrating or deleting them automatically
when necessary."

The DLM watches COMPLETED Data Drops: after their ``lifetime`` elapses they
EXPIRE (further reads denied) and are then DELETED (payload reclaimed).
Drops flagged ``persist`` are spilled from memory to durable storage before
their volatile payload is reclaimed (the "migrating" case).
"""
from __future__ import annotations

import threading
import time
from pathlib import Path
from typing import Dict, List, Optional

from .drop import DataDrop, DropState, FilePayload, MemoryPayload
from .session import Session


class DataLifecycleManager:
    def __init__(self, session: Session, poll: float = 0.02,
                 spill_dir: str = "/tmp/repro_dlm") -> None:
        self.session = session
        self.poll = poll
        self.spill_dir = Path(spill_dir)
        self.expired: List[str] = []
        self.deleted: List[str] = []
        self.persisted: List[str] = []
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self) -> "DataLifecycleManager":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

    def sweep(self, now: Optional[float] = None) -> None:
        """One pass over all data drops (also callable synchronously)."""
        now = time.monotonic() if now is None else now
        for uid, drop in list(self.session.drops.items()):
            if not isinstance(drop, DataDrop):
                continue
            if drop.state is DropState.COMPLETED:
                if drop.meta.get("persist") and uid not in self.persisted:
                    self._persist(drop)
                if (drop.lifetime is not None and drop.completed_at is not None
                        and now - drop.completed_at >= drop.lifetime):
                    drop.expire()
                    self.expired.append(uid)
            elif drop.state is DropState.EXPIRED:
                drop.payload.delete()
                drop.delete()
                self.deleted.append(uid)

    def _persist(self, drop: DataDrop) -> None:
        """Migrate a volatile payload to durable storage (spill)."""
        if isinstance(drop.payload, FilePayload):
            self.persisted.append(drop.uid)
            return
        if not isinstance(drop.payload, MemoryPayload):
            return
        self.spill_dir.mkdir(parents=True, exist_ok=True)
        tgt = FilePayload(str(self.spill_dir /
                              f"{drop.uid.replace('/', '_')}.pkl"))
        try:
            tgt.write(drop.payload.read())
            tgt.seal()
            drop.meta["spilled_to"] = tgt.data_url
            self.persisted.append(drop.uid)
        except Exception:  # noqa: BLE001 - persistence is best-effort
            pass

    def _run(self) -> None:
        while not self._stop.is_set():
            self.sweep()
            self._stop.wait(self.poll)
