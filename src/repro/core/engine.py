"""Engine facade — the six-stage pipeline in one object (paper Fig. 1).

``Pipeline`` wires the stages together:

  compose (LGT) -> parametrise (LG) -> translate (unroll+partition, PGT)
  -> deploy (map+managers, PG) -> execute (data-activated cascade)

Each stage is independently accessible (the separation of concerns the paper
insists on); this facade is what examples, the training launcher and the
benchmarks use.
"""
from __future__ import annotations

import time
import uuid
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from .config import EngineConfig, config_from_kwargs
from .fault import FaultManager, StragglerWatcher
from .lifecycle import DataLifecycleManager
from .logical import LogicalGraph, LogicalGraphTemplate
from .managers import MasterDropManager, make_cluster
from .mapping import NodeInfo, map_partitions
from .pgt import CompiledPGT
from .resilience import (CompiledFaultManager, ResilienceConfig,
                         execute_resilient)
from .session import CompiledSession, Session, SessionState
from .telemetry import (MetricsRegistry, Span, TelemetryConfig,
                        export_chrome_trace)
from .templates import GraphTemplate, translate_lg
from .unroll import PhysicalGraphTemplate


@dataclass
class ExecutionReport:
    session_id: str
    state: str
    status_counts: Dict[str, int]
    wall_time: float
    events_published: int
    errors: List[str] = field(default_factory=list)
    speculative_wins: int = 0
    recoveries: int = 0            # node-failure recovery passes
    recovered_drops: int = 0       # drops reset + remapped across passes
    retries: int = 0               # dispatch-layer re-attempts

    @property
    def ok(self) -> bool:
        return (self.state == SessionState.FINISHED.value
                and not self.errors)

    def overhead_per_drop_us(self, payload_time: float = 0.0) -> float:
        n = sum(self.status_counts.values())
        return 1e6 * max(self.wall_time - payload_time, 0.0) / max(n, 1)


class Pipeline:
    """End-to-end driver for one logical graph on one cluster.

    ``execution`` selects the deploy+execute substrate:

    * ``"objects"`` — one Python ``Drop`` per graph node, event-driven
      (the paper's engine; the semantic oracle),
    * ``"compiled"`` — array-native: batched deploy over ``CompiledPGT``
      index slices + the frontier scheduler
      (:mod:`repro.core.exec_compiled`).  Same ``ExecutionReport``, no
      per-drop Python objects; DLM/straggler services require drop
      objects and are rejected.
    """

    def __init__(self, config: Optional[EngineConfig] = None,
                 **legacy: Any) -> None:
        if config is not None:
            if legacy:
                raise TypeError(
                    "pass either an EngineConfig or legacy keyword "
                    "arguments, not both")
            if not isinstance(config, EngineConfig):
                raise TypeError(
                    f"config must be an EngineConfig, got "
                    f"{type(config).__name__}")
            config.validate()
        else:
            if legacy:
                warnings.warn(
                    "Pipeline(**kwargs) is deprecated; pass "
                    "Pipeline(EngineConfig(...)) (repro.core.config)",
                    DeprecationWarning, stacklevel=2)
            config = config_from_kwargs(**legacy)
        self.config = config
        manager = config.manager
        if manager is not None:
            # ride a resident EngineManager: shared cluster + executors
            # + template cache; the Pipeline becomes a thin per-run view
            self.master, self.nodes = manager.master, manager.nodes
            self._owns_cluster = False
        else:
            self.master, self.nodes = make_cluster(
                config.num_nodes, config.num_islands,
                config.workers_per_node, workers=config.workers)
            self._owns_cluster = True
        # mutable working copies — benchmarks and tests tune these on a
        # built Pipeline (e.g. ``p.resilience = ResilienceConfig(...)``);
        # the frozen config records what was requested at construction
        self.manager = manager
        self.dop = config.dop
        self.algorithm = config.algorithm
        self.deadline = config.deadline
        self.enable_dlm = config.enable_dlm
        self.enable_stragglers = config.enable_stragglers
        self.execution = config.execution
        self.resilience = config.resilience
        self.stream = config.stream
        self.pgt: Optional[PhysicalGraphTemplate] = None
        self._template: Optional[GraphTemplate] = None
        self.session: Optional[Session] = None
        # FaultManager (objects) or CompiledFaultManager (compiled)
        self.fault_manager: Any = None
        self.translate_time = 0.0
        self.deploy_time = 0.0
        self.map_time = 0.0        # partition->node mapping share of deploy
        # telemetry: inherit the manager's config/registry when riding a
        # resident EngineManager (one registry per service, not per run)
        if config.telemetry is not None:
            self.telemetry = config.telemetry
        elif manager is not None:
            self.telemetry = manager.telemetry
        else:
            self.telemetry = TelemetryConfig()
        if manager is not None and manager.metrics is not None:
            self.metrics = manager.metrics
        else:
            self.metrics = MetricsRegistry() if self.telemetry.metrics \
                else None
        self.spans: List[Span] = []   # translate/map/deploy/execute

    def _record_span(self, name: str, t0: float) -> None:
        if self.telemetry.spans:
            self.spans.append(Span(name, t0, time.monotonic()))

    # -- stage 4: translate ---------------------------------------------------
    def translate(self, lg: LogicalGraph) -> PhysicalGraphTemplate:
        t0 = time.monotonic()
        if self.manager is not None:
            # resident path: translate+map once per shape, cached by
            # structural hash — repeated runs of the same LG skip both
            self._template = self.manager.get_template(
                lg, algorithm=self.algorithm, dop=self.dop,
                deadline=self.deadline)
            pgt = self._template.pgt
        else:
            self._template = None
            pgt = translate_lg(lg, algorithm=self.algorithm, dop=self.dop,
                               deadline=self.deadline)
        self.translate_time = time.monotonic() - t0
        self._record_span("translate", t0)
        self.pgt = pgt
        return pgt

    # -- stage 5: deploy ---------------------------------------------------------
    def deploy(self, pgt: Optional[PhysicalGraphTemplate] = None,
               session_id: Optional[str] = None) -> Session:
        supplied = pgt is not None
        pgt = pgt or self.pgt
        assert pgt is not None, "translate() first"
        t0 = time.monotonic()
        if (self._template is not None
                and pgt is self._template.pgt):
            # manager path: the template is already mapped and carries the
            # per-node slices — materialize is O(drops), no map, no argsort
            self.map_time = 0.0
            session = self._template.materialize(
                session_id or f"s-{uuid.uuid4().hex[:8]}",
                master=self.master)
            self.fault_manager = None
        elif self.execution == "compiled":
            if not isinstance(pgt, CompiledPGT):
                # translate() always yields a CompiledPGT now (loop-carried
                # graphs included); this lift only remains for explicitly
                # supplied dict PGTs, e.g. hand-built or deserialised ones
                # (only replace self.pgt when it IS the graph being lifted)
                pgt = CompiledPGT.from_dict_pgt(pgt)
                if not supplied:
                    self.pgt = pgt
            tm = time.monotonic()     # map share excludes the dict lift
            map_partitions(pgt, self.nodes)
            self.map_time = time.monotonic() - tm
            self._record_span("map", tm)
            session = CompiledSession(
                session_id or f"s-{uuid.uuid4().hex[:8]}", pgt)
            self.master.deploy_compiled(session, pgt)
            self.fault_manager = CompiledFaultManager(session, self.master)
        else:
            tm = time.monotonic()
            map_partitions(pgt, self.nodes)
            self.map_time = time.monotonic() - tm
            session = self.master.create_session(
                session_id or f"s-{uuid.uuid4().hex[:8]}")
            self.master.deploy(session, pgt)
            self.fault_manager = FaultManager(session, pgt, self.master)
        if isinstance(session, CompiledSession):
            if self.telemetry.timeline:
                session.enable_timeline()
            if self.metrics is not None:
                session.metrics = self.metrics
        self.deploy_time = time.monotonic() - t0
        self._record_span("deploy", t0)
        self.session = session
        return session

    # -- stage 6: execute ----------------------------------------------------------
    def execute(self, timeout: float = 60.0,
                inputs: Optional[Dict[str, Any]] = None,
                hooks: Any = None) -> ExecutionReport:
        """Run the deployed session.

        ``hooks`` (an :class:`~repro.core.exec_compiled.ExecHooks`) is
        honoured on both substrates: the compiled engine threads it into
        the frontier scheduler; the object engine bridges its drop-level
        ``streamChunk`` events onto ``hooks.on_stream_chunk`` so chunk
        observability is engine-portable.
        """
        assert self.session is not None, "deploy() first"
        session = self.session
        if isinstance(session, CompiledSession):
            return self._execute_compiled(session, timeout, inputs, hooks)
        on_chunk = getattr(hooks, "on_stream_chunk", None)
        if on_chunk is not None:
            def _bridge(event: Any) -> None:
                if event.type == "streamChunk":
                    on_chunk(session, event.source_uid,
                             event.data["consumer"], event.data["seq"])
            session.bus.subscribe_all(_bridge)
        if inputs:
            from .drop import DataDrop
            for uid, value in inputs.items():
                d = session.drops[uid]
                assert isinstance(d, DataDrop)
                d.write(value)
        dlm = DataLifecycleManager(session).start() if self.enable_dlm \
            else None
        watcher = (StragglerWatcher(session, self.master).start()
                   if self.enable_stragglers else None)
        t0 = time.monotonic()
        session.start()
        finished = session.wait(timeout)
        wall = time.monotonic() - t0
        self._record_span("execute", t0)
        if watcher:
            watcher.stop()
        if dlm:
            dlm.stop()
        errs = [f"{d.uid}: {(d.error_info or '')[:200]}"
                for d in session.errors()]
        return ExecutionReport(
            session_id=session.session_id,
            state=(session.state.value if finished else "TIMEOUT"),
            status_counts=session.status(),
            wall_time=wall,
            events_published=session.bus.published,
            errors=errs,
            speculative_wins=watcher.wins if watcher else 0,
        )

    def _execute_compiled(self, session: CompiledSession, timeout: float,
                          inputs: Optional[Dict[str, Any]],
                          hooks: Any = None) -> ExecutionReport:
        from .exec_compiled import execute_frontier
        if inputs:
            for uid, value in inputs.items():
                session.write(uid, value)
        t0 = time.monotonic()
        if self.resilience is not None:
            finished, stats = execute_resilient(
                session, self.master, self.resilience, timeout=timeout,
                fault_manager=self.fault_manager, hooks=hooks,
                stream=self.stream)
        else:
            executors = (self.manager.executors if self.manager is not None
                         else self.master.node_executors())
            finished = execute_frontier(
                session, timeout=timeout, hooks=hooks,
                executors=executors, stream=self.stream)
            stats = None
        wall = time.monotonic() - t0
        self._record_span("execute", t0)
        errs = [f"{r.uid}: {(r.error_info or '')[:200]}"
                for r in session.errors()]
        return ExecutionReport(
            session_id=session.session_id,
            state=(session.state.value if finished else "TIMEOUT"),
            status_counts=session.status(),
            wall_time=wall,
            events_published=session.bus.published,
            errors=errs,
            speculative_wins=stats.speculative_wins if stats else 0,
            recoveries=stats.recoveries if stats else 0,
            recovered_drops=stats.recovered_drops if stats else 0,
            retries=stats.retries if stats else 0,
        )

    # -- convenience: run everything -----------------------------------------------
    def run(self, lg: LogicalGraph, timeout: float = 60.0,
            inputs: Optional[Dict[str, Any]] = None,
            hooks: Any = None) -> ExecutionReport:
        self.translate(lg)
        self.deploy()
        return self.execute(timeout=timeout, inputs=inputs, hooks=hooks)

    def export_trace(self, path: str) -> Dict[str, int]:
        """Write the last session's Perfetto trace (timeline required);
        pipeline-stage spans ride along on their own track."""
        assert self.session is not None, "run a session first"
        return export_chrome_trace(
            self.session, path, spans=self.spans,
            batch_threshold=self.telemetry.trace_batch_threshold)

    def shutdown(self) -> None:
        # manager-owned clusters outlive any one Pipeline; only the
        # manager's close() may kill the shared node pools
        if self._owns_cluster:
            self.master.shutdown()

    def __enter__(self) -> "Pipeline":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()
