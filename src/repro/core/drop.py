"""Drops — the generalised graph nodes of DALiuGE (paper §4).

Both *data* and *applications* are nodes.  A Drop wraps a generic payload with
lifecycle state, provenance, and event behaviour, "making data virtually
active" (§4).  Payloads are strictly write-once / read-many (§2.3, §4); Drops
themselves are stateful and checkpointable.

State machine (paper Fig. 11)::

    INITIALIZED -> [WRITING] -> COMPLETED -> EXPIRED -> DELETED
                 \\-> ERROR (any I/O or execution error)
                 \\-> CANCELLED / SKIPPED

Application Drops additionally track an execution status
(NOT_RUN -> RUNNING -> FINISHED | ERROR).
"""
from __future__ import annotations

import enum
import pickle
import threading
import time
import traceback
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

from .events import Event, EventBus


class DropState(str, enum.Enum):
    INITIALIZED = "INITIALIZED"
    WRITING = "WRITING"
    COMPLETED = "COMPLETED"
    ERROR = "ERROR"
    EXPIRED = "EXPIRED"
    DELETED = "DELETED"
    CANCELLED = "CANCELLED"
    SKIPPED = "SKIPPED"


class AppState(str, enum.Enum):
    NOT_RUN = "NOT_RUN"
    RUNNING = "RUNNING"
    FINISHED = "FINISHED"
    ERROR = "ERROR"
    CANCELLED = "CANCELLED"
    SKIPPED = "SKIPPED"


_TERMINAL = {DropState.COMPLETED, DropState.ERROR, DropState.CANCELLED,
             DropState.SKIPPED, DropState.EXPIRED, DropState.DELETED}


# ---------------------------------------------------------------------------
# Payloads — write-once / read-many (§4.2 "Drop I/O")
# ---------------------------------------------------------------------------


class PayloadError(RuntimeError):
    pass


def buffer_nbytes(value) -> "int | None":
    """Byte size of a buffer-protocol value (bytes, bytearray, array.array,
    mmap, ...) without serialising it; None for opaque objects."""
    try:
        return memoryview(value).nbytes
    except TypeError:
        return None


class Payload:
    """I/O abstraction over a Drop's data (paper §4.2 option 1).

    open/read/write/close POSIX-style byte/object model.  Write-once:
    a second ``write`` after ``seal`` raises.
    """

    def __init__(self) -> None:
        self._sealed = False
        self._lock = threading.Lock()

    # -- interface ---------------------------------------------------------
    def write(self, value: Any) -> None:
        with self._lock:
            if self._sealed:
                raise PayloadError("payload is write-once and already sealed")
            self._write(value)

    def seal(self) -> None:
        with self._lock:
            self._sealed = True

    @property
    def sealed(self) -> bool:
        return self._sealed

    def read(self) -> Any:
        return self._read()

    def exists(self) -> bool:
        raise NotImplementedError

    def delete(self) -> None:
        raise NotImplementedError

    def nbytes(self) -> int:
        return 0

    @property
    def data_url(self) -> str:
        raise NotImplementedError

    # -- impl hooks ----------------------------------------------------------
    def _write(self, value: Any) -> None:
        raise NotImplementedError

    def _read(self) -> Any:
        raise NotImplementedError


class MemoryPayload(Payload):
    """In-memory payload (paper's InMemoryDataDROP, used by MUSER §6)."""

    def __init__(self) -> None:
        super().__init__()
        self._value: Any = None
        self._present = False

    def _write(self, value: Any) -> None:
        self._value = value
        self._present = True

    def _read(self) -> Any:
        if not self._present:
            raise PayloadError("payload not present")
        return self._value

    def exists(self) -> bool:
        return self._present

    def delete(self) -> None:
        self._value = None
        self._present = False

    def nbytes(self) -> int:
        v = self._value
        if v is None:
            return 0
        if hasattr(v, "nbytes"):
            try:
                return int(v.nbytes)
            except TypeError:
                pass
        n = buffer_nbytes(v)
        if n is not None:
            return n
        try:
            return len(pickle.dumps(v, protocol=pickle.HIGHEST_PROTOCOL))
        except Exception:
            return 0

    @property
    def data_url(self) -> str:
        return f"mem://{id(self):x}"


class FilePayload(Payload):
    """File-backed payload (paper's FileDROP), pickle serialised."""

    def __init__(self, path: str) -> None:
        super().__init__()
        self._path = Path(path)

    def _write(self, value: Any) -> None:
        self._path.parent.mkdir(parents=True, exist_ok=True)
        with open(self._path, "wb") as fh:
            pickle.dump(value, fh, protocol=pickle.HIGHEST_PROTOCOL)

    def _read(self) -> Any:
        if not self._path.exists():
            raise PayloadError(f"{self._path} not present")
        with open(self._path, "rb") as fh:
            return pickle.load(fh)

    def exists(self) -> bool:
        return self._path.exists()

    def delete(self) -> None:
        if self._path.exists():
            self._path.unlink()

    def nbytes(self) -> int:
        return self._path.stat().st_size if self._path.exists() else 0

    @property
    def data_url(self) -> str:
        return f"file://{self._path}"


class NullPayload(Payload):
    """Payload-less Drop (pure barrier / signal)."""

    def _write(self, value: Any) -> None:
        pass

    def _read(self) -> Any:
        return None

    def exists(self) -> bool:
        return True

    def delete(self) -> None:
        pass

    @property
    def data_url(self) -> str:
        return "null://"


def make_payload(kind: str, *, path: Optional[str] = None) -> Payload:
    if kind == "memory":
        return MemoryPayload()
    if kind == "file":
        assert path is not None, "file payload requires a path"
        return FilePayload(path)
    if kind == "null":
        return NullPayload()
    raise ValueError(f"unknown payload kind {kind!r}")


# ---------------------------------------------------------------------------
# Drops
# ---------------------------------------------------------------------------


class Drop:
    """Abstract Drop: uid, state machine, event firing (paper §4, Fig. 9/11)."""

    def __init__(self, uid: str, *, bus: Optional[EventBus] = None,
                 lifetime: Optional[float] = None, node: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None) -> None:
        self.uid = uid
        self.bus = bus or EventBus()
        self.node = node                       # physical placement (set at deploy)
        self.lifetime = lifetime               # seconds until EXPIRED (None = pinned)
        self.meta: Dict[str, Any] = dict(meta or {})
        self._state = DropState.INITIALIZED
        self._state_lock = threading.RLock()
        self.completed_at: Optional[float] = None
        self.error_info: Optional[str] = None

    # -- state ---------------------------------------------------------------
    @property
    def state(self) -> DropState:
        return self._state

    def _set_state(self, new: DropState, **event_data: Any) -> None:
        with self._state_lock:
            if self._state == new:
                return
            if self._state in _TERMINAL and new not in (
                    DropState.EXPIRED, DropState.DELETED):
                # terminal states only advance along the lifecycle tail
                if not (self._state is DropState.COMPLETED and new in
                        (DropState.EXPIRED, DropState.DELETED)):
                    return
            self._state = new
        self.fire("status", status=new.value, **event_data)

    def fire(self, type_: str, **data: Any) -> None:
        self.bus.publish(Event(type=type_, source_uid=self.uid, data=data))

    # -- lifecycle tail (§4.3) ------------------------------------------------
    def expire(self) -> None:
        if self._state is DropState.COMPLETED:
            self._set_state(DropState.EXPIRED)

    def delete(self) -> None:
        if self._state in (DropState.EXPIRED, DropState.COMPLETED,
                           DropState.ERROR):
            self._set_state(DropState.DELETED)

    def cancel(self) -> None:
        if self._state not in _TERMINAL:
            self._set_state(DropState.CANCELLED)

    def skip(self) -> None:
        if self._state not in _TERMINAL:
            self._set_state(DropState.SKIPPED)
            self.fire("dropSkipped")

    # -- checkpointing (Drop state persistence, paper §4) ----------------------
    def to_record(self) -> Dict[str, Any]:
        return {
            "uid": self.uid,
            "type": type(self).__name__,
            "state": self._state.value,
            "node": self.node,
            "completed_at": self.completed_at,
            "error_info": self.error_info,
            "meta": self.meta,
        }

    def restore_record(self, rec: Dict[str, Any]) -> None:
        self._state = DropState(rec["state"])
        self.node = rec.get("node")
        self.completed_at = rec.get("completed_at")
        self.error_info = rec.get("error_info")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.uid} {self._state.value}>"


class DataDrop(Drop):
    """A Data Drop: payload + producers/consumers (paper §4, Fig. 10)."""

    def __init__(self, uid: str, *, payload: Optional[Payload] = None,
                 **kw: Any) -> None:
        super().__init__(uid, **kw)
        self.payload = payload or MemoryPayload()
        self.producers: List["AppDrop"] = []
        self.consumers: List["AppDrop"] = []
        self.streaming_consumers: List["AppDrop"] = []
        self._finished_producers = 0
        self._errored_producers = 0
        self._chunk_seq = 0          # chunks written (streaming fan-out)

    # -- graph wiring ----------------------------------------------------------
    def add_producer(self, app: "AppDrop") -> None:
        if app not in self.producers:
            self.producers.append(app)
            if self not in app.outputs:
                app.outputs.append(self)

    def add_consumer(self, app: "AppDrop", streaming: bool = False) -> None:
        tgt = self.streaming_consumers if streaming else self.consumers
        if app not in tgt:
            tgt.append(app)
            ins = app.streaming_inputs if streaming else app.inputs
            if self not in ins:
                ins.append(self)

    # -- data access -------------------------------------------------------------
    def write(self, value: Any) -> None:
        if self.state not in (DropState.INITIALIZED, DropState.WRITING):
            raise PayloadError(
                f"cannot write drop {self.uid} in state {self.state}")
        self._set_state(DropState.WRITING)
        self.payload.write(value)
        if self.streaming_consumers:
            seq = self._chunk_seq
            self._chunk_seq = seq + 1
            for sc in self.streaming_consumers:
                sc.on_stream_chunk(self, value)
                # one event per delivery: the hooks bridge
                # (Pipeline.execute on_stream_chunk) subscribes to these
                self.fire("streamChunk", consumer=sc.uid, seq=seq)

    def read(self) -> Any:
        if self.state in (DropState.EXPIRED, DropState.DELETED):
            raise PayloadError(f"drop {self.uid} expired/deleted; read denied")
        return self.payload.read()

    @property
    def data_url(self) -> str:
        return self.payload.data_url

    def nbytes(self) -> int:
        return self.payload.nbytes()

    # -- event-driven completion (§3.6) ------------------------------------------
    def set_completed(self) -> None:
        """Mark payload fully present -> COMPLETED; notify consumers."""
        if self.state in _TERMINAL:
            return
        self.payload.seal()
        self.completed_at = time.monotonic()
        self._set_state(DropState.COMPLETED)
        self.fire("dropCompleted")
        for c in list(self.consumers):
            c.on_input_completed(self)
        for sc in list(self.streaming_consumers):
            sc.on_input_completed(self)

    def set_error(self, info: str = "") -> None:
        if self.state in _TERMINAL:
            return
        self.error_info = info
        self._set_state(DropState.ERROR)
        self.fire("dropError", info=info)
        for c in list(self.consumers) + list(self.streaming_consumers):
            c.on_input_error(self)

    def on_producer_finished(self, app: "AppDrop") -> None:
        """Paper §3.6: a data Drop completes once ALL its producers finish."""
        with self._state_lock:
            self._finished_producers += 1
            done = (self._finished_producers + self._errored_producers
                    >= len(self.producers))
        if done:
            self.set_completed()

    def on_producer_error(self, app: "AppDrop") -> None:
        """§3.6: Data Drops move to ERROR if ANY of their producers error."""
        with self._state_lock:
            self._errored_producers += 1
        self.set_error(f"producer {app.uid} errored")

    def to_record(self) -> Dict[str, Any]:
        rec = super().to_record()
        rec.update(
            finished_producers=self._finished_producers,
            errored_producers=self._errored_producers,
            data_url=self.data_url,
            payload_sealed=self.payload.sealed,
        )
        return rec

    def restore_record(self, rec: Dict[str, Any]) -> None:
        super().restore_record(rec)
        self._finished_producers = rec.get("finished_producers", 0)
        self._errored_producers = rec.get("errored_producers", 0)
        if rec.get("payload_sealed"):
            self.payload.seal()


def _drop_order_key(d: "Drop"):
    oid = d.meta.get("oid")
    return (tuple(oid) if oid else (), d.uid)


class AppDrop(Drop):
    """An Application Drop: a stateless task in a stateful wrapper (paper §3, §4).

    Batch semantics (§3.6): waits until every input is resolved
    (COMPLETED or ERROR); runs if the errored fraction is <= the
    error-tolerance threshold ``t`` (Fig. 7), else moves to ERROR.
    """

    def __init__(self, uid: str, func: Optional[Callable[..., Any]] = None, *,
                 error_threshold: float = 0.0, executor: Optional[Any] = None,
                 **kw: Any) -> None:
        super().__init__(uid, **kw)
        self.func = func
        self.error_threshold = float(error_threshold)   # t in the paper
        # per-drop scratch for streaming chunk handlers (cross-chunk
        # accumulation between on_stream_chunk calls; the compiled
        # engine's _StreamAppRef mirrors it)
        self.scratch: Dict[str, Any] = {}
        self.inputs: List[DataDrop] = []
        self.streaming_inputs: List[DataDrop] = []
        self.outputs: List[DataDrop] = []
        self.exec_state = AppState.NOT_RUN
        self._resolved: Dict[str, bool] = {}   # uid -> errored?
        self._exec_lock = threading.Lock()
        self._executor = executor               # set by the NodeDropManager
        self.run_duration: Optional[float] = None
        self.attempts = 0

    # -- graph wiring ------------------------------------------------------------
    def add_input(self, d: DataDrop, streaming: bool = False) -> None:
        d.add_consumer(self, streaming=streaming)

    def add_output(self, d: DataDrop) -> None:
        d.add_producer(self)

    # -- event handlers (§3.6) -----------------------------------------------------
    def on_input_completed(self, d: DataDrop) -> None:
        self._record_resolution(d.uid, errored=False)

    def on_input_error(self, d: DataDrop) -> None:
        self._record_resolution(d.uid, errored=True)

    def on_stream_chunk(self, d: DataDrop, value: Any) -> None:
        """Streaming consumers process input continuously (§4, Fig. 10)."""
        if self.func is not None and getattr(self.func, "streaming", False):
            self.func(value, self)

    def _record_resolution(self, uid: str, errored: bool) -> None:
        with self._exec_lock:
            self._resolved[uid] = errored
            n_in = len(self.inputs) + len(self.streaming_inputs)
            if len(self._resolved) < n_in:
                return
            n_err = sum(1 for e in self._resolved.values() if e)
            frac_err = n_err / max(n_in, 1)
            already = self.exec_state is not AppState.NOT_RUN
        if already or self.state in _TERMINAL:
            return
        if frac_err > self.error_threshold:
            self.set_error(
                f"{n_err}/{n_in} inputs errored > t={self.error_threshold}")
        else:
            self._submit()

    # -- execution -------------------------------------------------------------
    def _submit(self) -> None:
        if self._executor is not None:
            self._executor.submit(self.execute)
        else:
            self.execute()

    def execute(self) -> None:
        with self._exec_lock:
            if self.exec_state is not AppState.NOT_RUN:
                return
            self.exec_state = AppState.RUNNING
        self.attempts += 1
        self.fire("execStatus", status=AppState.RUNNING.value)
        t0 = time.monotonic()
        try:
            if self.func is not None:
                ok_inputs = [d for d in self.inputs
                             if d.state is DropState.COMPLETED]
                # deterministic input order regardless of wiring order
                # (cross-node edges are wired later by the island manager)
                ok_inputs.sort(key=_drop_order_key)
                if getattr(self.func, "streaming", False):
                    # streaming-marked func: chunks were delivered via
                    # on_stream_chunk; batch resolution runs only the
                    # optional finalizer (§4 — the consumer completes
                    # when its producers do)
                    fin = getattr(self.func, "finish", None)
                    if fin is not None:
                        fin(ok_inputs, list(self.outputs), self)
                else:
                    self.func(ok_inputs, list(self.outputs), self)
            self.run_duration = time.monotonic() - t0
            self._finish_ok()
        except Exception:  # noqa: BLE001 - app failures become drop ERRORs
            self.run_duration = time.monotonic() - t0
            self.set_error(traceback.format_exc(limit=8))

    def _finish_ok(self) -> None:
        with self._exec_lock:
            if self.exec_state in (AppState.FINISHED, AppState.ERROR,
                                   AppState.CANCELLED):
                return  # a speculative duplicate already committed
            self.exec_state = AppState.FINISHED
        self.completed_at = time.monotonic()
        self._set_state(DropState.COMPLETED)
        self.fire("producerFinished")
        for out in list(self.outputs):
            out.on_producer_finished(self)

    def commit_speculative(self) -> bool:
        """Commit a speculative duplicate's result (straggler mitigation).

        First finisher wins; the guard makes the loser a no-op.  Safe for
        idempotent (pure) apps — the write-once payload holds one value.
        """
        with self._exec_lock:
            if self.exec_state in (AppState.FINISHED, AppState.ERROR,
                                   AppState.CANCELLED):
                return False
            self.exec_state = AppState.FINISHED
        self.completed_at = time.monotonic()
        self._set_state(DropState.COMPLETED)
        self.fire("producerFinished", speculative=True)
        for out in list(self.outputs):
            out.on_producer_finished(self)
        return True

    def set_error(self, info: str = "") -> None:
        self.exec_state = AppState.ERROR
        self.error_info = info
        self._set_state(DropState.ERROR)
        self.fire("dropError", info=info)
        for out in list(self.outputs):
            out.on_producer_error(self)

    def skip(self) -> None:
        super().skip()
        self.exec_state = AppState.SKIPPED
        for out in list(self.outputs):
            out.on_producer_finished(self)

    # -- root trigger -------------------------------------------------------------
    def trigger_root(self) -> None:
        """Apps without inputs are roots; started directly at session start."""
        if not self.inputs and not self.streaming_inputs:
            self._submit()

    def to_record(self) -> Dict[str, Any]:
        rec = super().to_record()
        rec.update(exec_state=self.exec_state.value,
                   resolved=dict(self._resolved), attempts=self.attempts)
        return rec

    def restore_record(self, rec: Dict[str, Any]) -> None:
        super().restore_record(rec)
        self.exec_state = AppState(rec.get("exec_state", "NOT_RUN"))
        self._resolved = dict(rec.get("resolved", {}))
        self.attempts = rec.get("attempts", 0)
