"""Small shared helpers for the core package."""
from __future__ import annotations


def safe_uid(uid: str) -> str:
    """Filesystem-safe encoding of a drop uid (used for payload spill files
    and checkpoint entries)."""
    return uid.replace("/", "_").replace("#", "_").replace(".", "_")
