"""One partition substrate shared by translate and mapping.

The paper's scalability story rests on *hierarchical* graph partitioning
("Partitioning SKA Dataflows for Optimal Graph Execution"): ``min_time``
coarsens the drop graph by edge-zeroing merges, and ``map_partitions``
coarsens the resulting partition graph again by heavy-edge matching.
Before this module the two stages each built and collapsed their own
hierarchy; now ``min_time`` *records* the merge hierarchy it builds
anyway (:class:`PartitionHierarchy`) and the mapper consumes it directly
— translate hands the mapper its coarsening for free, and the mapper
projects the node assignment back down the recorded levels with KL
refinement at every level (multilevel uncoarsening) instead of refining
only at the finest granularity.

Everything here is plain numpy over flat arrays — no imports from the
rest of ``repro.core`` (partition, mapping and schedule all build on
top of this module, so it must sit below them).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def dense_labels(labels: np.ndarray) -> np.ndarray:
    """Renumber arbitrary partition labels (e.g. union-find root ids) to
    dense 0..P-1 int32 (value-ordered, so already-dense labels pass
    through unchanged)."""
    if labels.size == 0:
        return labels.astype(np.int32, copy=False)
    lo = int(labels.min())
    span = int(labels.max()) - lo + 1
    if 0 <= lo and span <= 4 * labels.size:
        # scan-based renumber (no sort): same value order as np.unique
        present = np.zeros(span, dtype=bool)
        present[labels - lo] = True
        remap = np.cumsum(present, dtype=np.int64) - 1
        return remap[labels - lo].astype(np.int32)
    return np.unique(labels, return_inverse=True)[1].astype(np.int32)


def aggregate_edges(eu: np.ndarray, ev: np.ndarray, ew: np.ndarray,
                    parent: np.ndarray, nv: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Project an undirected weighted edge list through a vertex merge map.

    ``parent`` maps every fine vertex to its coarse vertex (0..nv-1).
    Edges whose endpoints land in the same coarse vertex are internalised
    (dropped); parallel survivors are collapsed with summed weights via
    the usual packed-key ``np.unique`` + ``np.bincount`` aggregation.
    Total *cut* weight is exactly preserved for any labelling refined on
    the coarse graph and projected back (internal edges can never be cut
    again).
    """
    empty = (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64),
             np.empty(0, dtype=np.float64))
    if eu.size == 0:
        return empty
    cu = parent[eu].astype(np.int64, copy=False)
    cv = parent[ev].astype(np.int64, copy=False)
    live = cu != cv
    if not live.any():
        return empty
    lo = np.minimum(cu[live], cv[live])
    hi = np.maximum(cu[live], cv[live])
    key = lo * np.int64(nv) + hi
    uk, inv = np.unique(key, return_inverse=True)
    cw = np.bincount(inv, weights=ew[live])
    return uk // nv, uk % nv, cw


def level_structure(levels: np.ndarray, esrc: np.ndarray, edst: np.ndarray,
                    n: int):
    """Level-bucketed edge and node orders for level-synchronous passes.

    Partition-independent, so one computation serves every evaluation of
    the same graph — ``schedule._Arrays`` caches it per PGT and
    ``PrefixCP`` / ``_critical_path_dist`` share the result.  Returns
    ``(esrc_s, edst_s, e_order, bounds, node_order, nbounds, max_level)``
    where the edge triplets are sorted by destination level and
    ``bounds[lv]:bounds[lv+1]`` slices out one level.
    """
    max_lv = int(levels.max()) if n else 0
    if esrc.size:
        edge_lv = levels[edst]
        e_order = np.argsort(edge_lv, kind="stable")
        edge_lv_sorted = edge_lv[e_order]
        bounds = np.searchsorted(
            edge_lv_sorted, np.arange(edge_lv_sorted[-1] + 2))
        esrc_s, edst_s = esrc[e_order], edst[e_order]
    else:
        e_order = np.empty(0, dtype=np.int64)
        bounds = None
        esrc_s = edst_s = e_order
    node_order = np.argsort(levels, kind="stable")
    nbounds = np.searchsorted(levels[node_order], np.arange(max_lv + 2))
    return (esrc_s, edst_s, e_order, bounds, node_order, nbounds, max_lv)


class HierarchyLevel:
    """One level of a partition hierarchy: a weighted undirected graph.

    * ``load`` / ``mem`` / ``count`` — per-vertex app weight, data volume
      and member-drop count (float64 / float64 / int64),
    * ``eu`` / ``ev`` / ``ew`` — unique undirected cross-vertex edges
      (``eu < ev``) with summed volumes,
    * ``parent`` — maps this level's vertices to the next-*coarser*
      level's (``None`` at the coarsest level).  Projecting a coarse
      assignment down is one gather: ``a_fine = a_coarse[parent]``.
    """

    __slots__ = ("load", "mem", "count", "eu", "ev", "ew", "parent")

    def __init__(self, load, mem, count, eu, ev, ew, parent=None) -> None:
        self.load = load
        self.mem = mem
        self.count = count
        self.eu = eu
        self.ev = ev
        self.ew = ew
        self.parent = parent

    @property
    def num_vertices(self) -> int:
        return int(self.load.size)

    @property
    def num_edges(self) -> int:
        return int(self.eu.size)

    def cut(self, a: np.ndarray) -> float:
        """Total edge weight crossing the node assignment ``a``."""
        if self.eu.size == 0:
            return 0.0
        return float(self.ew[a[self.eu] != a[self.ev]].sum())


class PartitionHierarchy:
    """The merge hierarchy ``min_time`` builds, recorded for the mapper.

    ``levels[0]`` is the finest level — one vertex per PGT partition of
    the labelling the partitioner kept (dense ids, so vertex *i* is
    partition *i*).  Deeper entries are the coarser snapshots the merge
    sweep passed through on the way (nested by construction: the
    union-find only ever coarsens along the cost-sorted prefix).

    ``labels`` is a *copy* of the finest per-drop labelling at recording
    time: :meth:`matches` detects any later mutation of
    ``pgt.partition`` (annealing, manual edits) and the mapper falls back
    to the flat extraction rather than consuming a stale hierarchy.
    """

    __slots__ = ("levels", "labels")

    def __init__(self, levels: List[HierarchyLevel],
                 labels: np.ndarray) -> None:
        self.levels = levels
        self.labels = labels

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    def matches(self, pgt) -> bool:
        part = getattr(pgt, "partition", None)
        return part is not None and np.array_equal(part, self.labels)

    @classmethod
    def from_labelings(cls, labelings: Sequence[np.ndarray],
                       load: np.ndarray, mem: np.ndarray, count: np.ndarray,
                       eu: np.ndarray, ev: np.ndarray, ew: np.ndarray
                       ) -> "PartitionHierarchy":
        """Build the hierarchy from nested per-drop dense labelings.

        ``labelings[0]`` is the finest (kept) labelling and
        ``load``/``mem``/``count``/``eu``/``ev``/``ew`` its aggregated
        partition graph; later entries are successively coarser nested
        labelings (every partition of ``labelings[i]`` maps into exactly
        one partition of ``labelings[i+1]``).  Levels that do not merge
        anything are skipped.
        """
        finest = labelings[0]
        levels = [HierarchyLevel(load, mem, count, eu, ev, ew)]
        prev = finest
        for cur in labelings[1:]:
            nv_prev = levels[-1].num_vertices
            nv_cur = int(cur.max()) + 1 if cur.size else 0
            if nv_cur >= nv_prev:
                continue             # checkpoint merged nothing new
            # per-partition parent map: one scatter over the drops
            # (nested labelings make every write per slot consistent)
            parent = np.empty(nv_prev, dtype=np.int32)
            parent[prev] = cur
            top = levels[-1]
            cload = np.bincount(parent, weights=top.load, minlength=nv_cur)
            cmem = np.bincount(parent, weights=top.mem, minlength=nv_cur)
            ccnt = np.bincount(parent, weights=top.count,
                               minlength=nv_cur).astype(np.int64)
            ceu, cev, cew = aggregate_edges(top.eu, top.ev, top.ew,
                                            parent, nv_cur)
            top.parent = parent
            levels.append(HierarchyLevel(cload, cmem, ccnt, ceu, cev, cew))
            prev = cur
        return cls(levels, finest.copy())
