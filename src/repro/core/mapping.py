"""Resource mapping: PGT partitions -> physical nodes (paper §3.5).

"We use the METIS software library, which internally uses a multilevel k-way
partitioning algorithm, to merge the p PGT partitions into m virtual clusters
if p > m ... with the goal of balancing the overall workload (both compute
time and memory usage) evenly.  The physical mapping from the m merged
clusters to m compute nodes becomes a straightforward round-robin assignment."

Two implementations share the objective ``alpha * imbalance + beta * cut``:

* ``mapping="csr"`` (default) — array-native multilevel scheme over the
  partition hierarchy ``min_time`` records while merging
  (:class:`~repro.core.substrate.PartitionHierarchy`; the flat
  :meth:`~repro.core.pgt.CompiledPGT.partition_graph_arrays` extraction
  is the fallback when no fresh hierarchy exists):

  1. **Coarsen**: start from the recorded merge hierarchy — translate
     already coarsened this graph, so the mapper re-uses its levels —
     and extend it past the coarsest recorded level with rounds of
     vectorized *heavy-edge matching* (every vertex picks its heaviest
     incident edge, ties broken toward the lighter partner; mutual picks
     contract; re-aggregation via ``np.unique``/``np.bincount``) until
     <= m super-vertices or the positive-weight edges run out.
  2. **Assign**: longest-processing-time greedy of the coarsest level
     onto nodes.  Loads carry a drop-count epsilon, so
     *zero-communication / zero-weight* components (where every
     tie-break used to collapse the whole graph onto node0) spread ~1/m
     per node by count.
  3. **Uncoarsen + refine**: project the assignment back down the
     chain one level at a time, running the vectorized Kernighan–Lin
     best-move greedy at *every* level (``refine_levels="all"``) —
     coarse moves relocate whole clusters that single fine-level moves
     cannot, which is where cut quality is won on communication-heavy
     graphs (``refine_levels="finest"`` restores the old single-level
     behaviour).

* ``mapping="dict"`` — the original dict-of-dicts implementation, kept as
  the semantic oracle (``tests/test_mapping_balance.py`` checks the CSR
  mapper never produces a materially worse objective).

Both paths accept either PGT representation; the CSR path extracts the
partition graph vectorized from a ``CompiledPGT`` and via the dict walk
otherwise (loop-carried graphs still unroll into dict PGTs).
"""
from __future__ import annotations

import heapq
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pgt import KIND_DATA, CompiledPGT
from .substrate import HierarchyLevel
from .unroll import PhysicalGraphTemplate

# drop-count tie-break scale: small enough never to outweigh a real load
# difference, large enough to order pure-count ties (see _chain_loads)
_COUNT_EPS = 1e-9


@dataclass
class NodeInfo:
    """A homogeneous compute node (paper assumes identical capabilities)."""

    name: str
    island: str = "island0"
    alive: bool = True


@dataclass
class PartitionGraph:
    vweights: Dict[int, float] = field(default_factory=dict)       # load
    vmem: Dict[int, float] = field(default_factory=dict)           # memory
    eweights: Dict[Tuple[int, int], float] = field(default_factory=dict)

    @classmethod
    def from_pgt(cls, pgt) -> "PartitionGraph":
        if isinstance(pgt, CompiledPGT):
            return cls._from_compiled(pgt)
        g = cls()
        for spec in pgt.drops.values():
            g.vweights[spec.partition] = (
                g.vweights.get(spec.partition, 0.0) + spec.weight())
            g.vmem[spec.partition] = (
                g.vmem.get(spec.partition, 0.0) +
                (spec.data_volume if spec.kind == "data" else 0.0))
        for s, d, _ in pgt.edges:
            ps, pd = pgt.drops[s].partition, pgt.drops[d].partition
            if ps == pd:
                continue
            key = (min(ps, pd), max(ps, pd))
            vol = (pgt.drops[s].data_volume if pgt.drops[s].kind == "data"
                   else pgt.drops[d].data_volume)
            g.eweights[key] = g.eweights.get(key, 0.0) + vol
        return g

    @classmethod
    def _from_compiled(cls, pgt: CompiledPGT) -> "PartitionGraph":
        """Dict view of the vectorized partition-graph extraction."""
        g = cls()
        ids, load, mem, _, eu, ev, ew = pgt.partition_graph_arrays()
        for p, wv, mv in zip(ids.tolist(), load.tolist(), mem.tolist()):
            g.vweights[p] = float(wv)
            g.vmem[p] = float(mv)
        labels = ids.tolist()
        for a, b, v in zip(eu.tolist(), ev.tolist(), ew.tolist()):
            g.eweights[(labels[a], labels[b])] = float(v)
        return g


class PartitionArrays:
    """The partition-level graph as flat arrays — the CSR mapper's input.

    * ``ids``   — occurring partition labels, sorted,
    * ``load`` / ``mem`` / ``count`` — per-partition app weight, data
      volume, drop count,
    * ``eu`` / ``ev`` / ``ew`` — unique undirected cross-partition edges
      (indices into ``ids``, ``eu < ev``) with summed volumes.
    """

    __slots__ = ("ids", "load", "mem", "count", "eu", "ev", "ew")

    def __init__(self, ids, load, mem, count, eu, ev, ew) -> None:
        self.ids = ids
        self.load = load
        self.mem = mem
        self.count = count
        self.eu = eu
        self.ev = ev
        self.ew = ew

    @classmethod
    def from_pgt(cls, pgt) -> "PartitionArrays":
        if isinstance(pgt, CompiledPGT):
            return cls(*pgt.partition_graph_arrays())
        # dict PGTs (loop-carried graphs): one spec walk, then arrays
        g = PartitionGraph.from_pgt(pgt)
        counts: Counter = Counter(
            s.partition for s in pgt.drops.values())
        labels = sorted(g.vweights)
        index = {p: i for i, p in enumerate(labels)}
        npart = len(labels)
        ids = np.asarray(labels, dtype=np.int64)
        load = np.fromiter((g.vweights[p] for p in labels),
                           dtype=np.float64, count=npart)
        mem = np.fromiter((g.vmem[p] for p in labels),
                          dtype=np.float64, count=npart)
        count = np.fromiter((counts[p] for p in labels),
                            dtype=np.int64, count=npart)
        ne = len(g.eweights)
        eu = np.fromiter((index[a] for a, _ in g.eweights),
                         dtype=np.int64, count=ne)
        ev = np.fromiter((index[b] for _, b in g.eweights),
                         dtype=np.int64, count=ne)
        ew = np.fromiter(g.eweights.values(), dtype=np.float64, count=ne)
        return cls(ids, load, mem, count, eu, ev, ew)


def _validate(nodes: Sequence[NodeInfo],
              refine_iters: int) -> List[NodeInfo]:
    """Shared argument validation (both mapper paths).

    Duplicate node names used to silently collapse via dict keying (two
    ``NodeInfo("n0")`` entries looked like one node with doubled
    capacity); a negative ``refine_iters`` silently skipped refinement.
    """
    if refine_iters < 0:
        raise ValueError(
            f"refine_iters must be >= 0, got {refine_iters}")
    counts = Counter(n.name for n in nodes)
    dupes = sorted(name for name, c in counts.items() if c > 1)
    if dupes:
        raise ValueError(f"duplicate node names: {dupes}")
    live = [n for n in nodes if n.alive]
    if not live:
        raise ValueError("no live nodes to map onto")
    return live


def map_partitions(pgt, nodes: Sequence[NodeInfo],
                   alpha: float = 1.0, beta: float = 1e-9,
                   refine_iters: int = 200,
                   mapping: str = "csr",
                   refine_levels: str = "all",
                   refine_mode: str = "worklist",
                   level_stats: Optional[List[Dict[str, float]]] = None
                   ) -> Dict[int, str]:
    """Assign each PGT partition to a node; also stamps ``spec.node``.

    ``mapping="csr"`` (default) runs the array-native multilevel mapper;
    ``mapping="dict"`` runs the original dict implementation (the
    semantic oracle, fine to ~10^4 partitions).

    ``refine_levels`` controls the uncoarsening pass of the CSR path:
    ``"all"`` (default) runs KL refinement at every level of the
    coarsening chain while projecting the assignment down;
    ``"finest"`` refines only at the finest level (the pre-substrate
    behaviour).  ``refine_mode`` selects the KL inner loop:
    ``"worklist"`` (default) maintains the cut-to-node table
    incrementally, touching only the moved vertex's neighbourhood per
    move; ``"sweep"`` rebuilds it from the full edge list every round
    (the pre-worklist behaviour, kept as the oracle).  When
    ``level_stats`` is a list it receives one dict per refined level —
    cut and imbalance before/after refinement plus the refine wall —
    for diagnostics (``bench_partition.py --verbose-partition``).
    """
    live = _validate(nodes, refine_iters)
    if refine_mode not in ("sweep", "worklist"):
        raise ValueError(f"unknown refine_mode {refine_mode!r}")
    if mapping == "dict":
        return _map_partitions_dict(pgt, live, alpha, beta, refine_iters)
    if mapping != "csr":
        raise ValueError(f"unknown mapping {mapping!r}")
    if refine_levels not in ("all", "finest"):
        raise ValueError(f"unknown refine_levels {refine_levels!r}")
    m = len(live)
    # min_time records its merge hierarchy (core/substrate.py): the
    # finest partition graph AND its coarser levels arrive pre-built.
    # Fall back to the flat extraction when the hierarchy is absent
    # (dict PGTs, min_res, manual labels) or stale (partition mutated
    # since — annealing, DropView writes)
    hier = getattr(pgt, "_partition_hierarchy", None)
    if hier is not None and hier.matches(pgt):
        levels = list(hier.levels)
        ids = np.arange(levels[0].num_vertices, dtype=np.int64)
    else:
        g = PartitionArrays.from_pgt(pgt)
        levels = [HierarchyLevel(g.load, g.mem, g.count, g.eu, g.ev, g.ew)]
        ids = g.ids
    npart = int(ids.size)
    if npart == 0:
        stamp_nodes(pgt, {})
        return {}
    lw = _chain_loads(levels)
    edges = [(l.eu, l.ev, l.ew) for l in levels]
    parents = [l.parent for l in levels[:-1]]
    # 1. coarsen: extend the recorded chain past its coarsest level with
    #    vectorized heavy-edge matching until <= m super-vertices
    for parent, clw, ceu, cev, cew in _hem_levels(lw[-1], *edges[-1], m):
        parents.append(parent)
        lw.append(clw)
        edges.append((ceu, cev, cew))
    # 2. initial assignment: LPT greedy of the coarsest level onto nodes
    a = _lpt_assign(lw[-1], m)
    # 3. uncoarsen: project down one level at a time, KL-refining off
    #    each level's own edge arrays (coarse moves relocate whole
    #    clusters that single finest-level moves cannot reach)
    top = len(lw) - 1
    for i in range(top, -1, -1):
        if i < top:
            a = a[parents[i]]
        if refine_levels == "all" or i == 0:
            eu, ev, ew = edges[i]
            before = (_level_stat(lw[i], a, m, eu, ev, ew)
                      if level_stats is not None else None)
            t0 = time.monotonic()
            _refine_arrays(lw[i], a, m, eu, ev, ew, alpha, beta,
                           refine_iters, refine_mode)
            refine_s = time.monotonic() - t0
            if before is not None:
                after = _level_stat(lw[i], a, m, eu, ev, ew)
                level_stats.append({
                    "level": i, "vertices": int(lw[i].size),
                    "edges": int(eu.size),
                    "cut_before": before[0], "cut_after": after[0],
                    "imbalance_before": before[1],
                    "imbalance_after": after[1],
                    "refine_s": refine_s})
    assign = {int(p): live[int(j)].name
              for p, j in zip(ids.tolist(), a.tolist())}
    stamp_nodes(pgt, assign)
    return assign


def _chain_loads(levels: Sequence[HierarchyLevel]) -> List[np.ndarray]:
    """Per-level effective load vectors with a drop-count tie-break.

    A uniform zero-weight graph has every partition load 0; every greedy
    decision then ties and historically resolved to node0 — the whole
    graph piled onto one node.  Adding a count term that is *tiny
    relative to the mean positive load* (or the count itself when no
    load exists) makes balance-by-count the tie-break without measurably
    distorting weighted graphs.

    The coefficients are fixed at the finest level; the loads are then
    linear in ``(load, mem, count)``, so projecting a level's loads
    through its parent map reproduces the coarser level's exactly —
    refinement sees consistent balance bookkeeping at every level.
    """
    base = levels[0]
    load0 = base.load + 1e-6 * base.mem
    total = float(load0.sum())
    if total <= 0.0:
        return [l.count.astype(np.float64) for l in levels]
    eps = (total / max(float(base.count.sum()), 1.0)) * _COUNT_EPS
    return [(l.load + 1e-6 * l.mem) + eps * l.count for l in levels]


def _level_stat(w: np.ndarray, a: np.ndarray, m: int, eu: np.ndarray,
                ev: np.ndarray, ew: np.ndarray) -> Tuple[float, float]:
    """(cut volume, load imbalance) of assignment ``a`` on one level."""
    cut = float(ew[a[eu] != a[ev]].sum()) if ew.size else 0.0
    loads = np.zeros(m, dtype=np.float64)
    np.add.at(loads, a, w)
    imb = float(loads.max() / max(float(loads.mean()), 1e-12))
    return cut, imb


def _hem_levels(lw: np.ndarray, eu: np.ndarray, ev: np.ndarray,
                ew: np.ndarray, m: int
                ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray]]:
    """Vectorized heavy-edge-matching coarsening, one chain level per round.

    Rounds of parallel matching: every vertex nominates the neighbour
    across its heaviest positive edge (ties toward the lighter partner —
    load-aware, so merged loads stay even), mutual nominations contract.
    Merges per round are capped at ``nv - m`` (heaviest matched edges
    first), so coarsening never overshoots below ``m`` vertices.  Each
    round is O(E log E) numpy work; rounds are O(log P) in practice.

    Merged loads are capped at the balanced per-node share
    (``sum(lw)/m``): a pair whose combined load would exceed it does not
    contract.  Without the cap a connected uniform graph coarsens into
    one giant super-vertex that no amount of single-move refinement can
    re-spread — the multilevel analogue of the node0 pile-up.

    Returns one ``(parent, load, eu, ev, ew)`` record per round —
    ``parent`` maps the previous level's vertices to the new one's, the
    rest is the new level's graph — ready to splice onto the recorded
    hierarchy chain.  Zero-weight edges never match — disconnected /
    zero-communication components are left to the load-aware LPT
    assignment (and contribute nothing to any cut, so dropping them from
    the per-level refinement edges is exact).
    """
    out: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                    np.ndarray]] = []
    pos = ew > 0.0
    ceu = eu[pos].astype(np.int64, copy=True)
    cev = ev[pos].astype(np.int64, copy=True)
    cew = ew[pos].astype(np.float64, copy=True)
    cload = lw.astype(np.float64, copy=True)
    cap = float(cload.sum()) / max(m, 1)
    nv = int(lw.size)
    while nv > m and ceu.size:
        src = np.concatenate([ceu, cev])
        dst = np.concatenate([cev, ceu])
        w = np.concatenate([cew, cew])
        # per-vertex heaviest incident edge; equal weights prefer the
        # lighter partner, then the smaller id (deterministic)
        order = np.lexsort((-dst, -cload[dst], w, src))
        s_srt = src[order]
        last = np.flatnonzero(np.r_[s_srt[1:] != s_srt[:-1], True])
        choice = np.full(nv, -1, dtype=np.int64)
        bestw = np.zeros(nv, dtype=np.float64)
        choice[s_srt[last]] = dst[order][last]
        bestw[s_srt[last]] = w[order][last]
        cand = np.flatnonzero(choice >= 0)
        mutual = cand[choice[choice[cand]] == cand]
        pu = mutual[mutual < choice[mutual]]
        if pu.size:
            pv = choice[pu]
            fits = cload[pu] + cload[pv] <= cap     # balance constraint
            pu, pv = pu[fits], pv[fits]
        if pu.size == 0:
            break
        if pu.size > nv - m:      # don't coarsen below m vertices
            keep = np.argsort(-bestw[pu], kind="stable")[:nv - m]
            pu, pv = pu[keep], pv[keep]
        merge_map = np.arange(nv, dtype=np.int64)
        merge_map[pv] = pu        # matched pairs are disjoint
        uniq, new_of = np.unique(merge_map, return_inverse=True)
        nv = int(uniq.size)
        cload = np.bincount(new_of, weights=cload, minlength=nv)
        ceu, cev = new_of[ceu], new_of[cev]
        live_e = ceu != cev
        if live_e.any():
            lo = np.minimum(ceu[live_e], cev[live_e])
            hi = np.maximum(ceu[live_e], cev[live_e])
            key = lo * np.int64(nv) + hi
            uk, inv_k = np.unique(key, return_inverse=True)
            cew = np.bincount(inv_k, weights=cew[live_e])
            ceu, cev = uk // nv, uk % nv
        else:
            ceu = cev = np.empty(0, dtype=np.int64)
            cew = np.empty(0, dtype=np.float64)
        out.append((new_of, cload, ceu, cev, cew))
    return out


def _lpt_assign(gload: np.ndarray, m: int) -> np.ndarray:
    """Longest-processing-time greedy: groups (descending load) onto the
    currently lightest node.  All-equal loads short-circuit to an exact
    round-robin (the common zero-weight / uniform case, vectorized)."""
    ngroups = gload.size
    a = np.zeros(ngroups, dtype=np.int64)
    if ngroups == 0 or m <= 1:
        return a
    order = np.argsort(-gload, kind="stable")
    spread = float(gload.max() - gload.min()) if ngroups else 0.0
    if spread <= 1e-12 * max(abs(float(gload.max())), 1.0):
        a[order] = np.arange(ngroups, dtype=np.int64) % m
        return a
    heap: List[Tuple[float, int]] = [(0.0, j) for j in range(m)]
    for gi in order.tolist():
        load, j = heapq.heappop(heap)
        a[gi] = j
        heapq.heappush(heap, (load + float(gload[gi]), j))
    return a


def _refine_arrays(w: np.ndarray, a: np.ndarray, m: int,
                   ea: np.ndarray, eb: np.ndarray, ew: np.ndarray,
                   alpha: float, beta: float, refine_iters: int,
                   refine_mode: str = "sweep") -> None:
    """Greedy refinement of ``alpha * imbalance + beta * cut_volume``.

    Array-native: the Δcost of moving any partition to any node is
    evaluated for ALL (partition, node) pairs at once —

    * Δimbalance (sum of squared node loads) is ``2 w_p (L_t - L_s + w_p)``,
    * Δcut is ``cut_to[p, s] - cut_to[p, t]`` where ``cut_to[p, t]`` is the
      weight of p's edges into partitions currently on node t —

    and the single best move is applied per round, until no move improves.
    ``a`` (partition -> node index) is refined in place.

    ``refine_mode`` selects how ``cut_to`` is kept current:

    * ``"sweep"`` — rebuilt from the full edge list every round (two
      ``np.add.at`` over E_p), O(iters · (P·m + E_p)); the oracle.
    * ``"worklist"`` — built once, then patched per move: relocating
      partition p from node s to t only changes ``cut_to[q, {s,t}]``
      for q adjacent to p, so each move costs O(deg(p) + P·m) instead
      of O(E_p + P·m).  Full-level rebuilds dominate the 10M-tier map
      wall; boundary-only updates are where that time goes away.  Both
      modes evaluate the same Δcost, so they pick identical move
      sequences up to float summation order.
    """
    nparts = w.size
    if nparts == 0 or m <= 1 or refine_iters == 0:
        return
    loads = np.zeros(m, dtype=np.float64)
    np.add.at(loads, a, w)
    if ew.size and not ew.any():
        ew = np.empty(0, dtype=np.float64)
    rows = np.arange(nparts)
    if refine_mode == "worklist" and ew.size:
        _refine_worklist(w, a, m, ea, eb, ew, alpha, beta, refine_iters,
                         loads, rows)
        return
    for _ in range(refine_iters):
        if ew.size:
            cut_to = np.zeros((nparts, m))
            np.add.at(cut_to, (ea, a[eb]), ew)
            np.add.at(cut_to, (eb, a[ea]), ew)
            d_cut = cut_to[rows, a][:, None] - cut_to
        else:
            d_cut = 0.0
        d_imb = 2.0 * w[:, None] * (loads[None, :] - loads[a][:, None]
                                    + w[:, None])
        delta = alpha * d_imb + beta * d_cut
        delta[rows, a] = 0.0
        best = int(np.argmin(delta))
        p, t = divmod(best, m)
        if not delta[p, t] + 1e-15 < 0.0:
            break
        loads[a[p]] -= w[p]
        loads[t] += w[p]
        a[p] = t


def _refine_worklist(w: np.ndarray, a: np.ndarray, m: int,
                     ea: np.ndarray, eb: np.ndarray, ew: np.ndarray,
                     alpha: float, beta: float, refine_iters: int,
                     loads: np.ndarray, rows: np.ndarray) -> None:
    """Boundary-only KL inner loop (``refine_mode="worklist"``).

    ``cut_to`` and ``d_cut`` are built once; after each applied move
    only the moved vertex's neighbourhood is re-scanned — the move
    p: s→t shifts weight ``w(p,q)`` from column s to column t of every
    neighbour q's ``cut_to`` row, and row p's own baseline column
    changes, so exactly ``{p} ∪ N(p)`` rows of ``d_cut`` are stale.
    """
    nparts = w.size
    # neighbour CSR over the doubled undirected edge list, grouped by src
    src = np.concatenate([ea, eb])
    order = np.argsort(src, kind="stable")
    nbr = np.concatenate([eb, ea])[order]
    nbw = np.concatenate([ew, ew])[order]
    starts = np.searchsorted(src[order], np.arange(nparts + 1))
    cut_to = np.zeros((nparts, m))
    np.add.at(cut_to, (ea, a[eb]), ew)
    np.add.at(cut_to, (eb, a[ea]), ew)
    d_cut = cut_to[rows, a][:, None] - cut_to
    for _ in range(refine_iters):
        d_imb = 2.0 * w[:, None] * (loads[None, :] - loads[a][:, None]
                                    + w[:, None])
        delta = alpha * d_imb + beta * d_cut
        delta[rows, a] = 0.0
        best = int(np.argmin(delta))
        p, t = divmod(best, m)
        if not delta[p, t] + 1e-15 < 0.0:
            break
        s = int(a[p])
        loads[s] -= w[p]
        loads[t] += w[p]
        a[p] = t
        lo, hi = int(starts[p]), int(starts[p + 1])
        nbs, wq = nbr[lo:hi], nbw[lo:hi]
        # np.add.at: robust against duplicate (p, q) entries in the input
        np.add.at(cut_to, (nbs, s), -wq)
        np.add.at(cut_to, (nbs, t), wq)
        aff = np.append(nbs, p)
        d_cut[aff] = cut_to[aff, a[aff]][:, None] - cut_to[aff]


# ---------------------------------------------------------------------------
# The original dict-of-dicts mapper — kept as the semantic oracle
# ---------------------------------------------------------------------------


def _map_partitions_dict(pgt, live: Sequence[NodeInfo],
                         alpha: float, beta: float,
                         refine_iters: int) -> Dict[int, str]:
    """The pre-CSR implementation (``mapping="dict"``): dict partition
    graph, sorted-edge contraction, heap merge of lightest groups, greedy
    assignment.  Retains the historical zero-weight tie-breaking (whole
    uniform graphs land on node0) — that behaviour is exactly what the
    CSR mapper's load-aware tie-breaks fix."""
    m = len(live)
    g = PartitionGraph.from_pgt(pgt)
    parts = sorted(g.vweights)

    # --- coarsen: heaviest-edge matching until <= m super-vertices -----------
    group_of: Dict[int, int] = {p: p for p in parts}

    def find(p: int) -> int:
        while group_of[p] != p:
            group_of[p] = group_of[group_of[p]]
            p = group_of[p]
        return p

    ngroups = len(parts)
    edges = sorted(g.eweights.items(), key=lambda kv: -kv[1])
    ei = 0
    while ngroups > m and ei < len(edges):
        (a, b), w = edges[ei]
        ei += 1
        if w <= 0.0:
            break   # zero-communication pairs: leave to load-based merging
        ra, rb = find(a), find(b)
        if ra != rb:
            group_of[rb] = ra
            ngroups -= 1
    # if still too many groups (disconnected), merge the two lightest —
    # heap-based so zero-communication graphs (all edge volumes 0) coarsen
    # in O(P log P) instead of the old O(P^2) rebuild-and-sort loop
    if ngroups > m:
        loads: Dict[int, float] = {}
        for p in parts:
            r = find(p)
            loads[r] = loads.get(r, 0.0) + g.vweights[p] + 1e-6 * g.vmem[p]
        heap = [(l, r) for r, l in loads.items()]
        heapq.heapify(heap)

        def pop_live() -> Tuple[float, int]:
            while True:
                l, r = heapq.heappop(heap)
                if group_of[r] == r and loads.get(r) == l:
                    return l, r

        while ngroups > m:
            l1, r1 = pop_live()
            l2, r2 = pop_live()
            group_of[r2] = r1
            loads[r1] = l1 + l2
            del loads[r2]
            heapq.heappush(heap, (l1 + l2, r1))
            ngroups -= 1

    clusters: Dict[int, List[int]] = {}
    for p in parts:
        clusters.setdefault(find(p), []).append(p)

    # --- initial assignment: balanced greedy (round-robin by descending load) --
    cluster_load = {r: sum(g.vweights[p] + 1e-6 * g.vmem[p] for p in ps)
                    for r, ps in clusters.items()}
    node_load = {n.name: 0.0 for n in live}
    assign: Dict[int, str] = {}
    for r in sorted(clusters, key=lambda r: -cluster_load[r]):
        tgt = min(live, key=lambda n: node_load[n.name])
        for p in clusters[r]:
            assign[p] = tgt.name
        node_load[tgt.name] += cluster_load[r]

    # --- KL-style refinement (shared vectorised best-move greedy) --------------
    _refine(g, parts, assign, live, alpha, beta, refine_iters)

    stamp_nodes(pgt, assign)
    return assign


def _refine(g: PartitionGraph, parts: List[int], assign: Dict[int, str],
            live: Sequence[NodeInfo], alpha: float, beta: float,
            refine_iters: int) -> None:
    """Dict-graph driver for :func:`_refine_arrays` (the oracle path)."""
    nparts = len(parts)
    m = len(live)
    if nparts == 0 or m <= 1:
        return
    pidx = {p: i for i, p in enumerate(parts)}
    nidx = {n.name: j for j, n in enumerate(live)}
    w = np.fromiter((g.vweights[p] + 1e-6 * g.vmem[p] for p in parts),
                    dtype=np.float64, count=nparts)
    a = np.fromiter((nidx[assign[p]] for p in parts), dtype=np.int64,
                    count=nparts)
    ne = len(g.eweights)
    ea = np.fromiter((pidx[x] for x, _ in g.eweights), dtype=np.int64,
                     count=ne)
    eb = np.fromiter((pidx[y] for _, y in g.eweights), dtype=np.int64,
                     count=ne)
    ew = np.fromiter(g.eweights.values(), dtype=np.float64, count=ne)
    _refine_arrays(w, a, m, ea, eb, ew, alpha, beta, refine_iters)
    for i, p in enumerate(parts):
        assign[p] = live[int(a[i])].name


def stamp_nodes(pgt, assign: Dict[int, str]) -> None:
    """Write a partition->node assignment onto the PGT's placement field.

    Array path: one lookup-table gather writes the whole ``node_ids``
    array (no DropSpec views are materialised); dict path: per-spec
    attribute writes.  ``assign``'s keys are exactly the partition ids
    occurring in the PGT, so the sentinel-shifted index covers them.
    """
    if isinstance(pgt, CompiledPGT):
        _, idx, shift, span = pgt.partition_index()
        table = np.full(span, -1, dtype=np.int32)
        for p, node_name in assign.items():
            table[p + shift] = pgt.node_id_for(node_name)
        pgt.node_ids = table[idx]
    else:
        for spec in pgt.drops.values():
            spec.node = assign[spec.partition]
