"""Resource mapping: PGT partitions -> physical nodes (paper §3.5).

"We use the METIS software library, which internally uses a multilevel k-way
partitioning algorithm, to merge the p PGT partitions into m virtual clusters
if p > m ... with the goal of balancing the overall workload (both compute
time and memory usage) evenly.  The physical mapping from the m merged
clusters to m compute nodes becomes a straightforward round-robin assignment."

We implement the same multilevel scheme in pure python:

1. **Coarsen**: build the partition-level graph (vertex weight = total
   execution time + memory; edge weight = cross-partition data volume) and
   repeatedly contract heaviest-edge-matching pairs until <= m vertices.
2. **Initial assignment**: round-robin of coarse vertices to nodes.
3. **Refine** (Kernighan–Lin style): greedily move partitions between nodes
   when it reduces ``alpha * imbalance + beta * cut_volume``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .pgt import KIND_DATA, CompiledPGT
from .unroll import PhysicalGraphTemplate


@dataclass
class NodeInfo:
    """A homogeneous compute node (paper assumes identical capabilities)."""

    name: str
    island: str = "island0"
    alive: bool = True


@dataclass
class PartitionGraph:
    vweights: Dict[int, float] = field(default_factory=dict)       # load
    vmem: Dict[int, float] = field(default_factory=dict)           # memory
    eweights: Dict[Tuple[int, int], float] = field(default_factory=dict)

    @classmethod
    def from_pgt(cls, pgt) -> "PartitionGraph":
        if isinstance(pgt, CompiledPGT):
            return cls._from_compiled(pgt)
        g = cls()
        for spec in pgt.drops.values():
            g.vweights[spec.partition] = (
                g.vweights.get(spec.partition, 0.0) + spec.weight())
            g.vmem[spec.partition] = (
                g.vmem.get(spec.partition, 0.0) +
                (spec.data_volume if spec.kind == "data" else 0.0))
        for s, d, _ in pgt.edges:
            ps, pd = pgt.drops[s].partition, pgt.drops[d].partition
            if ps == pd:
                continue
            key = (min(ps, pd), max(ps, pd))
            vol = (pgt.drops[s].data_volume if pgt.drops[s].kind == "data"
                   else pgt.drops[d].data_volume)
            g.eweights[key] = g.eweights.get(key, 0.0) + vol
        return g

    @classmethod
    def _from_compiled(cls, pgt: CompiledPGT) -> "PartitionGraph":
        """Vectorized partition-graph extraction (bincount-based).

        Handles unassigned drops (partition == -1, or any negative id) the
        same way the dict path does: the sentinel is just another partition
        key (shifted internally for bincount, which rejects negatives).
        """
        g = cls()
        part, _, shift, span = pgt.partition_index()
        if part.size == 0:
            return g
        ids, w = pgt.partition_loads(pgt.weight_arr)
        _, mem = pgt.partition_loads(
            np.where(pgt.kind_arr == KIND_DATA, pgt.vol_arr, 0.0))
        for p, wv, mv in zip(ids.tolist(), w.tolist(), mem.tolist()):
            g.vweights[p] = float(wv)
            g.vmem[p] = float(mv)
        ps, pd = part[pgt.edge_src], part[pgt.edge_dst]
        cross = ps != pd
        if cross.any():
            vols = pgt.edge_volumes()[cross]
            lo = np.minimum(ps[cross], pd[cross])
            hi = np.maximum(ps[cross], pd[cross])
            key = (lo + shift) * np.int64(span) + (hi + shift)
            uniq, inv = np.unique(key, return_inverse=True)
            sums = np.bincount(inv, weights=vols)
            for k, v in zip(uniq.tolist(), sums.tolist()):
                g.eweights[(int(k) // span - shift,
                            int(k) % span - shift)] = float(v)
        return g


def map_partitions(pgt, nodes: Sequence[NodeInfo],
                   alpha: float = 1.0, beta: float = 1e-9,
                   refine_iters: int = 200) -> Dict[int, str]:
    """Assign each PGT partition to a node; also stamps ``spec.node``."""
    live = [n for n in nodes if n.alive]
    if not live:
        raise ValueError("no live nodes to map onto")
    m = len(live)
    g = PartitionGraph.from_pgt(pgt)
    parts = sorted(g.vweights)

    # --- coarsen: heaviest-edge matching until <= m super-vertices -----------
    group_of: Dict[int, int] = {p: p for p in parts}

    def find(p: int) -> int:
        while group_of[p] != p:
            group_of[p] = group_of[group_of[p]]
            p = group_of[p]
        return p

    ngroups = len(parts)
    edges = sorted(g.eweights.items(), key=lambda kv: -kv[1])
    ei = 0
    while ngroups > m and ei < len(edges):
        (a, b), w = edges[ei]
        ei += 1
        if w <= 0.0:
            break   # zero-communication pairs: leave to load-based merging
        ra, rb = find(a), find(b)
        if ra != rb:
            group_of[rb] = ra
            ngroups -= 1
    # if still too many groups (disconnected), merge the two lightest —
    # heap-based so zero-communication graphs (all edge volumes 0) coarsen
    # in O(P log P) instead of the old O(P^2) rebuild-and-sort loop
    if ngroups > m:
        loads: Dict[int, float] = {}
        for p in parts:
            r = find(p)
            loads[r] = loads.get(r, 0.0) + g.vweights[p] + 1e-6 * g.vmem[p]
        heap = [(l, r) for r, l in loads.items()]
        heapq.heapify(heap)

        def pop_live() -> Tuple[float, int]:
            while True:
                l, r = heapq.heappop(heap)
                if group_of[r] == r and loads.get(r) == l:
                    return l, r

        while ngroups > m:
            l1, r1 = pop_live()
            l2, r2 = pop_live()
            group_of[r2] = r1
            loads[r1] = l1 + l2
            del loads[r2]
            heapq.heappush(heap, (l1 + l2, r1))
            ngroups -= 1

    clusters: Dict[int, List[int]] = {}
    for p in parts:
        clusters.setdefault(find(p), []).append(p)

    # --- initial assignment: balanced greedy (round-robin by descending load) --
    cluster_load = {r: sum(g.vweights[p] + 1e-6 * g.vmem[p] for p in ps)
                    for r, ps in clusters.items()}
    node_load = {n.name: 0.0 for n in live}
    assign: Dict[int, str] = {}
    for r in sorted(clusters, key=lambda r: -cluster_load[r]):
        tgt = min(live, key=lambda n: node_load[n.name])
        for p in clusters[r]:
            assign[p] = tgt.name
        node_load[tgt.name] += cluster_load[r]

    # --- KL-style refinement (vectorised best-move greedy) ---------------------
    _refine(g, parts, assign, live, alpha, beta, refine_iters)

    stamp_nodes(pgt, assign)
    return assign


def _refine(g: PartitionGraph, parts: List[int], assign: Dict[int, str],
            live: Sequence[NodeInfo], alpha: float, beta: float,
            refine_iters: int) -> None:
    """Greedy refinement of ``alpha * imbalance + beta * cut_volume``.

    Array-native: the Δcost of moving any partition to any node is
    evaluated for ALL (partition, node) pairs at once —

    * Δimbalance (sum of squared node loads) is ``2 w_p (L_t - L_s + w_p)``,
    * Δcut is ``cut_to[p, s] - cut_to[p, t]`` where ``cut_to[p, t]`` is the
      weight of p's edges into partitions currently on node t (one
      ``np.add.at`` per round over the partition-graph edge list) —

    and the single best move is applied per round, until no move improves.
    O(iters · (P·m + E_p)) instead of the old first-improving-move scan's
    O(iters · P·m·E_p), which dominated deploy beyond ~10^4 partitions.
    """
    nparts = len(parts)
    m = len(live)
    if nparts == 0 or m <= 1:
        return
    pidx = {p: i for i, p in enumerate(parts)}
    nidx = {n.name: j for j, n in enumerate(live)}
    w = np.fromiter((g.vweights[p] + 1e-6 * g.vmem[p] for p in parts),
                    dtype=np.float64, count=nparts)
    a = np.fromiter((nidx[assign[p]] for p in parts), dtype=np.int64,
                    count=nparts)
    loads = np.zeros(m, dtype=np.float64)
    np.add.at(loads, a, w)
    if g.eweights:
        ea = np.fromiter((pidx[x] for x, _ in g.eweights), dtype=np.int64,
                         count=len(g.eweights))
        eb = np.fromiter((pidx[y] for _, y in g.eweights), dtype=np.int64,
                         count=len(g.eweights))
        ew = np.fromiter(g.eweights.values(), dtype=np.float64,
                         count=len(g.eweights))
        if not ew.any():
            ew = np.empty(0, dtype=np.float64)
    else:
        ew = np.empty(0, dtype=np.float64)
    rows = np.arange(nparts)
    for _ in range(refine_iters):
        if ew.size:
            cut_to = np.zeros((nparts, m))
            np.add.at(cut_to, (ea, a[eb]), ew)
            np.add.at(cut_to, (eb, a[ea]), ew)
            d_cut = cut_to[rows, a][:, None] - cut_to
        else:
            d_cut = 0.0
        d_imb = 2.0 * w[:, None] * (loads[None, :] - loads[a][:, None]
                                    + w[:, None])
        delta = alpha * d_imb + beta * d_cut
        delta[rows, a] = 0.0
        best = int(np.argmin(delta))
        p, t = divmod(best, m)
        if not delta[p, t] + 1e-15 < 0.0:
            break
        loads[a[p]] -= w[p]
        loads[t] += w[p]
        a[p] = t
    for i, p in enumerate(parts):
        assign[p] = live[int(a[i])].name


def stamp_nodes(pgt, assign: Dict[int, str]) -> None:
    """Write a partition->node assignment onto the PGT's placement field.

    Array path: one lookup-table gather writes the whole ``node_ids``
    array (no DropSpec views are materialised); dict path: per-spec
    attribute writes.  ``assign``'s keys are exactly the partition ids
    occurring in the PGT, so the sentinel-shifted index covers them.
    """
    if isinstance(pgt, CompiledPGT):
        _, idx, shift, span = pgt.partition_index()
        table = np.full(span, -1, dtype=np.int32)
        for p, node_name in assign.items():
            table[p + shift] = pgt.node_id_for(node_name)
        pgt.node_ids = table[idx]
    else:
        for spec in pgt.drops.values():
            spec.node = assign[spec.partition]
