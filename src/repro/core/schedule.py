"""Scheduling cost model for partitioned physical graphs (paper §3.4–§3.5).

Estimates the makespan of a partitioned PGT under the paper's assumptions:

* intra-partition edges are free (drops are co-located),
* inter-partition edges cost ``data_volume / bandwidth`` (data movement),
* each partition executes at most ``DoP`` application drops concurrently,
* resources are homogeneous.

Two graph representations are supported and must agree exactly:

* the legacy dict-of-``DropSpec`` :class:`PhysicalGraphTemplate`,
* the array-based :class:`repro.core.pgt.CompiledPGT` (CSR adjacency).

Both run the *canonical* event-driven simulation below.  Determinism rules
(so the two paths produce bit-identical makespans):

* ties are broken by dense drop id == creation order (identical in both
  representations — leaves in ``lg.leaves()`` order, instances in C-order),
* at equal times, app completions are processed before readiness events,
* each partition's waiting queue pops by (enqueue time, drop id),
* empty PGTs have makespan / critical path 0.0; a single drop's makespan is
  its weight (these edge cases previously diverged between ``0.0`` and
  ``max()``-of-empty errors).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .pgt import KIND_DATA, CompiledPGT, _kahn_levels, coo_to_csr
from .substrate import level_structure as _level_structure
from .unroll import PhysicalGraphTemplate

DEFAULT_BANDWIDTH = 1e9   # bytes/s across partitions (homogeneous links)

_EV_DONE = 0     # app finished (frees a DoP slot) — processed first
_EV_READY = 1    # drop became ready


def edge_cost(pgt, src: str, dst: str,
              bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """Cost of an edge if it crosses partitions: moving the data payload."""
    s = pgt.drops[src]
    d = pgt.drops[dst]
    vol = s.data_volume if s.kind == "data" else d.data_volume
    return vol / bandwidth


# ---------------------------------------------------------------------------
# array extraction (shared by the canonical kernels)
# ---------------------------------------------------------------------------


class _Arrays:
    """Flat int/float arrays for one PGT, cached on the PGT object.

    ``partition`` is re-read on every use (it mutates between calls); the
    structural fields are extracted once.
    """

    __slots__ = ("n", "weight", "is_data", "esrc", "edst", "evol",
                 "levels", "_order", "_build_order", "_out_csr",
                 "_build_out_csr", "_lists", "_ecost_l", "_lvl_struct",
                 "_in_csr")

    def __init__(self) -> None:
        self._order = None      # topological order, lazy (rarely used)
        self._build_order = None
        self._out_csr = None    # (indptr, dst ids, eid) by source, lazy
        self._lists = None      # (weight, is_data, indptr, out_dst, preds)
        self._ecost_l = None    # (bandwidth, CSR-ordered edge costs)
        self._lvl_struct = None  # level-bucketed edge/node orders
        self._in_csr = None     # (indptr, src ids, eid) by destination

    @property
    def order(self) -> np.ndarray:
        if self._order is None:
            self._order = self._build_order()
        return self._order

    @order.setter
    def order(self, value: np.ndarray) -> None:
        self._order = value

    @property
    def out_indptr(self) -> np.ndarray:
        return self.out_csr()[0]

    @property
    def out_dst(self) -> np.ndarray:
        return self.out_csr()[1]

    @property
    def out_eid(self) -> np.ndarray:
        return self.out_csr()[2]

    def out_csr(self):
        """Forward CSR, built on first use — the large-graph estimator
        path never touches it unless a delta propagation runs."""
        if self._out_csr is None:
            self._out_csr = self._build_out_csr()
        return self._out_csr

    def partition_of(self, pgt) -> np.ndarray:
        if isinstance(pgt, CompiledPGT):
            return pgt.partition
        part = np.empty(self.n, dtype=np.int64)
        for i, spec in enumerate(pgt.drops.values()):
            part[i] = spec.partition
        return part

    def sim_lists(self, bandwidth: float):
        """Python-list views of the static simulation inputs, cached —
        only the partition labels change between simulate calls."""
        if self._lists is None:
            self._lists = (
                self.weight.tolist(), self.is_data.tolist(),
                self.out_indptr.tolist(), self.out_dst.tolist(),
                np.bincount(self.edst, minlength=self.n).tolist())
        if self._ecost_l is None or self._ecost_l[0] != bandwidth:
            self._ecost_l = (
                bandwidth, (self.evol / bandwidth)[self.out_eid].tolist())
        return self._lists + (self._ecost_l[1],)

    def level_structure(self):
        """Level-bucketed edge and node orders for the critical-path pass.

        Partition-independent (only edge *costs* change between calls), so
        it is computed once per PGT and shared by every evaluation — the
        prefix sweep in ``min_time`` used to redo these argsorts at every
        checkpoint.  Returns ``(esrc_s, edst_s, eid_s, bounds, node_order,
        nbounds, max_level)``; the edge triplets are sorted by destination
        level with ``bounds[lv]:bounds[lv+1]`` slicing out one level.
        """
        if self._lvl_struct is None:
            # the computation lives in core/substrate.py — it is the
            # partition-independent piece of the shared level substrate
            self._lvl_struct = _level_structure(self.levels, self.esrc,
                                                self.edst, self.n)
        return self._lvl_struct

    def in_csr(self):
        """(indptr, src ids, COO edge ids) sorted by destination."""
        if self._in_csr is None:
            self._in_csr = coo_to_csr(self.n, self.edst, self.esrc)
        return self._in_csr


def _extract(pgt) -> _Arrays:
    cached = getattr(pgt, "_sched_arrays", None)
    if cached is not None:
        return cached
    a = _Arrays()
    if isinstance(pgt, CompiledPGT):
        a.n = pgt.num_drops
        a.weight = pgt.weight_arr
        a.is_data = pgt.kind_arr == KIND_DATA
        # int32 stays int32: every consumer (bincount, level bucketing,
        # PrefixCP gathers, coo_to_csr) is dtype-generic, and the 10M
        # tier saves two 80MB widening copies here
        a.esrc = pgt.edge_src
        a.edst = pgt.edge_dst
        a.evol = pgt.edge_volumes()
        a.levels = pgt.topo_levels()
        a._build_order = pgt.topological_order_ids
    else:
        ids: Dict[str, int] = {u: i for i, u in enumerate(pgt.drops)}
        a.n = len(ids)
        a.weight = np.fromiter(
            (s.weight() for s in pgt.drops.values()), dtype=np.float64,
            count=a.n)
        a.is_data = np.fromiter(
            (s.kind == "data" for s in pgt.drops.values()), dtype=bool,
            count=a.n)
        ne = len(pgt.edges)
        a.esrc = np.empty(ne, dtype=np.int64)
        a.edst = np.empty(ne, dtype=np.int64)
        a.evol = np.empty(ne, dtype=np.float64)
        drops = pgt.drops
        for k, (s, d, _) in enumerate(pgt.edges):
            si, di = ids[s], ids[d]
            a.esrc[k] = si
            a.edst[k] = di
            ss = drops[s]
            a.evol[k] = (ss.data_volume if ss.kind == "data"
                         else drops[d].data_volume)
        a.order, a.levels = _kahn_levels(a.n, a.esrc, a.edst)
    if isinstance(pgt, CompiledPGT):
        a._build_out_csr = pgt.out_csr_with_eid
    else:
        a._build_out_csr = lambda: coo_to_csr(a.n, a.esrc, a.edst)
    try:
        pgt._sched_arrays = a
    except AttributeError:  # pragma: no cover - slots-only containers
        pass
    return a


# NOTE: structural mutation invalidates this cache at the mutation sites —
# PhysicalGraphTemplate.add_drop/add_edge pop ``_sched_arrays`` directly.

# ---------------------------------------------------------------------------
# critical path (vectorized, level-synchronous)
# ---------------------------------------------------------------------------


def _critical_path_dist(a: _Arrays, part: Optional[np.ndarray],
                        bandwidth: float) -> np.ndarray:
    """Per-drop longest-path finish time; edges cost vol/bandwidth when
    crossing partitions (or always, when ``part`` is None — the
    unpartitioned bound).  Level-synchronous over the cached
    :meth:`_Arrays.level_structure` — no per-call argsorts."""
    dist = np.zeros(a.n, dtype=np.float64)
    if a.n == 0:
        return dist
    esrc_s, edst_s, e_order, bounds, node_order, nbounds, max_lv = \
        a.level_structure()
    ecost = a.evol / bandwidth
    if part is not None and a.esrc.size:
        ecost = ecost * (part[a.esrc] != part[a.edst])
    ecost_s = ecost[e_order]
    best = np.zeros(a.n, dtype=np.float64)
    for lv in range(max_lv + 1):
        nodes = node_order[nbounds[lv]:nbounds[lv + 1]]
        if lv > 0 and bounds is not None and lv < len(bounds) - 1:
            lo, hi = bounds[lv], bounds[lv + 1]
            if hi > lo:
                np.maximum.at(best, edst_s[lo:hi],
                              dist[esrc_s[lo:hi]] + ecost_s[lo:hi])
        dist[nodes] = best[nodes] + a.weight[nodes]
    return dist


def _critical_path_arrays(a: _Arrays, part: Optional[np.ndarray],
                          bandwidth: float) -> float:
    if a.n == 0:
        return 0.0
    return float(_critical_path_dist(a, part, bandwidth).max())


class PrefixCP:
    """Incremental partitioned critical-path evaluator.

    Tracks the longest-path state (per-drop finish times) across a
    *sequence* of label assignments over one graph.  Each
    :meth:`evaluate` call recomputes only the region downstream of edges
    whose partition-crossing status changed since the previous call —
    during ``min_time``'s prefix sweep the merges are monotone (edges only
    become internal), so consecutive checkpoints share almost all of their
    critical-path state.  Arbitrary relabelings (e.g. ``min_res`` fold
    probes) are also handled — recompute cost stays proportional to the
    affected region, degrading to one full pass at worst.  Every step is
    exactly equivalent to ``_critical_path_arrays(a, labels, bandwidth)``.
    """

    def __init__(self, a: _Arrays, bandwidth: float) -> None:
        self.a = a
        self.bandwidth = bandwidth
        self._ecost = a.evol / bandwidth
        # a zero-cost edge contributes nothing whether it crosses or not —
        # its status changes can never move the critical path, so the
        # delta pass ignores them outright (app->data edges of volume-0
        # drops are common, and entire cost-free graphs short-circuit)
        self._costly = self._ecost != 0.0
        self._has_costly = bool(self._costly.any())
        # a graph with no costly edges AND no weights schedules to 0.0
        # under any labelling — the degenerate overhead-bench shape
        self._zero = (not self._has_costly
                      and (a.n == 0 or float(a.weight.max()) == 0.0))
        self._cross: Optional[np.ndarray] = None   # per-edge crossing mask
        self._dist: Optional[np.ndarray] = None
        self._in: Optional[Tuple[np.ndarray, ...]] = None
        self.delta_evals = 0      # instrumentation: delta vs full passes
        self.full_evals = 0

    # -- internals ---------------------------------------------------------
    def _full(self, labels: Optional[np.ndarray]) -> float:
        self._dist = _critical_path_dist(self.a, labels, self.bandwidth)
        self.full_evals += 1
        return float(self._dist.max()) if self.a.n else 0.0

    def _push(self, pend: Dict[int, List[np.ndarray]],
              nodes: np.ndarray) -> None:
        ls = self.a.levels[nodes]
        order = np.argsort(ls, kind="stable")
        nodes, ls = nodes[order], ls[order]
        cuts = np.flatnonzero(np.diff(ls)) + 1
        starts = np.concatenate(([0], cuts))
        for chunk, lv in zip(np.split(nodes, cuts), ls[starts]):
            pend.setdefault(int(lv), []).append(chunk)

    def evaluate(self, labels: Optional[np.ndarray]) -> float:
        a = self.a
        if a.n == 0 or self._zero:
            return 0.0
        if self._dist is not None and not self._has_costly:
            # crossing-status changes cannot move any path cost
            return float(self._dist.max())
        if a.esrc.size == 0:
            cross = np.empty(0, dtype=bool)
        elif labels is None:
            cross = np.ones(a.esrc.shape[0], dtype=bool)
        else:
            cross = labels[a.esrc] != labels[a.edst]
        if self._dist is None:
            self._cross = cross
            return self._full(labels)
        changed = np.flatnonzero((cross != self._cross) & self._costly)
        self._cross = cross
        if changed.size == 0:
            return float(self._dist.max())
        self.delta_evals += 1
        return self._propagate(np.unique(a.edst[changed]))

    def _propagate(self, seeds: np.ndarray) -> float:
        """Level-ordered recompute of ``dist`` for ``seeds`` and whatever
        their changes reach downstream."""
        a = self.a
        dist = self._dist
        cross = self._cross
        assert dist is not None and cross is not None
        if a.esrc.size == 0:
            np.copyto(dist, a.weight)
            return float(dist.max())
        if self._in is None:
            in_indptr, in_src, in_eid = a.in_csr()
            self._in = (in_indptr, in_src, in_eid, self._ecost[in_eid])
        in_indptr, in_src, in_eid, in_cost = self._in
        pend: Dict[int, List[np.ndarray]] = {}
        self._push(pend, seeds)
        while pend:
            lv = min(pend)
            nodes = np.unique(np.concatenate(pend.pop(lv)))
            starts = in_indptr[nodes]
            cnt = in_indptr[nodes + 1] - starts
            new = a.weight[nodes].copy()    # no-pred base: weight alone
            total = int(cnt.sum())
            if total:
                reps = np.repeat(
                    starts - np.concatenate(([0], np.cumsum(cnt)[:-1])),
                    cnt)
                pos = np.arange(total, dtype=np.int64) + reps
                cand = dist[in_src[pos]] \
                    + in_cost[pos] * cross[in_eid[pos]]
                nz = cnt > 0
                row_start = np.concatenate(([0], np.cumsum(cnt)[:-1]))
                new[nz] = np.maximum.reduceat(cand, row_start[nz]) \
                    + a.weight[nodes[nz]]
            moved = new != dist[nodes]
            if moved.any():
                chn = nodes[moved]
                dist[chn] = new[moved]
                s0 = a.out_indptr[chn]
                c0 = a.out_indptr[chn + 1] - s0
                tot = int(c0.sum())
                if tot:
                    reps = np.repeat(
                        s0 - np.concatenate(([0], np.cumsum(c0)[:-1])), c0)
                    pos2 = np.arange(tot, dtype=np.int64) + reps
                    self._push(pend, np.unique(a.out_dst[pos2]))
        return float(dist.max())


def critical_path(pgt, bandwidth: float = DEFAULT_BANDWIDTH,
                  partitioned: bool = True) -> float:
    """Longest path through the DAG (execution + cross-partition movement)."""
    a = _extract(pgt)
    part = a.partition_of(pgt) if partitioned else None
    return _critical_path_arrays(a, part, bandwidth)


# ---------------------------------------------------------------------------
# canonical makespan simulation
# ---------------------------------------------------------------------------


def _simulate_arrays(a: _Arrays, part: np.ndarray, dop: int,
                     bandwidth: float) -> float:
    """Canonical list-scheduling event simulation over int drop ids."""
    n = a.n
    if n == 0:
        return 0.0
    # plain python lists: ~5x faster scalar access than numpy in this loop
    weight, is_data, indptr, out_dst, preds0, ecost = a.sim_lists(bandwidth)
    partl = part.tolist() if isinstance(part, np.ndarray) else list(part)
    preds_left = list(preds0)
    ready_at = [0.0] * n

    evq: List[Tuple[float, int, int]] = []
    running: Dict[int, int] = {}
    waiting: Dict[int, List[Tuple[float, int]]] = {}
    makespan = 0.0

    for u in range(n):
        if preds_left[u] == 0:
            evq.append((0.0, _EV_READY, u))
    heapq.heapify(evq)

    def complete(u: int, t: float) -> None:
        nonlocal makespan
        if t > makespan:
            makespan = t
        pu = partl[u]
        for j in range(indptr[u], indptr[u + 1]):
            s = out_dst[j]
            cost = ecost[j] if partl[s] != pu else 0.0
            ra = t + cost
            if ra > ready_at[s]:
                ready_at[s] = ra
            preds_left[s] -= 1
            if preds_left[s] == 0:
                heapq.heappush(evq, (ready_at[s], _EV_READY, s))

    def try_start(p: int, t: float) -> None:
        q = waiting.get(p)
        while q and running.get(p, 0) < dop:
            _, u = heapq.heappop(q)
            running[p] = running.get(p, 0) + 1
            heapq.heappush(evq, (t + weight[u], _EV_DONE, u))

    while evq:
        t, kind, u = heapq.heappop(evq)
        if kind == _EV_DONE:
            p = partl[u]
            running[p] -= 1
            complete(u, t)
            try_start(p, t)
            continue
        if is_data[u] or weight[u] == 0.0:
            complete(u, t)
            continue
        p = partl[u]
        heapq.heappush(waiting.setdefault(p, []), (t, u))
        try_start(p, t)

    return makespan


def simulate_makespan(pgt, dop: int,
                      bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """List-scheduling simulation honouring the per-partition DoP cap.

    Event-driven: an app drop becomes ready when all its predecessors
    finished (plus cross-partition transfer latency); each partition runs
    at most ``dop`` apps at once.  Data drops are free.  Works identically
    for dict-based and array-based PGTs (see module docstring).
    """
    a = _extract(pgt)
    return _simulate_arrays(a, a.partition_of(pgt), dop, bandwidth)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------


def partition_stats(pgt) -> Dict[str, float]:
    if isinstance(pgt, CompiledPGT):
        if pgt.num_drops == 0:
            return {"num_partitions": 0.0, "cross_volume": 0.0,
                    "max_load": 0.0, "mean_load": 0.0, "imbalance": 1.0}
        ids, loads = pgt.partition_loads(pgt.weight_arr)
        part = pgt.partition
        cross = part[pgt.edge_src] != part[pgt.edge_dst]
        cross_volume = float(pgt.edge_volumes()[cross].sum())
        nump = float(ids.size)
    else:
        parts: Dict[int, float] = {}
        for uid, spec in pgt.drops.items():
            parts[spec.partition] = (parts.get(spec.partition, 0.0)
                                     + spec.weight())
        cross_volume = 0.0
        for s, d, _ in pgt.edges:
            if pgt.drops[s].partition != pgt.drops[d].partition:
                sp = pgt.drops[s]
                cross_volume += (sp.data_volume if sp.kind == "data"
                                 else pgt.drops[d].data_volume)
        loads = list(parts.values())
        nump = float(len(parts))
    loads = list(np.asarray(loads, dtype=np.float64)) or [0.0]
    return {
        "num_partitions": nump,
        "cross_volume": cross_volume,
        "max_load": float(max(loads)),
        "mean_load": float(sum(loads) / len(loads)),
        "imbalance": float(max(loads) / max(sum(loads) / len(loads), 1e-12)),
    }
