"""Scheduling cost model for partitioned physical graphs (paper §3.4–§3.5).

Estimates the makespan of a partitioned PGT under the paper's assumptions:

* intra-partition edges are free (drops are co-located),
* inter-partition edges cost ``data_volume / bandwidth`` (data movement),
* each partition executes at most ``DoP`` application drops concurrently,
* resources are homogeneous.

Used both by the ``min_time`` / ``min_res`` partitioners as their objective
and by the partition-quality benchmark.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .unroll import PhysicalGraphTemplate

DEFAULT_BANDWIDTH = 1e9   # bytes/s across partitions (homogeneous links)


def edge_cost(pgt: PhysicalGraphTemplate, src: str, dst: str,
              bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """Cost of an edge if it crosses partitions: moving the data payload."""
    s = pgt.drops[src]
    d = pgt.drops[dst]
    vol = s.data_volume if s.kind == "data" else d.data_volume
    return vol / bandwidth


def critical_path(pgt: PhysicalGraphTemplate,
                  bandwidth: float = DEFAULT_BANDWIDTH,
                  partitioned: bool = True) -> float:
    """Longest path through the DAG (execution + cross-partition movement)."""
    dist: Dict[str, float] = {}
    for uid in pgt.topological_order():
        spec = pgt.drops[uid]
        best = 0.0
        for p in pgt.predecessors(uid):
            c = 0.0
            if (not partitioned) or (pgt.drops[p].partition !=
                                     spec.partition):
                c = edge_cost(pgt, p, uid, bandwidth)
            best = max(best, dist[p] + c)
        dist[uid] = best + spec.weight()
    return max(dist.values()) if dist else 0.0


def simulate_makespan(pgt: PhysicalGraphTemplate, dop: int,
                      bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """List-scheduling simulation honouring the per-partition DoP cap.

    Event-driven simulation: an app drop becomes ready when all its
    predecessors finished (plus cross-partition transfer latency); each
    partition runs at most ``dop`` apps at once.  Data drops are free.
    """
    preds_left: Dict[str, int] = {}
    ready_at: Dict[str, float] = {}
    for uid in pgt.drops:
        preds_left[uid] = len(pgt.predecessors(uid))
        ready_at[uid] = 0.0

    # (time, seq, kind, uid) events; kind 0 = drop became ready, 1 = app done
    evq: List[Tuple[float, int, int, str]] = []
    seq = 0
    running: Dict[int, int] = {}     # partition -> running apps
    waiting: Dict[int, List[Tuple[float, int, str]]] = {}
    finished_at: Dict[str, float] = {}
    makespan = 0.0

    def push_ready(uid: str, t: float) -> None:
        nonlocal seq
        heapq.heappush(evq, (t, seq, 0, uid))
        seq += 1

    for uid in pgt.roots():
        push_ready(uid, 0.0)

    def try_start(part: int, t: float) -> None:
        nonlocal seq
        q = waiting.get(part)
        while q and running.get(part, 0) < dop:
            _, _, uid = heapq.heappop(q)
            running[part] = running.get(part, 0) + 1
            dur = pgt.drops[uid].weight()
            heapq.heappush(evq, (t + dur, seq, 1, uid))
            seq += 1

    def complete(uid: str, t: float) -> None:
        nonlocal makespan
        finished_at[uid] = t
        makespan = max(makespan, t)
        spec = pgt.drops[uid]
        for s in pgt.successors(uid):
            cost = 0.0
            if pgt.drops[s].partition != spec.partition:
                cost = edge_cost(pgt, uid, s, bandwidth)
            ready_at[s] = max(ready_at[s], t + cost)
            preds_left[s] -= 1
            if preds_left[s] == 0:
                push_ready(s, ready_at[s])

    while evq:
        t, _, kind, uid = heapq.heappop(evq)
        spec = pgt.drops[uid]
        if kind == 1:                       # app finished
            running[spec.partition] -= 1
            complete(uid, t)
            try_start(spec.partition, t)
            continue
        # drop became ready
        if spec.kind == "data" or spec.weight() == 0.0:
            complete(uid, t)
            continue
        part = spec.partition
        heapq.heappush(waiting.setdefault(part, []), (t, id(uid), uid))
        try_start(part, t)

    return makespan


def partition_stats(pgt: PhysicalGraphTemplate) -> Dict[str, float]:
    parts: Dict[int, float] = {}
    cross_volume = 0.0
    for uid, spec in pgt.drops.items():
        parts[spec.partition] = parts.get(spec.partition, 0.0) + spec.weight()
    for s, d, _ in pgt.edges:
        if pgt.drops[s].partition != pgt.drops[d].partition:
            sp = pgt.drops[s]
            cross_volume += (sp.data_volume if sp.kind == "data"
                             else pgt.drops[d].data_volume)
    loads = list(parts.values()) or [0.0]
    return {
        "num_partitions": float(len(parts)),
        "cross_volume": cross_volume,
        "max_load": max(loads),
        "mean_load": sum(loads) / len(loads),
        "imbalance": max(loads) / max(sum(loads) / len(loads), 1e-12),
    }
