"""Scheduling cost model for partitioned physical graphs (paper §3.4–§3.5).

Estimates the makespan of a partitioned PGT under the paper's assumptions:

* intra-partition edges are free (drops are co-located),
* inter-partition edges cost ``data_volume / bandwidth`` (data movement),
* each partition executes at most ``DoP`` application drops concurrently,
* resources are homogeneous.

Two graph representations are supported and must agree exactly:

* the legacy dict-of-``DropSpec`` :class:`PhysicalGraphTemplate`,
* the array-based :class:`repro.core.pgt.CompiledPGT` (CSR adjacency).

Both run the *canonical* event-driven simulation below.  Determinism rules
(so the two paths produce bit-identical makespans):

* ties are broken by dense drop id == creation order (identical in both
  representations — leaves in ``lg.leaves()`` order, instances in C-order),
* at equal times, app completions are processed before readiness events,
* each partition's waiting queue pops by (enqueue time, drop id),
* empty PGTs have makespan / critical path 0.0; a single drop's makespan is
  its weight (these edge cases previously diverged between ``0.0`` and
  ``max()``-of-empty errors).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .pgt import KIND_DATA, CompiledPGT, _kahn_levels, coo_to_csr
from .unroll import PhysicalGraphTemplate

DEFAULT_BANDWIDTH = 1e9   # bytes/s across partitions (homogeneous links)

_EV_DONE = 0     # app finished (frees a DoP slot) — processed first
_EV_READY = 1    # drop became ready


def edge_cost(pgt, src: str, dst: str,
              bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """Cost of an edge if it crosses partitions: moving the data payload."""
    s = pgt.drops[src]
    d = pgt.drops[dst]
    vol = s.data_volume if s.kind == "data" else d.data_volume
    return vol / bandwidth


# ---------------------------------------------------------------------------
# array extraction (shared by the canonical kernels)
# ---------------------------------------------------------------------------


class _Arrays:
    """Flat int/float arrays for one PGT, cached on the PGT object.

    ``partition`` is re-read on every use (it mutates between calls); the
    structural fields are extracted once.
    """

    __slots__ = ("n", "weight", "is_data", "esrc", "edst", "evol",
                 "out_indptr", "out_dst", "out_eid", "levels", "order",
                 "_lists", "_ecost_l")

    def __init__(self) -> None:
        self._lists = None      # (weight, is_data, indptr, out_dst, preds)
        self._ecost_l = None    # (bandwidth, CSR-ordered edge costs)

    def partition_of(self, pgt) -> np.ndarray:
        if isinstance(pgt, CompiledPGT):
            return pgt.partition
        part = np.empty(self.n, dtype=np.int64)
        for i, spec in enumerate(pgt.drops.values()):
            part[i] = spec.partition
        return part

    def sim_lists(self, bandwidth: float):
        """Python-list views of the static simulation inputs, cached —
        only the partition labels change between simulate calls."""
        if self._lists is None:
            self._lists = (
                self.weight.tolist(), self.is_data.tolist(),
                self.out_indptr.tolist(), self.out_dst.tolist(),
                np.bincount(self.edst, minlength=self.n).tolist())
        if self._ecost_l is None or self._ecost_l[0] != bandwidth:
            self._ecost_l = (
                bandwidth, (self.evol / bandwidth)[self.out_eid].tolist())
        return self._lists + (self._ecost_l[1],)


def _extract(pgt) -> _Arrays:
    cached = getattr(pgt, "_sched_arrays", None)
    if cached is not None:
        return cached
    a = _Arrays()
    if isinstance(pgt, CompiledPGT):
        a.n = pgt.num_drops
        a.weight = pgt.weight_arr
        a.is_data = pgt.kind_arr == KIND_DATA
        a.esrc = pgt.edge_src.astype(np.int64)
        a.edst = pgt.edge_dst.astype(np.int64)
        a.evol = pgt.edge_volumes()
        a.levels = pgt.topo_levels()
        a.order = pgt.topological_order_ids()
    else:
        ids: Dict[str, int] = {u: i for i, u in enumerate(pgt.drops)}
        a.n = len(ids)
        a.weight = np.fromiter(
            (s.weight() for s in pgt.drops.values()), dtype=np.float64,
            count=a.n)
        a.is_data = np.fromiter(
            (s.kind == "data" for s in pgt.drops.values()), dtype=bool,
            count=a.n)
        ne = len(pgt.edges)
        a.esrc = np.empty(ne, dtype=np.int64)
        a.edst = np.empty(ne, dtype=np.int64)
        a.evol = np.empty(ne, dtype=np.float64)
        drops = pgt.drops
        for k, (s, d, _) in enumerate(pgt.edges):
            si, di = ids[s], ids[d]
            a.esrc[k] = si
            a.edst[k] = di
            ss = drops[s]
            a.evol[k] = (ss.data_volume if ss.kind == "data"
                         else drops[d].data_volume)
        a.order, a.levels = _kahn_levels(a.n, a.esrc, a.edst)
    if isinstance(pgt, CompiledPGT):
        a.out_indptr, a.out_dst, a.out_eid = pgt.out_csr_with_eid()
    else:
        a.out_indptr, a.out_dst, a.out_eid = coo_to_csr(a.n, a.esrc, a.edst)
    try:
        pgt._sched_arrays = a
    except AttributeError:  # pragma: no cover - slots-only containers
        pass
    return a


# NOTE: structural mutation invalidates this cache at the mutation sites —
# PhysicalGraphTemplate.add_drop/add_edge pop ``_sched_arrays`` directly.

# ---------------------------------------------------------------------------
# critical path (vectorized, level-synchronous)
# ---------------------------------------------------------------------------


def _critical_path_arrays(a: _Arrays, part: Optional[np.ndarray],
                          bandwidth: float) -> float:
    """Longest path; edges cost vol/bandwidth when crossing partitions
    (or always, when ``part`` is None — the unpartitioned bound)."""
    if a.n == 0:
        return 0.0
    ecost = a.evol / bandwidth
    if part is not None and a.esrc.size:
        ecost = ecost * (part[a.esrc] != part[a.edst])
    dist = np.zeros(a.n, dtype=np.float64)
    best = np.zeros(a.n, dtype=np.float64)
    levels = a.levels
    if a.esrc.size:
        edge_lv = levels[a.edst]
        e_order = np.argsort(edge_lv, kind="stable")
        edge_lv_sorted = edge_lv[e_order]
        bounds = np.searchsorted(
            edge_lv_sorted, np.arange(edge_lv_sorted[-1] + 2))
        esrc_s, edst_s, ecost_s = (a.esrc[e_order], a.edst[e_order],
                                   ecost[e_order])
    else:
        bounds = None
    node_order = np.argsort(levels, kind="stable")
    node_lv_sorted = levels[node_order]
    nbounds = np.searchsorted(
        node_lv_sorted, np.arange(int(levels.max()) + 2))
    for lv in range(int(levels.max()) + 1):
        nodes = node_order[nbounds[lv]:nbounds[lv + 1]]
        if lv > 0 and bounds is not None and lv < len(bounds) - 1:
            lo, hi = bounds[lv], bounds[lv + 1]
            if hi > lo:
                np.maximum.at(best, edst_s[lo:hi],
                              dist[esrc_s[lo:hi]] + ecost_s[lo:hi])
        dist[nodes] = best[nodes] + a.weight[nodes]
    return float(dist.max())


def critical_path(pgt, bandwidth: float = DEFAULT_BANDWIDTH,
                  partitioned: bool = True) -> float:
    """Longest path through the DAG (execution + cross-partition movement)."""
    a = _extract(pgt)
    part = a.partition_of(pgt) if partitioned else None
    return _critical_path_arrays(a, part, bandwidth)


# ---------------------------------------------------------------------------
# canonical makespan simulation
# ---------------------------------------------------------------------------


def _simulate_arrays(a: _Arrays, part: np.ndarray, dop: int,
                     bandwidth: float) -> float:
    """Canonical list-scheduling event simulation over int drop ids."""
    n = a.n
    if n == 0:
        return 0.0
    # plain python lists: ~5x faster scalar access than numpy in this loop
    weight, is_data, indptr, out_dst, preds0, ecost = a.sim_lists(bandwidth)
    partl = part.tolist() if isinstance(part, np.ndarray) else list(part)
    preds_left = list(preds0)
    ready_at = [0.0] * n

    evq: List[Tuple[float, int, int]] = []
    running: Dict[int, int] = {}
    waiting: Dict[int, List[Tuple[float, int]]] = {}
    makespan = 0.0

    for u in range(n):
        if preds_left[u] == 0:
            evq.append((0.0, _EV_READY, u))
    heapq.heapify(evq)

    def complete(u: int, t: float) -> None:
        nonlocal makespan
        if t > makespan:
            makespan = t
        pu = partl[u]
        for j in range(indptr[u], indptr[u + 1]):
            s = out_dst[j]
            cost = ecost[j] if partl[s] != pu else 0.0
            ra = t + cost
            if ra > ready_at[s]:
                ready_at[s] = ra
            preds_left[s] -= 1
            if preds_left[s] == 0:
                heapq.heappush(evq, (ready_at[s], _EV_READY, s))

    def try_start(p: int, t: float) -> None:
        q = waiting.get(p)
        while q and running.get(p, 0) < dop:
            _, u = heapq.heappop(q)
            running[p] = running.get(p, 0) + 1
            heapq.heappush(evq, (t + weight[u], _EV_DONE, u))

    while evq:
        t, kind, u = heapq.heappop(evq)
        if kind == _EV_DONE:
            p = partl[u]
            running[p] -= 1
            complete(u, t)
            try_start(p, t)
            continue
        if is_data[u] or weight[u] == 0.0:
            complete(u, t)
            continue
        p = partl[u]
        heapq.heappush(waiting.setdefault(p, []), (t, u))
        try_start(p, t)

    return makespan


def simulate_makespan(pgt, dop: int,
                      bandwidth: float = DEFAULT_BANDWIDTH) -> float:
    """List-scheduling simulation honouring the per-partition DoP cap.

    Event-driven: an app drop becomes ready when all its predecessors
    finished (plus cross-partition transfer latency); each partition runs
    at most ``dop`` apps at once.  Data drops are free.  Works identically
    for dict-based and array-based PGTs (see module docstring).
    """
    a = _extract(pgt)
    return _simulate_arrays(a, a.partition_of(pgt), dop, bandwidth)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------


def partition_stats(pgt) -> Dict[str, float]:
    if isinstance(pgt, CompiledPGT):
        if pgt.num_drops == 0:
            return {"num_partitions": 0.0, "cross_volume": 0.0,
                    "max_load": 0.0, "mean_load": 0.0, "imbalance": 1.0}
        ids, loads = pgt.partition_loads(pgt.weight_arr)
        part = pgt.partition
        cross = part[pgt.edge_src] != part[pgt.edge_dst]
        cross_volume = float(pgt.edge_volumes()[cross].sum())
        nump = float(ids.size)
    else:
        parts: Dict[int, float] = {}
        for uid, spec in pgt.drops.items():
            parts[spec.partition] = (parts.get(spec.partition, 0.0)
                                     + spec.weight())
        cross_volume = 0.0
        for s, d, _ in pgt.edges:
            if pgt.drops[s].partition != pgt.drops[d].partition:
                sp = pgt.drops[s]
                cross_volume += (sp.data_volume if sp.kind == "data"
                                 else pgt.drops[d].data_volume)
        loads = list(parts.values())
        nump = float(len(parts))
    loads = list(np.asarray(loads, dtype=np.float64)) or [0.0]
    return {
        "num_partitions": nump,
        "cross_volume": cross_volume,
        "max_load": float(max(loads)),
        "mean_load": float(sum(loads) / len(loads)),
        "imbalance": float(max(loads) / max(sum(loads) / len(loads), 1e-12)),
    }
