"""EngineConfig — one typed object for every Pipeline mode switch.

``Pipeline`` historically grew ~10 keyword arguments whose legal
combinations were policed inside ``__init__``.  ``EngineConfig``
collapses them into a frozen dataclass and owns *all* mode validation in
one place (:meth:`EngineConfig.validate`), so the error surface is
identical whether a caller builds a config explicitly or goes through
the legacy kwargs (which now warn with ``DeprecationWarning`` exactly
once per construction).

The split mirrors the paper's separation between graph *translation*
policy (``algorithm``/``dop``/``deadline``), cluster *shape*
(``num_nodes``/``num_islands``/``workers_per_node``) and *execution*
substrate selection (``execution``/``resilience``/``stream``/services).
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Any, Optional, Union

from .resilience import ResilienceConfig
from .streaming import StreamConfig
from .telemetry import TelemetryConfig


@dataclass(frozen=True)
class EngineConfig:
    """Validated, immutable Pipeline configuration.

    ``stream`` selects the compiled engine's chunk-granular streaming
    lane: ``None`` auto-enables it whenever the graph has streaming
    edges, a :class:`~repro.core.streaming.StreamConfig` tunes ring
    capacity/backpressure, and ``False`` degrades streaming edges to
    batch dependencies (counted + warned once).
    """

    # cluster shape
    num_nodes: int = 2
    num_islands: int = 1
    workers_per_node: int = 4
    workers: str = "thread"   # "thread" | "process" (crash-isolated spawn workers)
    # translation policy
    dop: int = 8
    algorithm: str = "min_time"
    deadline: Optional[float] = None
    # execution substrate
    execution: str = "objects"
    enable_dlm: bool = False
    enable_stragglers: bool = False
    resilience: Optional[ResilienceConfig] = None
    manager: Any = None
    telemetry: Optional[TelemetryConfig] = None
    stream: Union[StreamConfig, bool, None] = None

    def validate(self) -> "EngineConfig":
        """Raise ``ValueError`` on any illegal mode combination.

        Every Pipeline mode error originates here — tests asserting on
        the messages exercise this single chokepoint.
        """
        if self.execution not in ("objects", "compiled"):
            raise ValueError(f"unknown execution mode {self.execution!r}")
        if self.workers not in ("thread", "process"):
            raise ValueError(f"unknown workers mode {self.workers!r} "
                             "(expected 'thread' or 'process')")
        if self.workers == "process" and self.execution != "compiled":
            raise ValueError(
                "workers='process' is the compiled engine's payload-plane "
                "mode; the object path dispatches per-drop callbacks that "
                "cannot cross a process boundary (use execution='compiled')")
        if self.workers == "process" and self.manager is not None:
            raise ValueError(
                "workers= shapes the Pipeline-owned cluster; a resident "
                "EngineManager owns its own (pass workers='process' to "
                "EngineManager instead)")
        if self.execution == "compiled" and (self.enable_dlm
                                             or self.enable_stragglers):
            raise ValueError(
                "compiled execution has no per-drop objects; DLM and "
                "straggler services need execution='objects'")
        if self.resilience is not None and self.execution != "compiled":
            raise ValueError(
                "resilience= is the compiled-path subsystem "
                "(core.resilience); the object path uses "
                "enable_stragglers / FaultManager (core.fault)")
        if self.stream is not None and self.execution != "compiled":
            raise ValueError(
                "stream= tunes the compiled engine's chunk lane; the "
                "object path streams natively per drop event "
                "(use execution='compiled')")
        if isinstance(self.stream, StreamConfig):
            self.stream.validate()
        if self.manager is not None:
            # ride a resident EngineManager: shared cluster + executors
            # + template cache; the Pipeline becomes a thin per-run view
            if self.execution != "compiled":
                raise ValueError(
                    "manager= serves compiled sessions; use "
                    "execution='compiled'")
            if self.resilience is not None:
                raise ValueError(
                    "resilience= mutates the shared template PGT "
                    "(node-failure remapping rewrites node_ids); run "
                    "a standalone Pipeline for fault-injection tiers")
        return self


#: legal legacy Pipeline(...) keyword names, in declaration order
LEGACY_KWARGS = tuple(f.name for f in fields(EngineConfig))


def config_from_kwargs(**kwargs: Any) -> EngineConfig:
    """Build + validate an :class:`EngineConfig` from legacy kwargs.

    Unknown names raise ``TypeError`` (matching the old signature's
    behaviour); mode errors raise ``ValueError`` from ``validate``.
    """
    unknown = set(kwargs) - set(LEGACY_KWARGS)
    if unknown:
        raise TypeError(
            f"Pipeline() got unexpected keyword argument(s) "
            f"{sorted(unknown)}")
    return EngineConfig(**kwargs).validate()
