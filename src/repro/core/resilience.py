"""Resilience for the compiled engine (paper §3.6 + §7, array-native).

``core.fault`` implements node-failure migration, straggler speculation
and bounded retries for the *object* engine — per-drop Python objects,
per-drop recursion.  This module is the same failure model lifted onto
the ``CompiledPGT`` / ``CompiledSession`` state arrays, where the
compiled path's 100x throughput advantage lives:

* **Node failure + lineage recovery** — :class:`CompiledFaultManager`
  computes the lost set (non-terminal drops on dead nodes plus volatile
  COMPLETED memory payloads there) and its upstream closure with
  vectorized reverse-CSR traversals (``pgt.in_csr`` + ``csr_gather``),
  remaps lost drops onto live nodes round-robin, resets state/payload
  rows in bulk and lets ``execute_frontier`` resume mid-wave — the
  scheduler re-derives its readiness counters from the state array.

* **Straggler speculation** — :class:`ResilientRunner` plugs into the
  dispatch layer (``ExecHooks.python_runner``): per-node wave batches run
  on the node's thread pool with deadline tracking; an app slower than
  ``factor`` x the median completed duration is duplicated onto the
  least-loaded live node, and the first writer commits into the dense
  payload table (the loser's buffered writes are discarded — no payload
  corruption, unlike raw double-execution).

* **Bounded retry** — a dispatch-layer policy (exponential backoff, no
  terminal sleep) instead of the object path's per-app ``with_retries``
  wrapper.

The object engine remains the semantic oracle: compiled recovery must
produce the same final status counts and payload values as
``fault.FaultManager.recover`` on identical failure scripts
(``tests/test_resilience_equiv.py`` enforces it).
"""
from __future__ import annotations

import statistics
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .exec_compiled import ExecHooks, _DataRef, _WaveTimeout, \
    execute_frontier, node_batches
from .managers import MasterDropManager
from .pgt import KIND_DATA, CompiledPGT, csr_gather
from .procpool import WorkerLost
from .session import (PK_FILE, PK_MEMORY, PK_NULL, ST_COMPLETED, ST_ERROR,
                      ST_INIT, CompiledSession)

__all__ = [
    "CompiledFaultManager", "FailureScript", "NodeFailureInterrupt",
    "ResilienceConfig", "ResilienceStats", "ResilientRunner", "RetryPolicy",
    "StragglerPolicy", "execute_resilient",
]


# ---------------------------------------------------------------------------
# Policy / configuration
# ---------------------------------------------------------------------------


@dataclass
class RetryPolicy:
    """Bounded re-attempts for registry apps (transient-failure guard)."""
    max_attempts: int = 3
    backoff: float = 0.0           # seconds; exponential: backoff * 2^k


@dataclass
class StragglerPolicy:
    """Speculative duplicate dispatch for slow apps (wave-deadline based).

    An app still uncommitted after ``factor`` x the median completed app
    duration (but at least ``min_runtime`` seconds) is duplicated onto the
    least-loaded live node; first writer wins."""
    factor: float = 3.0
    min_runtime: float = 0.05
    poll: float = 0.01


@dataclass
class FailureScript:
    """Scripted node death: kill ``node`` once the terminal-drop fraction
    reaches ``at_fraction`` (0.0 = before the first wave)."""
    node: str
    at_fraction: float = 0.5


@dataclass
class ResilienceConfig:
    failures: List[FailureScript] = field(default_factory=list)
    stragglers: Optional[StragglerPolicy] = None
    retry: Optional[RetryPolicy] = None

    @property
    def needs_runner(self) -> bool:
        return self.stragglers is not None or self.retry is not None


@dataclass
class ResilienceStats:
    recoveries: int = 0
    recovered_drops: int = 0
    speculative_wins: int = 0
    speculative_losses: int = 0
    retries: int = 0
    failed_nodes: List[str] = field(default_factory=list)
    recovery_seconds: float = 0.0      # lost-set closure+remap+reset, total


# ---------------------------------------------------------------------------
# Node failure + array-native lineage recovery
# ---------------------------------------------------------------------------


class CompiledFaultManager:
    """Array-native mirror of :class:`repro.core.fault.FaultManager`.

    Same failure model, no per-drop recursion: the lost set and its
    upstream closure are computed with bulk boolean masks and reverse-CSR
    gathers, so a 100k-drop recovery costs milliseconds (benchmarked by
    ``bench_execute.py --tier recovery``).
    """

    def __init__(self, session: CompiledSession,
                 master: MasterDropManager) -> None:
        self.session = session
        self.master = master
        self.stats = ResilienceStats()
        # one drop-id array per recovery pass (reset + remapped)
        self.recovered: List[np.ndarray] = []
        self._nid_dead: Optional[np.ndarray] = None   # set by lost_set()
        self._root_data: Optional[np.ndarray] = None  # bool cache

    # -- failure injection -------------------------------------------------
    def fail_node(self, node: str) -> None:
        nm = self.master.node_managers()[node]
        nm.fail()
        if node not in self.stats.failed_nodes:
            self.stats.failed_nodes.append(node)

    # -- lost set ----------------------------------------------------------
    def lost_set(self) -> np.ndarray:
        """Drop ids that must be re-executed after node death.

        Mirrors ``FaultManager.recover`` steps 1-3, vectorized:

        1. dead placement mask over ``pgt.node_ids``;
        2. initial lost set = non-terminal (INIT) drops on dead nodes
           plus COMPLETED *memory*-payload data drops there (memory died
           with the node; file payloads survive on shared storage; root
           data drops are pipeline inputs — durable by contract);
        3. upstream closure over the reverse CSR: a lost data drop pulls
           in its COMPLETED producers (they must re-run to regenerate the
           payload); a lost COMPLETED app pulls in every COMPLETED input
           whose payload is no longer readable (not durable).

        Unlike the oracle's per-drop recursion — which also walks and
        "resets" the not-yet-run INIT region upstream of lost drops (a
        no-op reset) — the closure expands only through the COMPLETED
        lineage that genuinely needs recomputation, so its cost scales
        with the recompute set, not the unexecuted graph.  Final states
        and payloads are identical (``tests/test_resilience_equiv.py``).
        """
        s, pgt = self.session, self.session.pgt
        dead_names = self.master.dead_nodes()
        if not dead_names:
            return np.empty(0, dtype=np.int64)
        # node-id lookup table beats np.isin (no sort of node_ids);
        # after the initial dead scan everything below operates on
        # subsets, so the closure scales with the lost set, not with n
        nid_dead = np.zeros(len(pgt.node_names), dtype=bool)
        nid_dead[[pgt.node_id_for(n) for n in dead_names]] = True
        self._nid_dead = nid_dead          # reused by recover()
        state = s.drop_state
        n = pgt.num_drops
        kind = pgt.kind_arr
        pk = s.payload_kind
        present = s.payload_present
        if self._root_data is None:
            self._root_data = (kind == KIND_DATA) & (pgt.in_degrees() == 0)
        root_data = self._root_data
        if s.node_slices:
            # the deploy/recovery-maintained per-node slices ARE the
            # dead placement set — no full-graph scan needed
            parts = [s.node_slices[nm] for nm in dead_names
                     if nm in s.node_slices]
            if not parts:
                return np.empty(0, dtype=np.int64)
            didx = parts[0] if len(parts) == 1 else np.concatenate(parts)
        else:
            didx = np.flatnonzero(nid_dead[pgt.node_ids])
        if didx.size == 0:
            return np.empty(0, dtype=np.int64)
        dst = state[didx]
        dvol = (kind[didx] == KIND_DATA) & (pk[didx] == PK_MEMORY)
        sel = didx[~root_data[didx]
                   & ((dst == ST_INIT) | ((dst == ST_COMPLETED) & dvol))]
        if sel.size == 0:
            return np.empty(0, dtype=np.int64)
        lost = np.zeros(n, dtype=bool)
        lost[sel] = True
        chunks = [sel]

        in_indptr, in_cols = pgt.in_csr()
        frontier = sel
        while frontier.size:
            is_d = kind[frontier] == KIND_DATA
            data_f = frontier[is_d]
            # only COMPLETED apps are reset-with-recompute; INIT apps on
            # dead nodes just migrate (their inputs are either durable,
            # already in the lost set, or will be produced on resume)
            app_f = frontier[~is_d]
            app_f = app_f[state[app_f] == ST_COMPLETED]
            parts = []
            if data_f.size:
                # COMPLETED producers of a lost data drop must re-run
                # (INIT producers simply run on resume)
                preds = csr_gather(in_indptr, in_cols, data_f)
                parts.append(preds[state[preds] == ST_COMPLETED])
            if app_f.size:
                # a re-run app needs every input payload readable: file
                # payloads are durable wherever they were written; memory
                # and null payloads need the value present AND the node
                # alive; root data drops are durable by contract.
                # Evaluated per gathered input - O(|ins|), not O(n).
                ins = csr_gather(in_indptr, in_cols, app_f)
                durable = (pk[ins] == PK_FILE) | (
                    ((pk[ins] == PK_NULL) | present[ins])
                    & ~nid_dead[pgt.node_ids[ins]])
                durable |= root_data[ins]
                parts.append(
                    ins[(state[ins] == ST_COMPLETED) & ~durable])
            if not parts:
                break
            cand = np.concatenate(parts) if len(parts) > 1 else parts[0]
            if cand.size == 0:
                break
            new = np.unique(cand)          # subset sort, no O(n) scan
            new = new[~lost[new]]
            if new.size == 0:
                break
            lost[new] = True
            chunks.append(new)
            frontier = new
        return np.concatenate(chunks) if len(chunks) > 1 else chunks[0]

    # -- recovery ----------------------------------------------------------
    def recover(self) -> np.ndarray:
        """Migrate lost drops onto live nodes and make the session
        resumable.  Returns the recovered drop-id array.

        Bulk operations only: one closure pass, one round-robin remap of
        ``node_ids``, one state/payload reset, one slice re-registration.
        ``execute_frontier`` then resumes mid-wave — its readiness
        counters are re-derived from the state array on entry.
        """
        t0 = time.monotonic()
        if not self.master.dead_nodes():
            return np.empty(0, dtype=np.int64)
        live = sorted(self.master.live_node_managers())
        if not live:
            raise RuntimeError("no live nodes left to migrate onto")
        s, pgt = self.session, self.session.pgt
        lost = self.lost_set()
        if s.stream is not None and lost.size:
            # a lost streaming consumer has irrecoverably consumed part
            # of its ring — pull its source data (and their producers)
            # into the lost set so the stream replays from chunk 0
            lost = s.stream.expand_lost(lost)
        if lost.size:
            # migrate only the lost drops placed on dead nodes; lost
            # lineage already on live nodes (producers pulled in by the
            # closure) re-runs in place — no pointless migration
            moved = lost[self._nid_dead[pgt.node_ids[lost]]]
            live_ids = np.fromiter((pgt.node_id_for(n) for n in live),
                                   dtype=np.int32, count=len(live))
            pgt.node_ids[moved] = live_ids[
                np.arange(moved.size, dtype=np.int64) % live_ids.size]
            s.drop_state[lost] = ST_INIT
            lost_data = lost[pgt.kind_arr[lost] == KIND_DATA]
            s.payloads[lost_data] = None
            s.payload_present[lost_data] = False
            # round-robin strides give each target node its slice directly
            moved_by_node = {live[t]: moved[t::live_ids.size]
                             for t in range(live_ids.size)}
            self.master.refresh_compiled_slices(s, pgt, moved_by_node)
            if s.stream is not None:
                mask = np.zeros(len(pgt), dtype=bool)
                mask[lost] = True
                s.stream.invalidate(mask)
            self.recovered.append(lost)
        s.reopen()
        s.recoveries += 1
        s.recovered_drops += int(lost.size)
        self.stats.recoveries += 1
        self.stats.recovered_drops += int(lost.size)
        self.stats.recovery_seconds += time.monotonic() - t0
        if s.metrics is not None:
            s.metrics.counter("resilience.recoveries").inc()
            s.metrics.counter("resilience.recovered_drops").inc(
                int(lost.size))
        return lost


# ---------------------------------------------------------------------------
# Straggler speculation + retry — the dispatch-layer runner
# ---------------------------------------------------------------------------


class _StagedRef(_DataRef):
    """Output ref that buffers writes instead of touching the payload
    table — the commit happens atomically, first-writer-wins."""

    __slots__ = ("buf",)

    def __init__(self, session: CompiledSession, idx: int,
                 buf: List[Tuple[int, object]]) -> None:
        super().__init__(session, idx)
        self.buf = buf

    def write(self, value) -> None:
        self.buf.append((self.idx, value))

    def read(self):
        for j, v in reversed(self.buf):
            if j == self.idx:
                return v
        return super().read()


class ResilientRunner:
    """``ExecHooks.python_runner``: threaded per-node dispatch with
    bounded retry and straggler speculation.

    The wave's Python apps arrive node-sorted; each node's batch is
    submitted to that node's thread pool (all nodes overlap — the object
    engine's wave parallelism, which the plain compiled path serialises).
    The dispatching thread tracks per-app deadlines against the running
    median and duplicates overdue apps onto the least-loaded live node.
    Both the primary and the duplicate run with *staged* output refs;
    whoever finishes first commits its buffer into the dense payload
    table under one lock and flips the state row — the loser's commit is
    a no-op and its writes are dropped.
    """

    def __init__(self, master: MasterDropManager, config: ResilienceConfig,
                 stats: ResilienceStats) -> None:
        self.master = master
        self.retry = config.retry
        self.strag = config.stragglers
        self.stats = stats
        self._lock = threading.Lock()
        # bounded window: the straggler threshold tracks recent behaviour
        # and the per-poll median stays O(window), not O(run history)
        self._durations: deque = deque(maxlen=256)
        self._rr = 0                      # round-robin tie-break cursor
        self._inflight: Dict[str, int] = {}
        # bumped by fault recovery (invalidate()): work started before a
        # recovery must never commit into the reset state rows
        self._epoch = 0

    def invalidate(self) -> None:
        """Discard all in-flight work at commit time (called after a
        node-failure recovery reset state rows to INIT — a leftover
        primary/duplicate thread committing a stale pre-failure buffer
        would otherwise flip a reset drop COMPLETED behind the resumed
        scheduler's back and stall its successors)."""
        with self._lock:
            self._epoch += 1

    # -- entry (the wave's Python apps, node-sorted) -----------------------
    def __call__(self, ctx, ids: np.ndarray) -> None:
        if self.strag is None:
            for i in ids.tolist():
                if time.monotonic() > ctx.deadline:
                    raise _WaveTimeout
                epoch = self._epoch
                t0 = time.monotonic()
                self._commit(ctx, int(i), *self._attempts(ctx, int(i)),
                             epoch=epoch, t0=t0)
            return
        self._threaded_wave(ctx, ids)

    def _threaded_wave(self, ctx, ids: np.ndarray) -> None:
        pgt = ctx.pgt
        nms = self.master.node_managers()
        # filled by the worker when the app actually STARTS running —
        # queue wait must not count toward the straggler deadline (the
        # object-path watcher clocks from the RUNNING event, and
        # mass-speculating a deep queued batch doubles the wave's work)
        started: Dict[int, float] = {}
        speculated: Set[int] = set()
        home: Dict[int, str] = {}

        # one epoch for the whole wave, captured before any submit: a
        # recovery can only happen at a wave boundary, so any work from
        # this wave that outlives one is stale by construction
        epoch = self._epoch

        def primary(i: int, node: str) -> None:
            t0 = time.monotonic()
            started[i] = t0
            try:
                self._commit(ctx, i, *self._attempts(ctx, i), epoch=epoch,
                             t0=t0)
            except (WorkerLost, _WaveTimeout):
                # drop stays INIT; the poll loop below surfaces the dead
                # node / deadline for the whole wave
                pass
            finally:
                with self._lock:
                    self._inflight[node] = self._inflight.get(node, 1) - 1
                    self._durations.append(time.monotonic() - t0)

        # submit every node's batch — all nodes overlap
        for batch in node_batches(pgt, ids):
            node = pgt.node_names[int(pgt.node_ids[int(batch[0])])]
            nm = nms.get(node)
            if nm is None or not nm.info.alive:
                # placement no longer live (mid-recovery edge): run inline
                for i in batch.tolist():
                    if time.monotonic() > ctx.deadline:
                        raise _WaveTimeout
                    t0 = time.monotonic()
                    self._commit(ctx, int(i),
                                 *self._attempts(ctx, int(i)), epoch=epoch,
                                 t0=t0)
                continue
            with self._lock:
                self._inflight[node] = \
                    self._inflight.get(node, 0) + int(batch.size)
            for i in batch.tolist():
                home[int(i)] = node
                nm.executor.submit(primary, int(i), node)

        state = ctx.s.drop_state
        while True:
            pending = ids[state[ids] == ST_INIT]
            if pending.size == 0:
                return
            # a worker process that died mid-wave leaves its drops INIT
            # forever; surface the dead home nodes so the resilient loop
            # recovers instead of spinning to the deadline
            dead = sorted({home[int(i)] for i in pending.tolist()
                           if home.get(int(i)) is not None
                           and not nms[home[int(i)]].info.alive})
            if dead:
                raise WorkerLost(dead)
            if time.monotonic() > ctx.deadline:
                raise _WaveTimeout   # committed work stays; resumable
            threshold = self._threshold()
            if threshold is not None:
                now = time.monotonic()
                for i in pending.tolist():
                    t0 = started.get(i)   # None = still queued, not slow
                    if t0 is not None and i not in speculated \
                            and now - t0 > threshold:
                        speculated.add(i)
                        self._speculate(ctx, i, home[i], epoch=epoch)
            time.sleep(self.strag.poll)

    # -- straggler speculation ---------------------------------------------
    def _threshold(self) -> Optional[float]:
        with self._lock:
            durs = list(self._durations)   # bounded snapshot (maxlen)
        if len(durs) < 3:
            return None
        return max(self.strag.factor * statistics.median(durs),
                   self.strag.min_runtime)

    def _speculate(self, ctx, i: int, home: str,
                   epoch: Optional[int] = None) -> None:
        """Duplicate app ``i`` onto the least-loaded live node (round-robin
        among ties), first-writer-wins."""
        live = self.master.live_node_managers()
        cands = [nm for n, nm in sorted(live.items()) if n != home]
        if not cands:
            return
        with self._lock:
            low = min(self._inflight.get(nm.name, 0) for nm in cands)
            tied = [nm for nm in cands
                    if self._inflight.get(nm.name, 0) == low]
            target = tied[self._rr % len(tied)]
            self._rr += 1
            self._inflight[target.name] = \
                self._inflight.get(target.name, 0) + 1

        wave_epoch = self._epoch if epoch is None else epoch

        def dup() -> None:
            t0 = time.monotonic()
            try:
                # run on the TARGET node (on a process-backed cluster the
                # duplicate executes in the target's worker process)
                buf, err = self._attempts(ctx, i, node=target.name)
                if err is None:
                    # a winning duplicate records the node that actually
                    # executed the drop, not its original placement
                    self._commit(ctx, i, buf, None, speculative=True,
                                 epoch=wave_epoch, t0=t0,
                                 node=ctx.pgt.node_id_for(target.name))
                else:
                    with self._lock:
                        self.stats.speculative_losses += 1
            except (WorkerLost, _WaveTimeout):
                # the target died or ran out of budget: the duplicate just
                # loses; the primary (or a recovery) still owns the drop
                with self._lock:
                    self.stats.speculative_losses += 1
            finally:
                with self._lock:
                    self._inflight[target.name] = \
                        self._inflight.get(target.name, 1) - 1

        target.executor.submit(dup)

    # -- staged execution with bounded retry -------------------------------
    def _attempts(self, ctx, i: int, node: Optional[str] = None):
        """Run app ``i`` with staged outputs; returns (buffer, error).

        ``node`` overrides the placement node (speculative duplicates run
        on their target).  On a process-backed node the attempt ships to
        that node's worker; :class:`WorkerLost` propagates — a dead worker
        is a node failure, never an app error."""
        ex = self._proc_executor(ctx, i, node)
        if ex is not None:
            return self._attempts_proc(ctx, i, ex)
        attempts = self.retry.max_attempts if self.retry else 1
        backoff = self.retry.backoff if self.retry else 0.0
        err: Optional[str] = None
        for k in range(attempts):
            buf: List[Tuple[int, object]] = []
            try:
                func, ins, outs, app = ctx.app_call(
                    i, out_ref=lambda s, j: _StagedRef(s, j, buf))
                if func is not None:
                    if getattr(func, "streaming", False):
                        # degraded/batch resolution of a streaming app:
                        # run its finish stage if present, skip otherwise
                        fin = getattr(func, "finish", None)
                        if fin is not None:
                            fin(ins, outs, app)
                    else:
                        func(ins, outs, app)
                return buf, None
            except Exception:  # noqa: BLE001 - becomes a drop ERROR
                err = traceback.format_exc(limit=8)
                if k + 1 < attempts:
                    with self._lock:
                        self.stats.retries += 1
                        ctx.s.retries += 1
                    if ctx.s.metrics is not None:
                        ctx.s.metrics.counter("resilience.retries").inc()
                    if backoff:          # no sleep after the final attempt
                        time.sleep(backoff * (2 ** k))
        return None, err

    def _proc_executor(self, ctx, i: int, node: Optional[str]):
        """The live process-backed executor app ``i`` should run on, or
        None (thread-backed node, dead node, unplaced drop — all fall back
        to the in-process staged path)."""
        if node is None:
            nid = int(ctx.pgt.node_ids[i])
            if nid < 0:
                return None
            node = ctx.pgt.node_names[nid]
        nm = self.master.node_managers().get(node)
        if nm is None or not nm.info.alive:
            return None
        ex = nm.executor
        return ex if hasattr(ex, "run_batch") else None

    def _attempts_proc(self, ctx, i: int, ex):
        """Process-backed attempt loop: same retry policy, with the app
        executed in the node's worker and its writes returned as the
        staged buffer for the normal first-writer-wins commit."""
        attempts = self.retry.max_attempts if self.retry else 1
        backoff = self.retry.backoff if self.retry else 0.0
        err: Optional[str] = None
        for k in range(attempts):
            spec = ctx.proc_spec(i)
            tb = spec.get("parent_tb")
            if tb is not None:
                return None, tb
            budget = ctx.deadline - time.monotonic()
            if budget <= 0:
                raise _WaveTimeout
            res = ex.run_batch([spec], budget)[0]   # WorkerLost propagates
            if res["status"] == "ok":
                return list(res["writes"]), None
            if res["status"] == "timeout":
                raise _WaveTimeout
            err = res["tb"]
            if k + 1 < attempts:
                with self._lock:
                    self.stats.retries += 1
                    ctx.s.retries += 1
                if ctx.s.metrics is not None:
                    ctx.s.metrics.counter("resilience.retries").inc()
                if backoff:
                    time.sleep(backoff * (2 ** k))
        return None, err

    def _commit(self, ctx, i: int, buf, err: Optional[str],
                speculative: bool = False, epoch: int = 0,
                t0: Optional[float] = None,
                node: Optional[int] = None) -> bool:
        """First-writer-wins commit into the payload table + state row.

        ``epoch`` is the runner epoch captured when the attempt started;
        a recovery in between (``invalidate()``) makes the buffer stale
        — the drop was reset to INIT for *re-execution*, and committing
        would hide it from the resumed scheduler's frontier.

        ``t0``/``node`` feed the session timeline: the *winning* attempt
        stamps its own start time and executing node (a speculative win
        records the duplicate's node, not the original placement)."""
        s = ctx.s
        with self._lock:
            if epoch != self._epoch or s.drop_state[i] != ST_INIT:
                if speculative:
                    self.stats.speculative_losses += 1
                return False
            if err is None:
                try:
                    for j, v in buf:
                        s._write_idx(j, v)
                except Exception:  # noqa: BLE001 - spill failures (file
                    # payload mkdir/pickle) become drop ERRORs, exactly
                    # as the plain dispatch path records them
                    s.drop_state[i] = ST_ERROR
                    s.record_error(i, traceback.format_exc(limit=8))
                    self._stamp(ctx, i, t0, node)
                    return True
                s.drop_state[i] = ST_COMPLETED
                if speculative:
                    self.stats.speculative_wins += 1
                    s.speculative_wins += 1
                    if s.metrics is not None:
                        s.metrics.counter(
                            "resilience.speculative_wins").inc()
            else:
                s.drop_state[i] = ST_ERROR
                s.record_error(i, err)
            self._stamp(ctx, i, t0, node)
        return True

    @staticmethod
    def _stamp(ctx, i: int, t0: Optional[float],
               node: Optional[int]) -> None:
        if ctx.tl is not None:
            t1 = time.monotonic()
            ctx.tl.stamp(int(i), t1 if t0 is None else t0, t1,
                         ctx.wave, node=node)


# ---------------------------------------------------------------------------
# The resilient execution loop
# ---------------------------------------------------------------------------


class NodeFailureInterrupt(Exception):
    """Control-flow signal: a failure script fired at a wave boundary."""

    def __init__(self, nodes: List[str]) -> None:
        super().__init__(f"node failure injected: {nodes}")
        self.nodes = nodes


def execute_resilient(session: CompiledSession, master: MasterDropManager,
                      config: ResilienceConfig, timeout: float = 60.0,
                      fault_manager: Optional[CompiledFaultManager] = None,
                      hooks: Optional[ExecHooks] = None,
                      stream=None) -> Tuple[bool, ResilienceStats]:
    """Run a deployed compiled session under a resilience policy.

    Drives ``execute_frontier`` with hooks: scripted node failures fire at
    wave boundaries (where every drop is terminal or INIT — no in-flight
    state), recovery resets/remaps the lost lineage, and the loop resumes
    the scheduler until the graph finishes or the deadline expires.

    ``hooks`` merges user observability into the internal failure-script
    hooks: a user ``on_wave`` runs before the failure check, and
    ``on_stream_chunk``/``on_backpressure`` pass straight through.
    ``stream`` forwards to :func:`execute_frontier` unchanged.
    """
    fm = fault_manager or CompiledFaultManager(session, master)
    stats = fm.stats
    runner = ResilientRunner(master, config, stats) \
        if config.needs_runner else None
    pending = sorted(config.failures, key=lambda f: f.at_fraction)
    fired: Set[int] = set()
    user_wave = hooks.on_wave if hooks is not None else None

    def on_wave(sess: CompiledSession, completed: int, total: int) -> None:
        if user_wave is not None:
            user_wave(sess, completed, total)
        frac = completed / max(total, 1)
        trig = [f for f in pending
                if id(f) not in fired and frac >= f.at_fraction]
        if trig:
            fired.update(id(f) for f in trig)
            raise NodeFailureInterrupt([f.node for f in trig])

    hooks = ExecHooks(
        on_wave=on_wave if (pending or user_wave is not None) else None,
        python_runner=runner,
        on_stream_chunk=hooks.on_stream_chunk if hooks is not None else None,
        on_backpressure=hooks.on_backpressure if hooks is not None else None)
    deadline = time.monotonic() + timeout
    while True:
        budget = deadline - time.monotonic()
        if budget <= 0:
            return False, stats
        try:
            # failure-only configs (no runner hook) still get the default
            # threaded per-node wave overlap; recomputed per resume so
            # freshly-dead nodes drop out of the executor map
            finished = execute_frontier(
                session, timeout=budget, hooks=hooks,
                executors=None if runner is not None
                else master.node_executors(), stream=stream)
            return finished, stats
        except (NodeFailureInterrupt, WorkerLost) as nf:
            # scripted failure (wave boundary) or a real worker-process
            # death (mid-wave SIGKILL / crash / wedge): same recovery path
            for node in nf.nodes:
                nm = master.node_managers().get(node)
                if nm is not None and nm.info.alive:
                    fm.fail_node(node)
                elif node not in stats.failed_nodes:
                    # worker death already flipped info.alive via on_lost;
                    # keep the failure ledger consistent with fail_node
                    stats.failed_nodes.append(node)
            if runner is not None:
                # invalidate BEFORE the state reset: a leftover thread
                # committing between recover() and a later invalidate()
                # would pass the epoch check against just-reset rows
                runner.invalidate()
            fm.recover()
