from .pipeline import ShardedTokenPipeline, synthetic_batch

__all__ = ["ShardedTokenPipeline", "synthetic_batch"]
