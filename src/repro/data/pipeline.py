"""Deterministic sharded synthetic token pipeline with prefetch.

The data path is itself expressed as Drops in the training logical graph
(Scatter over shards -> per-shard reader components); this module is the
payload those Application Drops run.  Determinism: batch ``i`` of shard
``s`` is a pure function of (seed, s, i) — re-execution after failure or
speculative duplication yields identical bytes, which is what makes the
engine's lineage recovery and first-wins straggler commits sound.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_batch(seed: int, shard: int, index: int, batch: int,
                    seq_len: int, vocab: int) -> Dict[str, np.ndarray]:
    """Pure function -> {tokens, labels} (labels = next-token shifted)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, shard, index]))
    # run-length stream: each token repeats the previous with p=0.7, else a
    # fresh draw -> entropy ~= 0.3*ln(V) + H(0.7), far below uniform ln(V),
    # so the "copy previous token" rule is learnable in a few hundred steps
    n = seq_len + 1
    base = rng.integers(0, vocab, size=(batch, n), dtype=np.int64)
    fresh = rng.random((batch, n)) >= 0.7
    fresh[:, 0] = True
    src_idx = np.where(fresh, np.arange(n)[None, :], 0)
    src_idx = np.maximum.accumulate(src_idx, axis=1)
    toks = np.take_along_axis(base, src_idx, axis=1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class PipelineConfig:
    seed: int
    num_shards: int
    shard: int
    batch: int
    seq_len: int
    vocab: int
    prefetch: int = 2


class ShardedTokenPipeline:
    """Background-prefetching iterator over one shard's batches."""

    def __init__(self, cfg: PipelineConfig) -> None:
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._index = 0
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        i = 0
        while not self._stop.is_set():
            b = synthetic_batch(self.cfg.seed, self.cfg.shard, i,
                                self.cfg.batch, self.cfg.seq_len,
                                self.cfg.vocab)
            while not self._stop.is_set():
                try:
                    self._q.put((i, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        i, b = self._q.get()
        self._index = i
        return b

    def close(self) -> None:
        self._stop.set()
