"""Model substrate: the 10 assigned architectures in functional JAX."""
from .common import SHAPES, ArchConfig, ShapeConfig
from .model import (decode_step, forward_train, init_cache, init_params,
                    prefill)

__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "decode_step",
           "forward_train", "init_cache", "init_params", "prefill"]
