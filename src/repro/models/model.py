"""Composite model assembly for all 10 assigned architectures.

One functional API:
  * ``init_params(cfg, key)``      — parameter pytree (layers stacked for scan)
  * ``forward_train(params, cfg, batch)`` — mean token loss (+ aux)
  * ``init_cache(cfg, batch, max_seq)``   — KV / SSM / hybrid cache pytree
  * ``prefill(params, cfg, batch)``       — logits + primed cache
  * ``decode_step(params, cfg, cache, tokens, pos)`` — one-token serve step

Layers are scanned (``jax.lax.scan`` over stacked params) so the lowered HLO
is depth-independent — a 64-layer 314B model compiles as fast as a 2-layer
toy, which is what makes the 80-cell dry-run tractable and is standard
practice for production JAX LLM stacks.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (attention, decode_attention, decode_cross_attention,
                        init_attention, init_kv_cache)
from .common import (ArchConfig, KeyGen, activation_fn, cross_entropy,
                     dense_init, rms_norm, sinusoidal_positions, softcap)
from .moe import init_moe, moe_block
from .ssm import (init_mamba2, init_ssm_cache, mamba2_decode_step,
                  mamba2_forward)
from ..sharding import ctx as sctx


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Layer-scan control.  Production lowers a `lax.scan` (depth-independent
# HLO); the dry-run *cost pass* unrolls it because XLA's HloCostAnalysis
# counts a while-body exactly once, which would undercount FLOPs/bytes/
# collective bytes by a factor of num_layers.
# ---------------------------------------------------------------------------

_UNROLL_LAYERS = False


@contextlib.contextmanager
def unrolled_layers(enable: bool = True):
    global _UNROLL_LAYERS
    prev = _UNROLL_LAYERS
    _UNROLL_LAYERS = enable
    try:
        yield
    finally:
        _UNROLL_LAYERS = prev


def _scan(body, carry, xs):
    if not _UNROLL_LAYERS:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# Block params
# ---------------------------------------------------------------------------


def _init_mlp(kg: KeyGen, cfg: ArchConfig, dt) -> Dict[str, jax.Array]:
    d, f = cfg.d_model, cfg.d_ff
    p = {"w1": dense_init(kg(), (d, f), dt, fan_in=d),
         "w2": dense_init(kg(), (f, d), dt, fan_in=f)}
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = dense_init(kg(), (d, f), dt, fan_in=d)
    return p


def _mlp(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    if cfg.activation in ("swiglu", "geglu"):
        gate = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = gate(h) * jnp.einsum("bsd,df->bsf", x, p["w3"])
    else:
        h = activation_fn(cfg.activation)(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def _init_dense_block(kg: KeyGen, cfg: ArchConfig, dt,
                      cross: bool = False) -> Dict[str, Any]:
    p = {"attn_norm": jnp.zeros((cfg.d_model,), dt),
         "attn": init_attention(kg, cfg, dt),
         "mlp_norm": jnp.zeros((cfg.d_model,), dt)}
    if cfg.family == "moe":
        p["moe"] = init_moe(kg, cfg, dt)
    else:
        p["mlp"] = _init_mlp(kg, cfg, dt)
    if cross:
        p["cross_norm"] = jnp.zeros((cfg.d_model,), dt)
        p["cross"] = init_attention(kg, cfg, dt, cross=True)
    return p


def _stack(layers):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# init_params
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    kg = KeyGen(key)
    dt = _dtype(cfg)
    d, vp = cfg.d_model, cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": dense_init(kg(), (vp, d), dt, fan_in=d),
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg(), (d, vp), dt, fan_in=d)

    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stack(
            [_init_dense_block(kg, cfg, dt) for _ in range(cfg.num_layers)])
    elif cfg.family == "ssm":
        params["layers"] = _stack(
            [{"norm": jnp.zeros((d,), dt), "mamba": init_mamba2(kg, cfg, dt)}
             for _ in range(cfg.num_layers)])
    elif cfg.family == "hybrid":
        assert cfg.shared_attn_period > 0
        assert cfg.num_layers % cfg.shared_attn_period == 0
        params["layers"] = _stack(
            [{"norm": jnp.zeros((d,), dt), "mamba": init_mamba2(kg, cfg, dt)}
             for _ in range(cfg.num_layers)])
        params["shared"] = _init_dense_block(kg, cfg, dt)
    elif cfg.family == "encdec":
        params["enc_layers"] = _stack(
            [_init_dense_block(kg, cfg, dt)
             for _ in range(cfg.num_encoder_layers)])
        params["enc_norm"] = jnp.zeros((d,), dt)
        params["layers"] = _stack(
            [_init_dense_block(kg, cfg, dt, cross=True)
             for _ in range(cfg.num_layers)])
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return params


# ---------------------------------------------------------------------------
# Layer-window schedule (gemma2 alternating local/global)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig, seq_or_cache_len: int) -> Optional[np.ndarray]:
    if cfg.alternate_local_global:
        w = [cfg.local_window if i % 2 == 0 else 0
             for i in range(cfg.num_layers)]
        return np.asarray(w, dtype=np.int32)
    if cfg.local_window:
        return np.full((cfg.num_layers,), cfg.local_window, dtype=np.int32)
    return None


# ---------------------------------------------------------------------------
# Forward (train / prefill share the full-sequence path)
# ---------------------------------------------------------------------------


def _dense_body(cfg: ArchConfig, positions, use_kernel, remat: bool):
    def body(carry, layer):
        h, aux = carry
        p, window = layer
        a = attention(p["attn"], rms_norm(h, p["attn_norm"]), cfg,
                      positions=positions, window=window,
                      causal=True, use_kernel=use_kernel)
        h = h + a
        xin = rms_norm(h, p["mlp_norm"])
        if cfg.family == "moe":
            m, aux_l = moe_block(p["moe"], xin, cfg)
            aux = aux + aux_l
        else:
            m = _mlp(p["mlp"], xin, cfg)
        h = sctx.constrain(h + m, "residual")
        return (h, aux), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return body


def _ssm_body(cfg: ArchConfig, use_kernel, remat: bool):
    def body(carry, p):
        h, aux = carry
        h = h + mamba2_forward(p["mamba"], rms_norm(h, p["norm"]), cfg,
                               use_kernel=use_kernel)
        h = sctx.constrain(h, "residual")
        return (h, aux), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return body


def _shared_block(cfg: ArchConfig, p, h, positions, use_kernel):
    a = attention(p["attn"], rms_norm(h, p["attn_norm"]), cfg,
                  positions=positions, window=None, causal=True,
                  use_kernel=use_kernel)
    h = h + a
    h = h + _mlp(p["mlp"], rms_norm(h, p["mlp_norm"]), cfg)
    return h


def backbone(params: Dict[str, Any], cfg: ArchConfig, x: jax.Array,
             positions: jax.Array, *, use_kernel: bool = False,
             remat: bool = False,
             enc_out: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Run the stacked layers.  Returns (hidden, aux_loss)."""
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe", "vlm"):
        windows = layer_windows(cfg, x.shape[1])
        if windows is None:
            windows = np.zeros((cfg.num_layers,), np.int32)
        body = _dense_body(cfg, positions, use_kernel, remat)
        (h, aux), _ = _scan(body, (x, aux0),
                                   (params["layers"], jnp.asarray(windows)))
        return h, aux
    if cfg.family == "ssm":
        body = _ssm_body(cfg, use_kernel, remat)
        (h, aux), _ = _scan(body, (x, aux0), params["layers"])
        return h, aux
    if cfg.family == "hybrid":
        per = cfg.shared_attn_period
        groups = cfg.num_layers // per
        grouped = jax.tree.map(
            lambda a: a.reshape(groups, per, *a.shape[1:]), params["layers"])
        inner = _ssm_body(cfg, use_kernel, remat)

        def outer(carry, gp):
            (h, aux), _ = _scan(inner, carry, gp)
            h = _shared_block(cfg, params["shared"], h, positions, use_kernel)
            return (h, aux), None
        if remat:
            outer = jax.checkpoint(outer, prevent_cse=False)
        (h, aux), _ = _scan(outer, (x, aux0), grouped)
        return h, aux
    if cfg.family == "encdec":
        assert enc_out is not None, "enc-dec backbone needs encoder output"
        windows = np.zeros((cfg.num_layers,), np.int32)

        def body(carry, layer):
            h, aux = carry
            p, window = layer
            a = attention(p["attn"], rms_norm(h, p["attn_norm"]), cfg,
                          positions=positions, window=window, causal=True,
                          use_rope=False, use_kernel=use_kernel)
            h = h + a
            c = attention(p["cross"], rms_norm(h, p["cross_norm"]), cfg,
                          positions=positions, causal=False, kv_src=enc_out,
                          use_rope=False, use_kernel=False)
            h = h + c
            h = sctx.constrain(
                h + _mlp(p["mlp"], rms_norm(h, p["mlp_norm"]), cfg),
                "residual")
            return (h, aux), None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (h, aux), _ = _scan(body, (x, aux0),
                                   (params["layers"], jnp.asarray(windows)))
        return h, aux
    raise ValueError(cfg.family)


def encode(params: Dict[str, Any], cfg: ArchConfig,
           frames: jax.Array, *, use_kernel: bool = False,
           remat: bool = False) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, enc_len, d)."""
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1]), frames.shape[:2])

    def body(carry, p):
        h, aux = carry
        a = attention(p["attn"], rms_norm(h, p["attn_norm"]), cfg,
                      positions=positions, causal=False, use_rope=False,
                      use_kernel=use_kernel)
        h = h + a
        h = h + _mlp(p["mlp"], rms_norm(h, p["mlp_norm"]), cfg)
        return (h, aux), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, _), _ = _scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["enc_layers"])
    return rms_norm(h, params["enc_norm"])


def embed_tokens(params: Dict[str, Any], cfg: ArchConfig,
                 tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.family == "encdec":
        x = x + sinusoidal_positions(
            tokens.shape[-1], cfg.d_model).astype(x.dtype)
    return x


def logits_fn(params: Dict[str, Any], cfg: ArchConfig,
              h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def forward_train(params: Dict[str, Any], cfg: ArchConfig,
                  batch: Dict[str, jax.Array], *, use_kernel: bool = False,
                  remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"],
                         use_kernel=use_kernel, remat=remat)
    h, aux = backbone(params, cfg, x, positions, use_kernel=use_kernel,
                      remat=remat, enc_out=enc_out)
    logits = logits_fn(params, cfg, h)
    loss = cross_entropy(logits, labels, cfg.vocab_size)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    dt = _dtype(cfg)
    L = cfg.num_layers

    def stack_kv(n):
        one = init_kv_cache(cfg, batch, max_seq, dt)
        return jax.tree.map(
            lambda a: jnp.zeros((n, *a.shape), a.dtype), one)

    if cfg.family in ("dense", "moe", "vlm"):
        return {"kv": stack_kv(L)}
    if cfg.family == "ssm":
        one = init_ssm_cache(cfg, batch, dt)
        return {"ssm": jax.tree.map(
            lambda a: jnp.zeros((L, *a.shape), a.dtype), one)}
    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.shared_attn_period
        one = init_ssm_cache(cfg, batch, dt)
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.zeros((L, *a.shape), a.dtype), one),
            "kv": stack_kv(groups),   # one KV cache per shared-block call
        }
    if cfg.family == "encdec":
        enc_len = max(max_seq // cfg.encoder_ratio, 1)
        hd = cfg.resolved_head_dim
        return {
            "kv": stack_kv(L),
            "cross_k": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, hd),
                                 dt),
            "cross_v": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, hd),
                                 dt),
        }
    raise ValueError(cfg.family)


def decode_step(params: Dict[str, Any], cfg: ArchConfig,
                cache: Dict[str, Any], tokens: jax.Array,
                pos: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serve step: tokens (B,1) at position ``pos`` -> (logits, cache)."""
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "encdec":
        # learned-pos analogue at decode: add the sinusoid for `pos`
        x = params["embed"][tokens] + jax.lax.dynamic_slice_in_dim(
            sinusoidal_positions(cache["kv"]["k"].shape[2], cfg.d_model),
            pos, 1, axis=0).astype(x.dtype)[None]

    if cfg.family in ("dense", "moe", "vlm"):
        windows = layer_windows(cfg, 0)
        if windows is None:
            windows = np.zeros((cfg.num_layers,), np.int32)

        def body(h, layer):
            p, kv, window = layer
            a, kv2 = decode_attention(
                p["attn"], rms_norm(h, p["attn_norm"]), kv, pos, cfg,
                window=window)
            h = h + a
            xin = rms_norm(h, p["mlp_norm"])
            if cfg.family == "moe":
                m, _ = moe_block(p["moe"], xin, cfg, num_groups=1)
            else:
                m = _mlp(p["mlp"], xin, cfg)
            return h + m, kv2
        h, kv = _scan(
            body, x, (params["layers"], cache["kv"], jnp.asarray(windows)))
        new_cache: Dict[str, Any] = {"kv": kv}
    elif cfg.family == "ssm":
        def body(h, layer):
            p, c = layer
            y, c2 = mamba2_decode_step(
                p["mamba"], rms_norm(h, p["norm"]), c, cfg)
            return h + y, c2
        h, ssm = _scan(body, x, (params["layers"], cache["ssm"]))
        new_cache = {"ssm": ssm}
    elif cfg.family == "hybrid":
        per = cfg.shared_attn_period
        groups = cfg.num_layers // per
        grouped_p = jax.tree.map(
            lambda a: a.reshape(groups, per, *a.shape[1:]), params["layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape(groups, per, *a.shape[1:]), cache["ssm"])

        def inner(h, layer):
            p, c = layer
            y, c2 = mamba2_decode_step(
                p["mamba"], rms_norm(h, p["norm"]), c, cfg)
            return h + y, c2

        def outer(h, layer):
            gp, gc, kv = layer
            h, gc2 = _scan(inner, h, (gp, gc))
            sp = params["shared"]
            a, kv2 = decode_attention(
                sp["attn"], rms_norm(h, sp["attn_norm"]), kv, pos, cfg)
            h = h + a
            h = h + _mlp(sp["mlp"], rms_norm(h, sp["mlp_norm"]), cfg)
            return h, (gc2, kv2)
        h, (gc, kv) = _scan(
            outer, x, (grouped_p, grouped_c, cache["kv"]))
        new_cache = {
            "ssm": jax.tree.map(
                lambda a: a.reshape(cfg.num_layers, *a.shape[2:]), gc),
            "kv": kv,
        }
    elif cfg.family == "encdec":
        def body(h, layer):
            p, kv, ck, cv = layer
            a, kv2 = decode_attention(
                p["attn"], rms_norm(h, p["attn_norm"]), kv, pos, cfg,
                use_rope=False)
            h = h + a
            c = decode_cross_attention(
                p["cross"], rms_norm(h, p["cross_norm"]), ck, cv, cfg)
            h = h + c
            h = h + _mlp(p["mlp"], rms_norm(h, p["mlp_norm"]), cfg)
            return h, kv2
        h, kv = _scan(
            body, x, (params["layers"], cache["kv"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = {"kv": kv, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
    else:
        raise ValueError(cfg.family)

    logits = logits_fn(params, cfg, h)
    return logits, new_cache


def prefill(params: Dict[str, Any], cfg: ArchConfig,
            batch: Dict[str, jax.Array], *, use_kernel: bool = False
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process the full prompt; return last-position logits + primed cache.

    The cache is primed by running the full-sequence backbone and projecting
    K/V per layer (for attention families) / final SSM states (for SSM).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"], use_kernel=use_kernel)
    cache = init_cache(cfg, B, S)

    # The priming pass IS the forward pass: one sweep over the layers that
    # both produces the final hidden state and captures per-layer K/V (or
    # final SSM states) into the cache — no duplicated backbone work.
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        h, cache = _prime_kv(params, cfg, x, positions, cache, enc_out,
                             use_kernel)
    else:
        h, cache = _prime_ssm(params, cfg, x, positions, cache, use_kernel)
    logits = logits_fn(params, cfg, h[:, -1:, :])
    return logits, cache


def _prime_kv(params, cfg, x, positions, cache, enc_out, use_kernel):
    """Run layers sequentially, storing per-layer K/V into the cache."""
    from .attention import _project_qkv  # noqa: PLC2701 (intra-package)
    windows = layer_windows(cfg, x.shape[1])
    if windows is None:
        windows = np.zeros((cfg.num_layers,), np.int32)

    def body(carry, layer):
        h = carry
        if cfg.family == "encdec":
            p, window, ck, cv = layer
        else:
            p, window = layer
        xin = rms_norm(h, p["attn_norm"])
        _, k, v = _project_qkv(p["attn"], xin, xin, cfg, positions,
                               positions,
                               use_rope=cfg.family != "encdec")
        a = attention(p["attn"], xin, cfg, positions=positions,
                      window=window, causal=True,
                      use_rope=cfg.family != "encdec",
                      use_kernel=use_kernel)
        h = h + a
        outs = {"k": k, "v": v}
        if cfg.family == "encdec":
            c = attention(p["cross"], rms_norm(h, p["cross_norm"]), cfg,
                          positions=positions, causal=False, kv_src=enc_out,
                          use_rope=False)
            h = h + c
            ck2 = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wk"])
            cv2 = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wv"])
            if cfg.use_bias:
                ck2 = ck2 + p["cross"]["bk"]
                cv2 = cv2 + p["cross"]["bv"]
            outs["ck"] = ck2
            outs["cv"] = cv2
        xin2 = rms_norm(h, p["mlp_norm"])
        if cfg.family == "moe":
            m, _ = moe_block(p["moe"], xin2, cfg)
        else:
            m = _mlp(p["mlp"], xin2, cfg)
        h = h + m
        return h, outs

    if cfg.family == "encdec":
        xs = (params["layers"], jnp.asarray(windows),
              cache["cross_k"], cache["cross_v"])
    else:
        xs = (params["layers"], jnp.asarray(windows))
    h, outs = _scan(body, x, xs)
    kv = {"k": outs["k"].astype(cache["kv"]["k"].dtype),
          "v": outs["v"].astype(cache["kv"]["v"].dtype)}
    new = dict(cache)
    new["kv"] = kv
    if cfg.family == "encdec":
        new["cross_k"] = outs["ck"].astype(cache["cross_k"].dtype)
        new["cross_v"] = outs["cv"].astype(cache["cross_v"].dtype)
    return h, new


def _prime_ssm(params, cfg, x, positions, cache, use_kernel):
    """Sequence pass capturing final SSM states (+ shared-block K/V)."""
    from .ssm import _causal_conv, _split_proj, ssd_chunked

    def mamba_with_state(p, h, c):
        B, S, d = h.shape
        di, n, g = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups
        hh, P = cfg.ssm_heads, cfg.ssm_headdim
        zxbcdt = jnp.einsum("bsd,dk->bsk", h, p["in_proj"])
        z, xin, b_, c_, dt = _split_proj(cfg, zxbcdt)
        conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv = conv_in[:, -(cfg.ssm_conv - 1):, :]
        xin, b_, c_ = jnp.split(conv_out, [di, di + g * n], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        a = -jnp.exp(p["A_log"])
        y, state = ssd_chunked(
            xin.reshape(B, S, hh, P), dt, a, b_.reshape(B, S, g, n),
            c_.reshape(B, S, g, n), min(cfg.ssm_chunk, S),
            use_kernel=use_kernel)
        y = (y + xin.reshape(B, S, hh, P)
             * p["D"][None, None, :, None]).astype(h.dtype)
        y = rms_norm(y.reshape(B, S, di) * jax.nn.silu(z), p["norm"])
        out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
        return out, {"conv": new_conv.astype(c["conv"].dtype),
                     "state": state.astype(c["state"].dtype)}

    if cfg.family == "ssm":
        def body(h, layer):
            p, c = layer
            y, c2 = mamba_with_state(p["mamba"], rms_norm(h, p["norm"]), c)
            return h + y, c2
        h, ssm = _scan(body, x, (params["layers"], cache["ssm"]))
        return h, {"ssm": ssm}

    # hybrid
    from .attention import _project_qkv
    per = cfg.shared_attn_period
    groups = cfg.num_layers // per
    grouped_p = jax.tree.map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), params["layers"])
    grouped_c = jax.tree.map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), cache["ssm"])

    def inner(h, layer):
        p, c = layer
        y, c2 = mamba_with_state(p["mamba"], rms_norm(h, p["norm"]), c)
        return h + y, c2

    def outer(h, layer):
        gp, gc = layer
        h, gc2 = _scan(inner, h, (gp, gc))
        sp = params["shared"]
        xin = rms_norm(h, sp["attn_norm"])
        q, k, v = _project_qkv(sp["attn"], xin, xin, cfg, positions,
                               positions, use_rope=True)
        a = attention(sp["attn"], xin, cfg, positions=positions,
                      causal=True)
        h = h + a
        h = h + _mlp(sp["mlp"], rms_norm(h, sp["mlp_norm"]), cfg)
        return h, (gc2, {"k": k, "v": v})
    h, (gc, kv) = _scan(outer, x, (grouped_p, grouped_c))
    return h, {
        "ssm": jax.tree.map(
            lambda a: a.reshape(cfg.num_layers, *a.shape[2:]), gc),
        "kv": {"k": kv["k"].astype(cache["kv"]["k"].dtype),
               "v": kv["v"].astype(cache["kv"]["v"].dtype)},
    }
