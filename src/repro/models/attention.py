"""GQA attention with local/global windows, softcap, qk-norm, KV caches.

Baseline math is pure jnp (what the dry-run lowers); the TPU hot path is the
Pallas flash-attention kernel in ``repro.kernels`` selected via
``ops.attention`` when ``use_kernel=True`` (validated in interpret mode).

Supports:
* grouped-query attention (num_kv_heads <= num_heads),
* sliding-window masks (gemma2 local layers; window passed per-layer so a
  scan over alternating local/global layers stays a single fused body),
* attention logit soft-capping (gemma2),
* qk layer-norm (chameleon),
* decode with a (batch, kv_heads, max_seq, head_dim) cache updated in place,
* cross-attention (whisper decoder).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, apply_rope, dense_init, rms_norm, softcap


def init_attention(kg: KeyGen, cfg: ArchConfig, dtype: Any,
                   cross: bool = False) -> Dict[str, jax.Array]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": dense_init(kg(), (d, nq, hd), dtype, fan_in=d),
        "wk": dense_init(kg(), (d, nkv, hd), dtype, fan_in=d),
        "wv": dense_init(kg(), (d, nkv, hd), dtype, fan_in=d),
        "wo": dense_init(kg(), (nq, hd, d), dtype, fan_in=nq * hd),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: Dict[str, jax.Array], x: jax.Array, kv_src: jax.Array,
                 cfg: ArchConfig, positions: Optional[jax.Array],
                 kv_positions: Optional[jax.Array],
                 use_rope: bool) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        assert positions is not None and kv_positions is not None
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ArchConfig) -> jax.Array:
    """q: (B,S,nq,hd), k: (B,T,nkv,hd) -> scores (B,nkv,G,S,T)."""
    b, s, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    return scores / math.sqrt(hd)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,nkv,G,S,T), v: (B,T,nkv,hd) -> (B,S,nq,hd)."""
    b, nkv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, nkv * g, -1)


def attention(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig, *,
              positions: jax.Array,
              window: Optional[jax.Array] = None,
              causal: bool = True,
              kv_src: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              use_rope: bool = True,
              use_kernel: bool = False) -> jax.Array:
    """Full-sequence attention (train / prefill).

    ``window``: scalar (static or traced) sliding-window size; None/0 = full.
    ``kv_src``: encoder output for cross-attention (then causal=False).
    """
    cross = kv_src is not None
    kv_src = x if kv_src is None else kv_src
    if kv_positions is None:
        kv_positions = (positions if not cross else
                        jnp.arange(kv_src.shape[1])[None, :])
    q, k, v = _project_qkv(p, x, kv_src, cfg, positions, kv_positions,
                           use_rope and not cross)

    if use_kernel and not cross:
        from ..kernels import ops as kops
        out = kops.flash_attention(
            q, k, v, causal=causal,
            window=int(window) if window is not None else 0,
            logit_cap=cfg.attn_softcap)
    else:
        scores = _gqa_scores(q, k, cfg)
        scores = softcap(scores, cfg.attn_softcap)
        qpos = positions[:, None, None, :, None]          # (B,1,1,S,1)
        kpos = kv_positions[:, None, None, None, :]       # (B,1,1,1,T)
        mask = jnp.ones_like(scores, dtype=bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            w = jnp.asarray(window)
            mask = mask & jnp.where(w > 0, qpos - kpos < w, True)
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v)
    out = out.astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.use_bias:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Decode path (single new token against a cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype: Any) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
    }


def decode_attention(p: Dict[str, jax.Array], x: jax.Array,
                     cache: Dict[str, jax.Array], pos: jax.Array,
                     cfg: ArchConfig, *,
                     window: Optional[jax.Array] = None,
                     use_rope: bool = True
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  x: (B,1,d); cache k/v: (B,T,nkv,hd); pos scalar."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    t_max = cache["k"].shape[1]
    kv_positions = positions  # rope for the new key at `pos`
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, kv_positions,
                                   use_rope)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(
        cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(
        cache["v"].dtype), (0, pos, 0, 0))
    scores = _gqa_scores(q, k, cfg)                 # (B,nkv,G,1,T)
    scores = softcap(scores, cfg.attn_softcap)
    kpos = jnp.arange(t_max)[None, None, None, None, :]
    mask = kpos <= pos
    if window is not None:
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, pos - kpos < w, True)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.use_bias:
        y = y + p["bo"]
    return y, {"k": k, "v": v}


def decode_cross_attention(p: Dict[str, jax.Array], x: jax.Array,
                           k: jax.Array, v: jax.Array,
                           cfg: ArchConfig) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decode)."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.use_bias:
        q = q + p["bq"]
    scores = _gqa_scores(q, k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.use_bias:
        y = y + p["bo"]
    return y
