"""Mamba2 — State Space Duality (SSD), chunked scan + O(1) decode.

The SSD "dual form" (arXiv:2405.21060) computes the selective-SSM sequence
mixing as chunk-local attention-like matmuls plus a tiny cross-chunk
recurrence — ideal for the TPU MXU: all heavy ops are (Q x Q) / (Q x N)
matmuls with Q = chunk length, N = state size.

The chunk-local contraction is also available as a Pallas kernel
(``repro.kernels.ssd_scan``); this file is the pure-jnp form the dry-run
lowers and the oracle the kernel is tested against.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, dense_init, rms_norm


def init_mamba2(kg: KeyGen, cfg: ArchConfig, dtype: Any
                ) -> Dict[str, jax.Array]:
    d = cfg.d_model
    di, n, g, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    conv_ch = di + 2 * g * n
    return {
        "in_proj": dense_init(kg(), (d, 2 * di + 2 * g * n + h), dtype,
                              fan_in=d),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_ch), dtype,
                             fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(kg(), (di, d), dtype, fan_in=di),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, n, g, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z, x, bc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + 2 * g * n], axis=-1)
    b_, c_ = jnp.split(bc, 2, axis=-1)
    return z, x, b_, c_, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B,S,C), w: (W,C)."""
    wsz = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wsz - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(wsz))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_: jax.Array,
                c_: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None,
                use_kernel: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD dual-form scan.

    x: (B,S,H,P)   dt: (B,S,H)   a: (H,) negative decay rates
    b_, c_: (B,S,G,N) with G groups broadcast over H heads.
    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    B, S, H, P = x.shape
    G, N = b_.shape[2], b_.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc, Q = S // chunk, chunk
    rep = H // G

    if use_kernel:
        from ..kernels import ops as kops
        return kops.ssd_scan(x, dt, a, b_, c_, chunk,
                             initial_state=initial_state)

    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)                       # already softplus'ed
    bc = jnp.repeat(b_.reshape(B, nc, Q, G, N), rep, axis=3)  # (B,nc,Q,H,N)
    cc = jnp.repeat(c_.reshape(B, nc, Q, G, N), rep, axis=3)

    dA = dtc * a[None, None, None, :]                   # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                        # (B,nc,Q,H)

    # ---- intra-chunk (the "attention-like" quadratic term) --------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :]                          # (B,nc,Q,1,H)
    lj = cum[:, :, None, :, :]                          # (B,nc,1,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(li - lj), 0.0)          # (B,nc,Q,Q,H)
    scores = jnp.einsum("bnihk,bnjhk->bnijh", cc, bc)   # (B,nc,Q,Q,H)
    att = scores * L * dtc[:, :, None, :, :]            # weight by dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xc)

    # ---- chunk states ------------------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,Q,H)
    weighted_x = xc * (dtc * decay_to_end)[..., None]   # (B,nc,Q,H,P)
    states = jnp.einsum("bnqhk,bnqhp->bnhkp", bc, weighted_x)  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence ----------------------------------------------------
    # log-depth associative scan over chunks (no while-loop in the HLO:
    # cheaper on the MXU pipeline AND correctly accounted by cost analysis).
    # Composition of (decay a, state b): (a1,b1)*(a2,b2) = (a1a2, a2b1+b2).
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,H)
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((B, H, N, P), x.dtype)).astype(jnp.float32)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2[..., None, None] * b1 + b2

    a_scan, b_scan = jax.lax.associative_scan(
        combine, (chunk_decay.astype(jnp.float32),
                  states.astype(jnp.float32)), axis=1)
    # inclusive scan gives state AFTER each chunk; shift right for BEFORE
    h_after = (a_scan[..., None, None] * h0[:, None] + b_scan)
    h_prevs = jnp.concatenate([h0[:, None], h_after[:, :-1]],
                              axis=1).astype(x.dtype)   # (B,nc,H,N,P)
    h_final = h_after[:, -1].astype(x.dtype)

    # ---- inter-chunk contribution ----------------------------------------------------
    y_inter = jnp.einsum("bnqhk,bnhkp->bnqhp",
                         cc * jnp.exp(cum)[..., None], h_prevs)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_final


def mamba2_forward(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
                   use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba2 mixer.  x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, n, g, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    P = cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xin, b_, c_, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, b_, c_ = jnp.split(conv_out, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, h, P)
    y, _ = ssd_chunked(xh, dt, a,
                       b_.reshape(B, S, g, n), c_.reshape(B, S, g, n),
                       min(cfg.ssm_chunk, S), use_kernel=use_kernel)
    y = (y + xh * p["D"][None, None, :, None]).astype(x.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


# ---------------------------------------------------------------------------
# Decode (O(1) per token — why SSM archs run the long_500k cell)
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype: Any
                   ) -> Dict[str, jax.Array]:
    di, n, g = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups
    h, P = cfg.ssm_heads, cfg.ssm_headdim
    conv_ch = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, n, P), dtype),
    }


def mamba2_decode_step(p: Dict[str, jax.Array], x: jax.Array,
                       cache: Dict[str, jax.Array], cfg: ArchConfig
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,1,d) one token; cache: conv window + SSM state."""
    B = x.shape[0]
    di, n, g = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_groups
    h, P = cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xin, b_, c_, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, b_, c_], axis=-1)[:, 0]   # (B,C)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    xin, b_, c_ = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"])                      # (B,h)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                    # (B,h)
    rep = h // g
    bh = jnp.repeat(b_.reshape(B, g, n), rep, axis=1)          # (B,h,n)
    ch = jnp.repeat(c_.reshape(B, g, n), rep, axis=1)
    xh = xin.reshape(B, h, P)
    state = (cache["state"] * decay[..., None, None]
             + jnp.einsum("bhk,bhp->bhkp",
                          bh * dt[..., None], xh).astype(cache["state"].dtype))
    y = jnp.einsum("bhk,bhkp->bhp", ch, state.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "state": state}
