"""Shared model substrate: configs, norms, rope, activations, losses.

Everything is functional JAX (params as pytrees, pure apply fns) so that
Application Drops wrapping these steps are stateless, exactly as the paper
requires of pipeline components (§3.1: "the computational tasks are
stateless, the Application Drops are stateful").
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact published numbers in configs/)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention details
    rope_theta: float = 10000.0
    local_window: int = 0          # 0 -> full attention
    alternate_local_global: bool = False   # gemma2: even layers local
    attn_softcap: float = 0.0      # gemma2 logit soft-capping
    final_softcap: float = 0.0
    qk_norm: bool = False          # chameleon
    use_bias: bool = False
    activation: str = "swiglu"     # swiglu | gelu | relu2
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # hybrid (zamba2): one shared attention block applied every N layers
    shared_attn_period: int = 0
    # enc-dec (whisper)
    num_encoder_layers: int = 0
    encoder_ratio: int = 8         # enc_len = seq_len // ratio (stub frontend)
    # systems knobs
    dtype: str = "bfloat16"
    sharding_strategy: str = "dp"  # dp | fsdp
    subquadratic: bool = False     # eligible for long_500k
    notes: str = ""

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and reporting)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + hd * nq * d
        if self.activation in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            mlp_total = self.num_experts * mlp + d * self.num_experts
        else:
            mlp_total = mlp
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, n, g = self.ssm_inner, self.ssm_state, self.ssm_groups
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * n + h)
            conv = (di + 2 * g * n) * self.ssm_conv
            ssm = in_proj + conv + di * d + di + 2 * h  # out, norm, A/D
        per_layer: float
        if self.family == "ssm":
            per_layer = ssm + d            # + norm
        elif self.family == "hybrid":
            per_layer = ssm + 2 * d
        else:
            per_layer = attn + mlp_total + 2 * d
        total = self.num_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_period:
            total += attn + mlp_total + 2 * d   # one shared block
        if self.family == "encdec":
            enc = self.num_encoder_layers * (attn + mlp_total + 2 * d)
            dec_cross = self.num_layers * (attn + d)   # cross-attn per layer
            total += enc + dec_cross
        total += v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d                  # lm head
        total += d                          # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe" or not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = (3 if self.activation in ("swiglu", "geglu") else 2) * d * f
        dead = self.num_layers * (self.num_experts - self.top_k) * mlp
        return int(self.param_count() - dead)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def activation_fn(name: str):
    if name in ("swiglu", "geglu"):   # gated: handled at call sites
        return jax.nn.silu if name == "swiglu" else jax.nn.gelu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":   # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                      # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean CE over tokens; logits (..., V) fp32-accumulated; labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab_size)
    loss = (lse - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype: Any, fan_in: Optional[int] = None) -> jax.Array:
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


class KeyGen:
    """Deterministic key splitter for param init."""

    def __init__(self, key: jax.Array) -> None:
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub
