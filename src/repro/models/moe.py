"""Mixture-of-Experts layer: top-k routing + capacity dispatch.

TPU-native adaptation of the paper's ``GroupBy`` corner-turn: the token ->
expert shuffle is *exactly* DALiuGE's static re-grouping (keys known a
priori: the router's top-k), realised here as a scatter/gather pair that
GSPMD lowers to all-to-all when experts and tokens live on different mesh
axes.

Dispatch is group-wise (GShard-style): tokens are viewed as (groups, S, d)
with per-group expert capacity C = S*top_k*capacity_factor/E.  Instead of the
classic one-hot dispatch einsum — O(S*E*C) memory, infeasible at 1M tokens —
we use scatter-add / gather with computed slot positions, which XLA handles
as dynamic-update ops and shards cleanly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import ctx as sctx
from .common import ArchConfig, KeyGen, activation_fn, dense_init


def init_moe(kg: KeyGen, cfg: ArchConfig, dtype: Any) -> Dict[str, jax.Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": dense_init(kg(), (d, e), jnp.float32, fan_in=d),
        "w1": dense_init(kg(), (e, d, f), dtype, fan_in=d),
        "w2": dense_init(kg(), (e, f, d), dtype, fan_in=f),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = dense_init(kg(), (e, d, f), dtype, fan_in=d)
    return p


def expert_capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_block(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
              num_groups: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    ``num_groups``: dispatch groups (defaults to B).  Tokens within a group
    share one capacity budget; groups shard over the data axes.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g = num_groups if num_groups else b
    tokens = b * s
    assert tokens % g == 0, (tokens, g)
    sg = tokens // g
    xg = x.reshape(g, sg, d)
    cap = expert_capacity(cfg, sg)

    # --- routing ------------------------------------------------------------
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                 # (g, sg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch/GShard): E * mean(frac_i * prob_i)
    me = probs.mean(axis=(0, 1))                          # (e,)
    one_hot_top1 = jax.nn.one_hot(idx[..., 0], e)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # --- slot positions within each expert's capacity ----------------------------
    # flatten the k assignment slots; earlier slots win capacity
    flat_idx = idx.reshape(g, sg * k)                     # (g, n)
    slot_one_hot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(slot_one_hot, axis=1) - 1  # (g, n, e)
    pos = jnp.take_along_axis(
        pos_in_expert, flat_idx[..., None], axis=-1)[..., 0]   # (g, n)
    keep = pos < cap
    # dropped tokens scatter out of bounds -> mode='drop' discards them
    pos_safe = jnp.where(keep, pos, cap)

    # --- dispatch: buffer[g, e, c, d] via scatter-add ------------------------------
    token_src = jnp.broadcast_to(
        jnp.repeat(jnp.arange(sg), k)[None, :], (g, sg * k))
    vals = jnp.take_along_axis(xg, token_src[..., None], axis=1)  # (g,n,d)
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    g_ids = jnp.broadcast_to(jnp.arange(g)[:, None], (g, sg * k))
    buf = buf.at[g_ids, flat_idx, pos_safe].add(vals, mode="drop")
    # EP profile: tokens corner-turn to their experts here (GroupBy!)
    buf = sctx.constrain(buf, "moe_buffer")

    # --- expert FFN (E stacked experts; f-dim is TP-sharded) ----------------------
    act = activation_fn(cfg.activation)
    h = jnp.einsum("gecd,edf->gecf", buf, p["w1"])
    if cfg.activation in ("swiglu", "geglu"):
        gate = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        hg = jnp.einsum("gecd,edf->gecf", buf, p["w3"])
        h = gate(h) * hg
    else:
        h = act(h)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    out_buf = sctx.constrain(out_buf, "moe_buffer")

    # --- combine: gather back + gate-weighted sum over k ---------------------------
    gathered = out_buf[g_ids, flat_idx, pos_safe]          # (g, n, d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    gathered = gathered.reshape(g, sg, k, d)
    y = jnp.einsum("gskd,gsk->gsd", gathered.astype(jnp.float32),
                   gates).astype(x.dtype)
    return y.reshape(b, s, d), aux
