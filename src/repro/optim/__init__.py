from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .compress import (compress_gradients, decompress_gradients,
                       error_feedback_update)
from .schedule import cosine_schedule

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "compress_gradients",
           "decompress_gradients", "error_feedback_update",
           "cosine_schedule"]
