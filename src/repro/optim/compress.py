"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ nodes the gradient all-reduce dominates the collective term for
small models; int8 quantisation cuts its bytes 4x (vs fp32) / 2x (vs bf16).
Error feedback (residual carried to the next step) keeps SGD convergence —
the property test checks the residual telescopes (the sum of decompressed
gradients converges to the sum of true gradients).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_gradients(grads: Any) -> Tuple[Any, Any]:
    """Per-tensor symmetric int8 quantisation: returns (q, scales)."""
    def q(g):
        g = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        return jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8), \
            scale
    out = jax.tree.map(q, grads)
    qs = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return qs, scales


def decompress_gradients(qs: Any, scales: Any) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def error_feedback_update(grads: Any, residual: Any
                          ) -> Tuple[Any, Any, Any]:
    """(grads+residual) -> compress -> (q, scales, new_residual)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs, scales = compress_gradients(corrected)
    recon = decompress_gradients(qs, scales)
    new_residual = jax.tree.map(lambda c, d: c - d, corrected, recon)
    return qs, scales, new_residual
