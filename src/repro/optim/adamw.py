"""AdamW + global-norm clipping, pure JAX (no external deps).

Optimizer state shards exactly like the parameters (ZeRO-style: the sharding
rules apply to every leaf of the state pytree), so FSDP archs pay no
replication for m/v.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params: Any, grads: Any, state: AdamWState, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1
                 ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
