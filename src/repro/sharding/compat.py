"""JAX version-compat shims for mesh context management.

The ambient-mesh context manager has moved repeatedly across JAX releases:

* ``jax.set_mesh(mesh)``            — newest spelling,
* ``jax.sharding.use_mesh(mesh)``   — intermediate spelling,
* ``jax.experimental.set_mesh`` / ``jax.experimental.use_mesh`` — earlier,
* ``with mesh:``                    — the classic ``Mesh.__enter__`` context
  (always available; sufficient here because every ``jit`` call also passes
  explicit ``NamedSharding`` in_shardings, which carry the mesh).

``use_mesh(mesh)`` resolves whichever exists on the installed JAX, so the
launch/dryrun stack and the sharding tests run unchanged across versions.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, ContextManager, Optional

import jax


def _resolve() -> Optional[Callable[[Any], ContextManager]]:
    for mod, attr in (
            (jax, "set_mesh"),
            (jax.sharding, "use_mesh"),
            (getattr(jax, "experimental", None), "set_mesh"),
            (getattr(jax, "experimental", None), "use_mesh"),
    ):
        fn = getattr(mod, attr, None) if mod is not None else None
        if fn is not None:
            return fn
    return None


_CTX_FN = _resolve()


def use_mesh(mesh) -> ContextManager:
    """Context manager making ``mesh`` the ambient mesh, on any JAX."""
    if _CTX_FN is not None:
        return _CTX_FN(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)  # pragma: no cover - defensive
