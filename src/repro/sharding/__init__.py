from .compat import use_mesh
from .rules import (batch_pspecs, cache_pspecs, data_axes, opt_pspecs,
                    param_pspecs, shard_if_divisible)

__all__ = ["batch_pspecs", "cache_pspecs", "data_axes", "opt_pspecs",
           "param_pspecs", "shard_if_divisible", "use_mesh"]
