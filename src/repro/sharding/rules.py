"""Logical-axis -> PartitionSpec rules for every architecture.

The production mesh (launch/mesh.py) is ``(16,16)`` axes ``("data","model")``
single-pod or ``(2,16,16)`` axes ``("pod","data","model")`` multi-pod.

Baseline strategy per tensor class (DESIGN.md §5):
  * vocab / d_ff / attention heads      -> TP over "model" (if divisible)
  * batch (and MoE groups)              -> DP over ("pod","data")
  * large d_model dims of weights       -> FSDP over ("pod","data") when the
    arch's ``sharding_strategy == "fsdp"`` (ZeRO-3: gathered per layer
    inside the scan)
  * KV caches at decode                 -> kv-heads over "model" when they
    divide, else the *sequence* axis over "model" (distributed
    flash-decoding; GSPMD inserts the softmax-stat reductions)
  * everything that doesn't divide      -> replicated, recorded in
    ``decisions`` so the dry-run report shows every fallback.

All functions are pure metadata: no devices touched.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ArchConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape.get(a, 1)   # absent axis (derived meshes) = no shard
    return n


def shard_if_divisible(dim: int, mesh: Mesh, names,
                       decisions: Optional[List[str]] = None,
                       label: str = "") -> Optional[Any]:
    """Return ``names`` if dim divides the axis product, else None."""
    sz = axis_size(mesh, names)
    if sz > 1 and dim % sz == 0:
        return names
    if decisions is not None and sz > 1:
        decisions.append(f"replicated {label} (dim {dim} % {sz} != 0)")
    return None


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def shard_best(dim: int, mesh: Mesh, candidates,
               decisions: Optional[List[str]] = None, label: str = ""):
    """First candidate axis-group that divides ``dim`` wins (EP cascade)."""
    for names in candidates:
        if names is None:
            continue
        sz = axis_size(mesh, names)
        if sz > 1 and dim % sz == 0:
            return names
    if decisions is not None:
        decisions.append(f"replicated {label} (dim {dim})")
    return None


def _leaf_spec(path: str, shape: Tuple[int, ...], cfg: ArchConfig,
               mesh: Mesh, decisions: List[str],
               tp=("model",), expert_axis: Optional[str] = None) -> P:
    da = data_axes(mesh)
    fsdp = da if cfg.sharding_strategy == "fsdp" else None
    m = tuple(tp) if len(tp) > 1 else tp[0]
    head_casc = [tuple(tp)] + [(a,) for a in tp]

    def div(dim, names, label):
        if label.endswith("heads") or label == "vocab" or label == "ffn":
            if names is m:
                got = shard_best(dim, mesh, head_casc, decisions,
                                 f"{path}:{label}")
                return got if got is None or len(got) > 1 else got[0]
        return shard_if_divisible(dim, mesh, names, decisions,
                                  f"{path}:{label}")

    stacked = path.startswith("['layers']") or \
        path.startswith("['enc_layers']")
    L = (1,) if stacked else ()           # leading scan axis -> None

    def spec(*tail):
        return P(*([None] * len(L) + list(tail)))

    body = shape[len(L):]

    # --- embeddings & head ------------------------------------------------
    if "embed" in path:
        return P(div(shape[0], m, "vocab"),
                 div(shape[1], fsdp, "embed-fsdp"))
    if "lm_head" in path:
        return P(div(shape[0], fsdp, "dmodel-fsdp"),
                 div(shape[1], m, "vocab"))
    # --- attention ---------------------------------------------------------
    if path.endswith("['wq']") or path.endswith("['wo']"):
        if path.endswith("['wq']"):       # (d, nq, hd)
            return spec(div(body[0], fsdp, "d-fsdp"),
                        div(body[1], m, "qheads"), None)
        return spec(div(body[0], m, "qheads"), None,
                    div(body[2], fsdp, "d-fsdp"))
    if path.endswith("['wk']") or path.endswith("['wv']"):
        return spec(div(body[0], fsdp, "d-fsdp"),
                    div(body[1], m, "kvheads"), None)
    if path.endswith("['bq']"):
        return spec(div(body[0], m, "qheads"), None)
    if path.endswith("['bk']") or path.endswith("['bv']"):
        return spec(div(body[0], m, "kvheads"), None)
    # --- dense mlp -----------------------------------------------------------
    if path.endswith("['w1']") or path.endswith("['w3']"):
        if len(body) == 3:                # moe (E, d, f)
            e_sh = (expert_axis if expert_axis
                    and body[0] % mesh.shape[expert_axis] == 0 else None)
            ffn_tp = ("tp",) if expert_axis else m
            return spec(e_sh, div(body[1], fsdp, "d-fsdp"),
                        shard_if_divisible(body[2], mesh, ffn_tp,
                                           decisions, f"{path}:ffn"))
        return spec(div(body[0], fsdp, "d-fsdp"), div(body[1], m, "ffn"))
    if path.endswith("['w2']"):
        if len(body) == 3:                # moe (E, f, d)
            e_sh = (expert_axis if expert_axis
                    and body[0] % mesh.shape[expert_axis] == 0 else None)
            ffn_tp = ("tp",) if expert_axis else m
            return spec(e_sh,
                        shard_if_divisible(body[1], mesh, ffn_tp,
                                           decisions, f"{path}:ffn"),
                        div(body[2], fsdp, "d-fsdp"))
        return spec(div(body[0], m, "ffn"), div(body[1], fsdp, "d-fsdp"))
    if path.endswith("['router']"):
        return spec(div(body[0], fsdp, "d-fsdp"), None)
    # --- mamba2 ---------------------------------------------------------------
    if path.endswith("['in_proj']"):      # (d, K-packed)
        return spec(div(body[0], fsdp, "d-fsdp"), None)
    if path.endswith("['out_proj']"):     # (di, d)
        return spec(None, div(body[1], fsdp, "d-fsdp"))
    # conv_w / conv_b / A_log / D / dt_bias / norms / biases: replicate
    return spec(*([None] * len(body)))


def param_pspecs(cfg: ArchConfig, params_abstract: Any, mesh: Mesh,
                 tp=("model",), expert_axis: Optional[str] = None
                 ) -> Tuple[Any, List[str]]:
    decisions: List[str] = []
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abstract)
    specs = []
    for path, leaf in flat:
        specs.append(_leaf_spec(jax.tree_util.keystr(path),
                                tuple(leaf.shape), cfg, mesh, decisions,
                                tp=tp, expert_axis=expert_axis))
    return jax.tree_util.tree_unflatten(treedef, specs), decisions


def replicated_pspecs(params_abstract: Any) -> Any:
    """All-replicated params (dp_all profile: model is small, DP is king)."""
    return jax.tree.map(lambda l: P(*([None] * len(l.shape))),
                        params_abstract)


def zero_opt_pspecs(opt_state_abstract: Any, mesh: Mesh) -> Any:
    """ZeRO: shard optimizer moments over whatever axes their dims allow
    (independent of the replicated param layout)."""
    from ..optim import AdamWState
    axes_avail = [a for a in ("data", "model") if a in mesh.axis_names]

    def leaf(l) -> P:
        dims: List[Any] = [None] * len(l.shape)
        used = set()
        for ax in axes_avail:
            for i, d in enumerate(l.shape):
                if dims[i] is None and i not in used \
                        and d % mesh.shape[ax] == 0 and d >= mesh.shape[ax]:
                    dims[i] = ax
                    used.add(i)
                    break
        return P(*dims)

    def tmap(t):
        return jax.tree.map(leaf, t)

    from ..optim import AdamWState as _A
    return _A(step=P(), m=tmap(opt_state_abstract.m),
              v=tmap(opt_state_abstract.v))


def opt_pspecs(param_specs: Any, opt_state_abstract: Any) -> Any:
    """Adam m/v shard exactly like their parameters (ZeRO)."""
    from ..optim import AdamWState
    return AdamWState(step=P(),
                      m=param_specs, v=param_specs)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def _batch_axis(mesh: Mesh, b: int) -> Optional[Tuple[str, ...]]:
    da = data_axes(mesh)
    return da if b % axis_size(mesh, da) == 0 else None


def batch_pspecs(cfg: ArchConfig, batch_abstract: Dict[str, Any],
                 mesh: Mesh, batch_axes=None) -> Dict[str, Any]:
    def baxis(b):
        if batch_axes is not None:
            return batch_axes if b % axis_size(mesh, batch_axes) == 0 \
                else _batch_axis(mesh, b)
        return _batch_axis(mesh, b)

    out: Dict[str, Any] = {}
    for k, v in batch_abstract.items():
        if k in ("tokens", "labels"):
            out[k] = P(baxis(v.shape[0]), None)
        elif k == "frames":
            out[k] = P(baxis(v.shape[0]), None, None)
        elif k == "pos":
            out[k] = P()
        elif k == "cache":
            out[k] = cache_pspecs(cfg, v, mesh)
        else:
            out[k] = P(*([None] * np.ndim(v)))
    return out


def cache_pspecs(cfg: ArchConfig, cache_abstract: Any, mesh: Mesh) -> Any:
    """KV: (L,B,T,kv,hd); SSM state: (L,B,h,n,p); conv: (L,B,W,C)."""
    decisions: List[str] = []

    def kv_spec(leaf):
        L, B, T, KV, HD = leaf.shape
        b = _batch_axis(mesh, B)
        kv = shard_if_divisible(KV, mesh, "model", decisions, "kvcache-heads")
        if kv is not None:
            return P(None, b, None, kv, None)
        # flash-decoding layout: shard the sequence axis instead
        t = shard_if_divisible(T, mesh, "model", decisions, "kvcache-seq")
        return P(None, b, t, None, None)

    def spec_for(path: str, leaf) -> P:
        if "cross_k" in path or "cross_v" in path:   # (L,B,enc,kv,hd)
            return kv_spec(leaf)
        if "'k'" in path or "'v'" in path:
            return kv_spec(leaf)
        if "state" in path:                          # (L,B,h,n,p)
            L, B, H, N, Pdim = leaf.shape
            return P(None, _batch_axis(mesh, B),
                     shard_if_divisible(H, mesh, "model", decisions,
                                        "ssm-heads"), None, None)
        if "conv" in path:                           # (L,B,W,C)
            return P(None, _batch_axis(mesh, leaf.shape[1]), None, None)
        return P(*([None] * len(leaf.shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    specs = [spec_for(jax.tree_util.keystr(p), l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def logits_pspec(cfg: ArchConfig, b: int, mesh: Mesh) -> P:
    return P(_batch_axis(mesh, b), None,
             shard_if_divisible(cfg.padded_vocab, mesh, "model"))
