"""Activation-sharding context: profile-driven constraints inside models.

The model code stays profile-agnostic; it calls ``constrain(x, role)`` at a
few strategic points (residual stream, MoE dispatch buffers, logits).  The
active ``ShardProfile`` decides what PartitionSpec (if any) each role gets.
Profiles are the §Perf hillclimbing lever:

  baseline   - no explicit constraints (GSPMD propagation only)
  dp_all     - batch sharded over (data x model): pure 256-way DP inside the
               fixed mesh; params replicated, optimizer ZeRO-sharded.
               For small archs whose TP would otherwise idle the model axis.
  sp         - sequence parallelism: the residual stream's seq dim lives on
               the model axis between blocks (reduce-scatter/all-gather
               replaces all-reduce; elementwise bytes shard 16x).
  ep         - expert parallelism on a derived (data, expert, tp) view of
               the same 256 chips; MoE dispatch becomes a true all-to-all
               (the paper's GroupBy corner-turn).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardProfile:
    name: str = "baseline"
    mesh: Optional[Mesh] = None
    # axis-name groups (derived meshes rename these)
    data_axes: Tuple[str, ...] = ("data",)
    tp_axes: Tuple[str, ...] = ("model",)
    expert_axis: Optional[str] = None


_local = threading.local()


def current() -> Optional[ShardProfile]:
    return getattr(_local, "profile", None)


@contextlib.contextmanager
def use_profile(profile: Optional[ShardProfile]):
    prev = getattr(_local, "profile", None)
    _local.profile = profile
    try:
        yield
    finally:
        _local.profile = prev


def _axis_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, role: str) -> jax.Array:
    """Apply the active profile's constraint for ``role`` (no-op outside)."""
    prof = current()
    if prof is None or prof.mesh is None:
        return x
    mesh = prof.mesh
    da, tp = prof.data_axes, prof.tp_axes
    dm = tuple(da) + tuple(tp)
    spec: Optional[P] = None

    if prof.name == "dp_all":
        if role in ("residual", "logits") and x.ndim >= 2:
            if x.shape[0] % _axis_size(mesh, dm) == 0:
                spec = P(dm, *([None] * (x.ndim - 1)))
        elif role == "moe_buffer" and x.ndim == 4:
            # pin the dispatch buffer's group axis: without this GSPMD
            # replicates the scatter destination (TB-scale all-reduces)
            if x.shape[0] % _axis_size(mesh, dm) == 0:
                spec = P(dm, None, None, None)
    elif prof.name == "sp":
        if role == "residual" and x.ndim == 3:
            b, s, _ = x.shape
            bs = da if b % _axis_size(mesh, da) == 0 else None
            if s % _axis_size(mesh, tp) == 0:
                spec = P(bs, tp, None)
    elif prof.name == "ep":
        e_ax = prof.expert_axis
        if role == "moe_buffer" and x.ndim == 4 and e_ax:
            g, e, c, d = x.shape
            gs = da if g % _axis_size(mesh, da) == 0 else None
            es = e_ax if e % mesh.shape[e_ax] == 0 else None
            spec = P(gs, es, None, None)
        if role == "residual" and x.ndim == 3:
            b = x.shape[0]
            if b % _axis_size(mesh, da) == 0:
                spec = P(da, None, None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
