"""End-to-end training driver THROUGH the graph engine (deliverable b).

The training run is a DALiuGE logical graph, exactly as the paper runs
astronomy pipelines:

  state[0] (root Data Drop: init or checkpoint-restored TrainState)
  Loop(supersteps):
      Scatter(shards): load_batch  -> batch-shard Data Drops   (data pipeline)
      train_app(state[t], batches) -> state[t+1] + metrics     (jitted JAX)
      every k-th iteration the metrics drop feeds a checkpoint app

Loop-carried state uses the paper's "new Data Drops per iteration"; the
jitted train step is the stateless task inside a stateful Application Drop.
Fault story: if any node dies mid-run, lineage recovery re-executes lost
drops (deterministic data pipeline => identical batches); checkpoints allow
cross-session restart.

CLI:
  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 40
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpointing import CheckpointManager
from ..configs import get_smoke_config
from ..core import EngineConfig, Pipeline, register_app
from ..data import synthetic_batch
from ..dsl import GraphBuilder
from ..models.common import ArchConfig
from ..train import make_train_step, train_state_init

PRESETS: Dict[str, ArchConfig] = {
    # ~100M-class decoder (TPU-sized example; minutes/step on 1 CPU).
    # Embeddings tied and vocab sized to the few-hundred-step token budget
    # (untied 32k vocab needs ~100x more tokens before per-id rows align).
    "lm100m": ArchConfig(
        name="lm100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=2048, tie_embeddings=True, activation="swiglu",
        dtype="float32", rope_theta=10000.0),
    # ~20M: a few hundred steps in minutes on CPU
    "lm20m": ArchConfig(
        name="lm20m", family="dense", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=6, head_dim=64, d_ff=1536,
        vocab_size=512, tie_embeddings=True, activation="swiglu",
        dtype="float32"),
    # seconds-scale smoke
    "tiny": dataclasses.replace(get_smoke_config("codeqwen15_7b"),
                                name="tiny"),
}


def build_training_graph(steps: int, shards: int, ckpt_every: int):
    g = GraphBuilder("train")
    g.data("state0")
    g.component("seed", app="identity")
    with g.loop("steps", steps):
        g.data("state", loop_entry=True)
        with g.scatter("shard", shards):
            g.component("load", app="train/load_batch", time=0.01)
            g.data("batch")
        with g.gather("collect", shards):
            g.component("step", app="train/step", time=1.0)
        g.data("state_next", loop_exit=True, carries="state")
        g.connect("state", "step")
        g.chain("load", "batch", "step", "state_next")
        if ckpt_every:
            g.component("maybe_ckpt", app="train/checkpoint", time=0.05)
            g.data("ckpt_marker", payload="null")
            g.chain("state_next", "maybe_ckpt", "ckpt_marker")
    g.component("final", app="identity")
    g.data("state_final")
    g.chain("state0", "seed", "state")
    g.chain("state_next", "final", "state_final")
    return g.graph()


def run_training(cfg: ArchConfig, *, steps: int = 40, shards: int = 2,
                 batch_per_shard: int = 4, seq: int = 128,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
                 resume: bool = False, peak_lr: float = 1e-3,
                 num_nodes: int = 2, log_every: int = 10) -> Dict[str, Any]:
    # NO buffer donation here: the state payload is a write-once Drop that
    # the checkpoint app may still be snapshotting when the next iteration's
    # step runs (donation would invalidate it under the reader's feet).
    # On-device production runs donate (launch/dryrun.py does); the engine
    # driver trades that for safe concurrent readers.
    train_step = jax.jit(make_train_step(
        cfg, peak_lr=peak_lr, warmup_steps=max(steps // 10, 1),
        total_steps=steps, remat=False))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    losses: list = []
    t_state = {"params_built": False}

    state0 = train_state_init(cfg, jax.random.PRNGKey(0))
    start_step = 0
    if mgr and resume:
        got = mgr.restore_latest(state0)
        if got is not None:
            start_step, restored = got
            state0 = jax.tree.map(jnp.asarray, restored)
            print(f"[train] resumed from step {start_step}")

    @register_app("train/load_batch")
    def load_batch(inputs, outputs, app):
        (it, shard) = app.meta["oid"]      # (loop index, shard index)
        b = synthetic_batch(17, shard, start_step + it, batch_per_shard,
                            seq, cfg.vocab_size)
        for o in outputs:
            o.write(b)

    @register_app("train/step")
    def step_app(inputs, outputs, app):
        state = None
        shards_np = []
        for i in inputs:
            v = i.read()
            if isinstance(v, dict) and "tokens" in v:
                shards_np.append(v)
            elif isinstance(v, tuple) and len(v) == 2:
                state = v[0]               # loop-carried (state, step)
            else:
                state = v                  # initial raw TrainState
        assert state is not None and shards_np
        batch = {k: jnp.asarray(np.concatenate([b[k] for b in shards_np]))
                 for k in shards_np[0]}
        new_state, metrics = train_step(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        it = app.meta["oid"][0]
        if log_every and (it % log_every == 0 or it == steps - 1):
            print(f"[train] step {start_step + it:5d} "
                  f"loss {loss:.4f} lr {float(metrics['lr']):.2e}",
                  flush=True)
        for o in outputs:
            if o.uid.startswith("state_next"):
                o.write((new_state, int(metrics["step"])))
            else:
                o.write(None)

    @register_app("train/checkpoint")
    def ckpt_app(inputs, outputs, app):
        it = app.meta["oid"][0]
        if mgr and ckpt_every and ((it + 1) % ckpt_every == 0
                                   or it == steps - 1):
            state, opt_step = inputs[0].read()
            mgr.save_async(opt_step, state)
        for o in outputs:
            o.write(None)

    # the loop-carried drop holds (state, step); the step app must accept
    # both the initial raw state and the tuple form:
    @register_app("identity")  # re-register: unwrap tuples gracefully
    def identity(inputs, outputs, app):
        vals = [i.read() for i in inputs]
        v = vals[0] if len(vals) == 1 else vals
        for o in outputs:
            o.write(v)

    lg = build_training_graph(steps, shards, ckpt_every if mgr else 0)
    with Pipeline(EngineConfig(num_nodes=num_nodes, workers_per_node=2,
                               dop=4)) as p:
        pgt = p.translate(lg)
        p.deploy()
        t0 = time.monotonic()
        rep = p.execute(inputs={"state0": state0}, timeout=24 * 3600)
        wall = time.monotonic() - t0
        assert rep.ok, rep.errors[:3]
        final_state, final_step = p.session.drops["state_final"].read()
    if mgr:
        mgr.wait()
    tokens = steps * shards * batch_per_shard * seq
    result = {
        "steps": steps, "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "losses": losses, "drops": len(pgt),
        "final_step": final_step,
    }
    print(f"[train] {steps} steps in {wall:.1f}s "
          f"({result['tokens_per_s']:.0f} tok/s); "
          f"loss {result['first_loss']:.3f} -> {result['last_loss']:.3f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--batch-per-shard", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    run_training(cfg, steps=args.steps, shards=args.shards,
                 batch_per_shard=args.batch_per_shard, seq=args.seq,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 resume=args.resume, peak_lr=args.lr)


if __name__ == "__main__":
    main()
