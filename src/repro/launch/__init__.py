# launch: mesh construction, dry-run, train/serve drivers.
# NOTE: importing this package must NOT touch jax device state —
# dryrun.py sets XLA_FLAGS before any jax import.
