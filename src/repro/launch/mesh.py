"""Production mesh construction (dry-run deliverable e.1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count locks at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominator terms)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, one direction)
HBM_BYTES = 16e9             # per chip
