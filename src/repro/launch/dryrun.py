import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first init.  512 placeholder host devices back the production meshes
# (16x16 single-pod, 2x16x16 multi-pod).  Set here and ONLY here — smoke
# tests and benchmarks see the real 1-CPU platform.

__doc__ = """Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  lower the step function with production in_shardings,
  compile it (proves the distribution config is coherent: no sharding
  mismatches, no unsupported collectives, no compile-time OOM),
  record memory_analysis / cost_analysis / per-collective bytes
  -> JSON under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch grok_1_314b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ARCH_NAMES, abstract_params, cell_supported,
                       get_config, input_specs)
from ..models.common import SHAPES, ArchConfig, ShapeConfig
from ..roofline import collective_bytes_from_hlo, model_flops, roofline_terms
from ..sharding import batch_pspecs, cache_pspecs, param_pspecs, use_mesh
from ..sharding.rules import opt_pspecs
from ..train.steps import (TrainState, make_decode_step, make_prefill_step,
                           make_train_step, train_state_init)
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

NUM_MICROBATCHES = 8   # train_4k: 256-batch -> 8 x 32 (bounds logits memory)


def _spec_to_shardings(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))


def _arg_bytes_per_device(mesh, abstract_trees, spec_trees) -> int:
    """Analytic per-device bytes of the inputs under their PartitionSpecs.

    memory_analysis() reports global-unique bytes, which hides the cost of
    REPLICATED tensors; this accounts a replicated leaf once per device."""
    total = 0
    for abs_t, spec_t in zip(abstract_trees, spec_trees):
        leaves = jax.tree.leaves(abs_t)
        specs = jax.tree.leaves(spec_t, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(leaves, specs):
            shards = 1
            for entry in (spec or ()):  # type: ignore[union-attr]
                if entry is None:
                    continue
                names = entry if isinstance(entry, tuple) else (entry,)
                for a in names:
                    shards *= mesh.shape.get(a, 1)
            nbytes = int(jnp.dtype(leaf.dtype).itemsize)
            for d in leaf.shape:
                nbytes *= d
            total += nbytes // max(shards, 1)
    return total


@dataclasses.dataclass
class Variant:
    """A §Perf hillclimbing variant: sharding profile + config tweaks."""

    name: str = "baseline"
    profile_name: str = "baseline"
    replicate_params: bool = False     # dp_all: replicate params, ZeRO opt
    batch_axes: Any = None             # e.g. ("data", "model") for dp_all
    derived_mesh: bool = False         # ep: reshape to (data, expert, tp)
    cfg_overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)
    remat: bool = True
    num_microbatches: Optional[int] = None


def make_variant(spec: str) -> Variant:
    v = Variant(name=spec)
    for part in spec.split("+"):
        if part in ("", "baseline"):
            continue
        if part == "dp_all":
            v.profile_name = "dp_all"
            v.replicate_params = True
            v.batch_axes = ("data", "model")
        elif part == "sp":
            v.profile_name = "sp"
        elif part == "ep":
            v.profile_name = "ep"
            v.derived_mesh = True
        elif part.startswith("chunk"):
            v.cfg_overrides["ssm_chunk"] = int(part[5:])
        elif part == "noremat":
            v.remat = False
        elif part.startswith("nm"):
            v.num_microbatches = int(part[2:])
        elif part == "pin":
            pass   # moe-buffer pinning (behaviour lives in sharding/ctx)
        elif part.startswith("cf"):
            v.cfg_overrides["capacity_factor"] = float(part[2:])
        else:
            raise ValueError(f"unknown variant part {part!r}")
    return v


def variant_mesh(mesh, variant: Variant):
    if not variant.derived_mesh:
        return mesh
    devs = mesh.devices
    if devs.ndim == 2:          # (data, model) -> (data, expert, tp)
        d0, d1 = devs.shape
        assert d1 % 8 == 0
        return jax.sharding.Mesh(devs.reshape(d0, 8, d1 // 8),
                                 ("data", "expert", "tp"))
    raise ValueError("ep variant is single-pod only (the roofline mesh)")


def _profile_for(variant: Variant, mesh):
    from ..sharding.ctx import ShardProfile
    if variant.profile_name == "baseline":
        return None
    if variant.profile_name == "ep":
        return ShardProfile(name="ep", mesh=mesh, data_axes=("data",),
                            tp_axes=("expert", "tp"), expert_axis="expert")
    return ShardProfile(name=variant.profile_name, mesh=mesh,
                        data_axes=tuple(a for a in ("pod", "data")
                                        if a in mesh.axis_names),
                        tp_axes=("model",))


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               cost_pass: bool = False, variant: Optional[Variant] = None):
    """Build + lower the cell's step function.  Returns (lowered, meta).

    Two flavours:
      * production pass (default): layers scanned, train microbatched —
        what would really run; used for memory_analysis + compile proof.
      * cost pass: layers UNROLLED and a single microbatch — XLA's
        HloCostAnalysis counts while-bodies once, so only the unrolled
        program yields true FLOPs/bytes/collective bytes.  Train totals are
        then scaled by num_microbatches.
    """
    import contextlib

    from ..models.model import unrolled_layers
    from ..sharding.ctx import use_profile
    from ..sharding.rules import replicated_pspecs, zero_opt_pspecs

    variant = variant or Variant()
    cfg = dataclasses.replace(cfg, **variant.cfg_overrides) \
        if variant.cfg_overrides else cfg
    mesh = variant_mesh(mesh, variant)
    profile = _profile_for(variant, mesh)

    decisions: list = []
    params_abs = abstract_params(cfg)
    if variant.replicate_params:
        pspecs = replicated_pspecs(params_abs)
        decisions = ["dp_all: params replicated; opt ZeRO-sharded"]
    elif variant.profile_name == "ep":
        pspecs, decisions = param_pspecs(cfg, params_abs, mesh,
                                         tp=("expert", "tp"),
                                         expert_axis="expert")
    else:
        pspecs, decisions = param_pspecs(cfg, params_abs, mesh)

    ctx = unrolled_layers(True) if cost_pass else contextlib.nullcontext()
    pctx = use_profile(profile)

    if shape.kind == "train":
        nm = variant.num_microbatches or NUM_MICROBATCHES
        if shape.global_batch % nm:
            nm = 1
        state_abs = jax.eval_shape(
            lambda: train_state_init(cfg, jax.random.PRNGKey(0)))
        if variant.replicate_params:
            ospecs = zero_opt_pspecs(state_abs.opt, mesh)
        else:
            ospecs = opt_pspecs(pspecs, state_abs.opt)
        state_specs = TrainState(params=pspecs, opt=ospecs, residual=None)
        batch_abs = input_specs(cfg, shape)
        if cost_pass:
            # one microbatch, costs scaled by nm afterwards
            batch_abs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (s.shape[0] // nm, *s.shape[1:]), s.dtype), batch_abs)
            step = make_train_step(cfg, num_microbatches=1,
                                   remat=variant.remat)
        else:
            step = make_train_step(cfg, num_microbatches=nm,
                                   remat=variant.remat)
        bspecs = batch_pspecs(cfg, batch_abs, mesh,
                              batch_axes=variant.batch_axes)
        with use_mesh(mesh), ctx, pctx:
            lowered = jax.jit(
                step,
                in_shardings=(_spec_to_shardings(mesh, state_specs),
                              _spec_to_shardings(mesh, bspecs)),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
        return lowered, {"num_microbatches": nm, "decisions": decisions,
                         "cost_scale": nm if cost_pass else 1,
                         "arg_bytes_per_device": _arg_bytes_per_device(
                             mesh, (state_abs, batch_abs),
                             (state_specs, bspecs))}

    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        batch_abs = input_specs(cfg, shape)
        bspecs = batch_pspecs(cfg, batch_abs, mesh,
                              batch_axes=variant.batch_axes)
        with use_mesh(mesh), ctx, pctx:
            lowered = jax.jit(
                step,
                in_shardings=(_spec_to_shardings(mesh, pspecs),
                              _spec_to_shardings(mesh, bspecs)),
            ).lower(params_abs, batch_abs)
        return lowered, {"decisions": decisions, "cost_scale": 1,
                         "arg_bytes_per_device": _arg_bytes_per_device(
                             mesh, (params_abs, batch_abs),
                             (pspecs, bspecs))}

    # decode
    step = make_decode_step(cfg)
    specs = input_specs(cfg, shape)
    cache_abs = specs["cache"]
    cspecs = cache_pspecs(cfg, cache_abs, mesh)
    tok_spec = batch_pspecs(cfg, {"tokens": specs["tokens"]}, mesh,
                            batch_axes=variant.batch_axes)["tokens"]
    with use_mesh(mesh), ctx, pctx:
        lowered = jax.jit(
            step,
            in_shardings=(_spec_to_shardings(mesh, pspecs),
                          _spec_to_shardings(mesh, cspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, P())),
            donate_argnums=(1,),
        ).lower(params_abs, cache_abs, specs["tokens"], specs["pos"])
    return lowered, {"decisions": decisions, "cost_scale": 1,
                     "arg_bytes_per_device": _arg_bytes_per_device(
                         mesh, (params_abs, cache_abs),
                         (pspecs, cspecs))}


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS_DIR, verbose: bool = True,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    var = make_variant(variant)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant,
        "family": cfg.family, "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    skip = cell_supported(cfg, shape)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        out_path.write_text(json.dumps(rec, indent=1))
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_name}: "
                  f"{skip}", flush=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec["chips"] = chips
    try:
        # ---- pass 1: production program (scan + microbatches) --------------
        t0 = time.monotonic()
        lowered, meta = lower_cell(cfg, shape, mesh, variant=var)
        rec.update({k: v for k, v in meta.items() if k != "cost_scale"})
        rec["lower_s"] = round(time.monotonic() - t0, 2)
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")
            if hasattr(mem, k)}
        args_b = rec["memory"].get("argument_size_in_bytes", 0)
        temp_b = rec["memory"].get("temp_size_in_bytes", 0)
        out_b = rec["memory"].get("output_size_in_bytes", 0)
        alias_b = rec["memory"].get("alias_size_in_bytes", 0)
        analytic_args = meta.get("arg_bytes_per_device", 0)
        rec["memory"]["per_device_bytes"] = int(
            analytic_args + (temp_b + max(out_b - alias_b, 0)) /
            max(chips, 1))
        rec["memory"]["arg_bytes_per_device"] = int(analytic_args)
        del compiled, lowered

        # ---- pass 2: cost program (unrolled layers, single microbatch) -----
        # XLA counts while-bodies once, so costs come from UNROLLED programs.
        # Unrolling the full depth is too slow; costs are exactly linear in
        # depth (identical layers), so we unroll L1 and L2 layers and
        # extrapolate: cost(L) = cost(L1) + (L-L1)*(cost(L2)-cost(L1))/(L2-L1)
        t2 = time.monotonic()
        per = max(cfg.shared_attn_period, 1)
        L1, L2 = (per, 2 * per) if cfg.family == "hybrid" else (2, 4)

        def reduced(L: int) -> ArchConfig:
            kw: Dict[str, Any] = {"num_layers": L}
            if cfg.family == "encdec":
                kw["num_encoder_layers"] = L
            return dataclasses.replace(cfg, **kw)

        def measure(c: ArchConfig) -> Dict[str, float]:
            lowered_c, meta_c = lower_cell(c, shape, mesh, cost_pass=True,
                                           variant=var)
            compiled_c = lowered_c.compile()
            scale = meta_c["cost_scale"]
            cost = compiled_c.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0] if cost else {}
            out = {"flops": float(cost.get("flops", 0.0)) * scale,
                   "bytes_accessed":
                       float(cost.get("bytes accessed", 0.0)) * scale}
            coll = collective_bytes_from_hlo(compiled_c.as_text())
            for k, v in coll.items():
                out[f"coll_{k}"] = v * scale
            return out

        m1, m2 = measure(reduced(L1)), measure(reduced(L2))
        L = cfg.num_layers
        ex = {k: m1[k] + (L - L1) * (m2[k] - m1[k]) / (L2 - L1)
              for k in m1}
        rec["cost_pass_s"] = round(time.monotonic() - t2, 2)
        flops = ex["flops"]
        bytes_accessed = ex["bytes_accessed"]
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_accessed,
                       "extrapolated_from": [L1, L2]}
        coll = {k[5:]: v for k, v in ex.items() if k.startswith("coll_")}
        rec["collectives"] = coll

        # cost_analysis on the CPU backend reports per-partition (per-device)
        # numbers for SPMD programs; normalise to GLOBAL totals.
        global_flops = flops * chips
        global_bytes = bytes_accessed * chips
        coll_global = coll["total"] * chips
        terms = roofline_terms(global_flops, global_bytes, coll_global,
                               chips, PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
        mf = model_flops(cfg, shape)
        terms["model_flops"] = mf
        terms["useful_fraction"] = (mf / global_flops) if global_flops else 0.0
        rec["roofline"] = terms
        rec["status"] = "ok"
    except Exception as exc:  # noqa: BLE001 - record the failure, keep going
        rec["status"] = "error"
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc(limit=10)
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} "
                     f"frac={r['roofline_fraction']:.3f} "
                     f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
        print(f"[dryrun] {status.upper():7s} {arch} x {shape_name} x "
              f"{mesh_name}{extra}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="hillclimb variant, e.g. dp_all, sp, ep, "
                         "dp_all+chunk128")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))
    print(f"[dryrun] {len(cells)} cells", flush=True)
    t0 = time.monotonic()
    for a, s, m in cells:
        mesh_name = "multi" if m else "single"
        sfx = "" if args.variant == "baseline" else f"__{args.variant}"
        p = out_dir / f"{a}__{s}__{mesh_name}{sfx}.json"
        if args.skip_existing and p.exists():
            try:
                if json.loads(p.read_text()).get("status") in ("ok",
                                                               "skipped"):
                    print(f"[dryrun] cached  {a} x {s} x {mesh_name}",
                          flush=True)
                    continue
            except Exception:  # noqa: BLE001
                pass
        run_cell(a, s, m, out_dir, variant=args.variant)
    print(f"[dryrun] done in {time.monotonic() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
