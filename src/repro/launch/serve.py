"""Batched serving driver through the graph engine (MUSER analogue, §6).

Requests stream in like MUSER's correlator frames: the logical graph
Scatters a request batch into micro-batches, each micro-batch flows through
prefill -> decode Drops, and a Gather assembles responses.  InMemory Drops
carry the KV caches between prefill and decode exactly like MUSER's
visibility frames ("data of these types needs high I/O bandwidth").

With ``--sessions N`` the same graph shape is served N times through a
resident :class:`~repro.core.manager.EngineManager`: the first session
pays translate+map, every later one is a template-cache hit that only
materializes fresh session state — the paper's "translate once, run
per-observation" manager shape, reported as sessions/s with p50/p99
session latency.

CLI:
  PYTHONPATH=src python -m repro.launch.serve --requests 8 --decode 16
  PYTHONPATH=src python -m repro.launch.serve --sessions 8 --concurrent 4
  PYTHONPATH=src python -m repro.launch.serve --sessions 8 \
      --stats-json results/serve_stats.json   # registry snapshot dump
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_smoke_config
from ..core import (EngineConfig, EngineManager, Pipeline, TelemetryConfig,
                    register_app)
from ..dsl import GraphBuilder
from ..models import model as M
from ..models.common import ArchConfig
from ..train import make_decode_step, make_prefill_step


def _dump_stats(path: str, payload: Dict[str, Any]) -> None:
    """Write the observability dump (--stats-json): the MetricsRegistry
    snapshot plus whatever serving stats the caller collected."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as fh:
        json.dump(payload, fh, indent=2, default=repr)
    print(f"[serve] stats written to {p}")


def run_serving(cfg: ArchConfig, *, num_requests: int = 8,
                microbatch: int = 4, prompt_len: int = 32,
                decode_steps: int = 16, num_nodes: int = 2,
                sessions: int = 1, max_concurrent: int = 4,
                stats_json: Optional[str] = None,
                streaming: bool = False, execution: str = "objects",
                hooks: Any = None) -> Dict[str, Any]:
    """Serve ``num_requests`` prompts through the graph engine.

    ``streaming=True`` switches token delivery to the chunk lane: each
    decode step writes one ``(microbatch, step, tokens)`` chunk onto the
    ``gen`` drop, whose edge into the assembler is streaming — the
    assembler accumulates chunks as they arrive (on either engine) and
    concatenates at batch resolution.  ``hooks`` (ExecHooks) forwards to
    :meth:`Pipeline.execute` for chunk/wave observability.
    """
    assert num_requests % microbatch == 0
    n_micro = num_requests // microbatch
    max_seq = prompt_len + decode_steps

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prefill_step = jax.jit(make_prefill_step(cfg))
    decode_one = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(num_requests, prompt_len)).astype(np.int32)

    @register_app("serve/prefill")
    def prefill_app(inputs, outputs, app):
        (mb,) = app.meta["oid"]
        chunk = jnp.asarray(prompts[mb * microbatch:(mb + 1) * microbatch])
        batch = {"tokens": chunk}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (microbatch, max(prompt_len // cfg.encoder_ratio, 1),
                 cfg.d_model), jnp.float32)
        next_tok, cache = prefill_step(params, batch)
        # grow cache to max_seq for the decode phase
        grown = M.init_cache(cfg, microbatch, max_seq)

        def fill(dst, src):
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src, pad).astype(dst.dtype)
        cache = jax.tree.map(fill, grown, cache)
        for o in outputs:
            o.write({"next": next_tok[:, None], "cache": cache})

    @register_app("serve/decode")
    def decode_app(inputs, outputs, app):
        st = inputs[0].read()
        tok, cache = st["next"], st["cache"]
        toks = [tok]
        for i in range(decode_steps - 1):
            tok, cache = decode_one(params, cache, tok,
                                    jnp.int32(prompt_len + i))
            toks.append(tok)
        for o in outputs:
            o.write(np.asarray(jnp.concatenate(toks, axis=1)))

    @register_app("serve/decode-stream")
    def decode_stream_app(inputs, outputs, app):
        # streaming variant: one chunk per generated token position so
        # the assembler overlaps with generation; chunks are tagged with
        # (microbatch id, step) — assembly order is interleave-proof
        (mb,) = app.meta["oid"]
        st = inputs[0].read()
        tok, cache = st["next"], st["cache"]
        for o in outputs:
            o.write((mb, 0, np.asarray(tok)))
        for i in range(decode_steps - 1):
            tok, cache = decode_one(params, cache, tok,
                                    jnp.int32(prompt_len + i))
            for o in outputs:
                o.write((mb, i + 1, np.asarray(tok)))

    @register_app("serve/assemble")
    def assemble(inputs, outputs, app):
        chunks = [i.read() for i in inputs]
        for o in outputs:
            o.write(np.concatenate(chunks, axis=0))

    def _assemble_finish(inputs, outputs, app):
        per_mb = app.scratch
        mbs = sorted(per_mb)
        rows = [np.concatenate([per_mb[m][s] for s in sorted(per_mb[m])],
                               axis=1) for m in mbs]
        for o in outputs:
            o.write(np.concatenate(rows, axis=0))

    @register_app("serve/assemble-stream", streaming=True,
                  finish=_assemble_finish)
    def assemble_stream(value, app):
        mb, step, tok = value
        app.scratch.setdefault(mb, {})[step] = tok

    g = GraphBuilder("serve")
    g.data("reqs")
    decode_kind = "serve/decode-stream" if streaming else "serve/decode"
    asm_kind = "serve/assemble-stream" if streaming else "serve/assemble"
    with g.scatter("mb", n_micro):
        g.component("prefill", app="serve/prefill", time=0.5)
        g.data("kv", volume=1e6)
        g.component("decode", app=decode_kind, time=1.0)
        g.data("gen")
    with g.gather("all", n_micro):
        g.component("assemble", app=asm_kind, time=0.01)
    g.data("responses")
    g.chain("reqs", "prefill", "kv", "decode", "gen")
    # token delivery: streaming mode rides the chunk lane gen -> assemble
    g.connect("gen", "assemble", streaming=streaming)
    g.chain("assemble", "responses")

    if sessions > 1:
        return _run_sessions(g.graph(), sessions=sessions,
                             num_nodes=num_nodes,
                             max_concurrent=max_concurrent,
                             num_requests=num_requests,
                             decode_steps=decode_steps,
                             stats_json=stats_json)

    telemetry = TelemetryConfig(metrics=True) if stats_json else None
    engine_cfg = EngineConfig(num_nodes=num_nodes, workers_per_node=2,
                              execution=execution, telemetry=telemetry)
    with Pipeline(engine_cfg) as p:
        p.translate(g.graph())
        p.deploy()
        t0 = time.monotonic()
        rep = p.execute(inputs={"reqs": num_requests}, timeout=3600,
                        hooks=hooks)
        wall = time.monotonic() - t0
        assert rep.ok, rep.errors[:3]
        out = (p.session.read("responses") if execution == "compiled"
               else p.session.drops["responses"].read())
        if stats_json:
            _dump_stats(stats_json, {
                "metrics": p.metrics.snapshot() if p.metrics else {},
                "spans": [{"name": s.name, "seconds": s.duration}
                          for s in p.spans],
                "wall_s": wall,
            })
    gen_tokens = num_requests * decode_steps
    result = {
        "responses_shape": tuple(out.shape),
        "wall_s": wall,
        "gen_tokens_per_s": gen_tokens / wall,
        "drops": sum(rep.status_counts.values()),
    }
    print(f"[serve] {num_requests} requests x {decode_steps} tokens in "
          f"{wall:.2f}s ({result['gen_tokens_per_s']:.1f} tok/s), "
          f"responses {out.shape}")
    return result


def _run_sessions(lg, *, sessions: int, num_nodes: int,
                  max_concurrent: int, num_requests: int,
                  decode_steps: int,
                  stats_json: Optional[str] = None) -> Dict[str, Any]:
    """Serve one graph shape ``sessions`` times through a resident
    EngineManager: one cold translate+map, then cache-hit sessions that
    share node pools and run up to ``max_concurrent`` at once."""
    telemetry = TelemetryConfig(metrics=True) if stats_json else None
    with EngineManager(num_nodes=num_nodes, workers_per_node=2,
                       max_concurrent=max_concurrent,
                       max_pending=sessions,
                       telemetry=telemetry) as mgr:
        t0 = time.monotonic()
        tickets = [mgr.submit(lg, inputs={"reqs": num_requests},
                              timeout=3600, block=True)
                   for _ in range(sessions)]
        reports = [t.result() for t in tickets]
        wall = time.monotonic() - t0
        for rep in reports:
            assert rep.ok, rep.errors[:3]
        out = tickets[-1].session.read("responses")
        lats = sorted(t.latency for t in tickets)
        stats = mgr.stats()
        if stats_json:
            _dump_stats(stats_json, stats)
    gen_tokens = sessions * num_requests * decode_steps
    result = {
        "responses_shape": tuple(out.shape),
        "sessions": sessions,
        "wall_s": wall,
        "sessions_per_s": sessions / wall,
        "gen_tokens_per_s": gen_tokens / wall,
        "p50_session_s": lats[len(lats) // 2],
        "p99_session_s": lats[min(len(lats) - 1,
                                  int(0.99 * (len(lats) - 1)))],
        "template_hits": stats["templates"]["hits"],
        "drops": sum(reports[0].status_counts.values()),
    }
    print(f"[serve] {sessions} sessions x {num_requests} requests in "
          f"{wall:.2f}s ({result['sessions_per_s']:.2f} sessions/s, "
          f"{result['gen_tokens_per_s']:.1f} tok/s, "
          f"p50 {result['p50_session_s']:.3f}s / "
          f"p99 {result['p99_session_s']:.3f}s, "
          f"{result['template_hits']} cache hits)")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--decode", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=1,
                    help="serve the shape N times via a resident "
                         "EngineManager (template-cache hits after the "
                         "first)")
    ap.add_argument("--concurrent", type=int, default=4,
                    help="max concurrent sessions when --sessions > 1")
    ap.add_argument("--stats-json", type=str, default=None,
                    help="enable the metrics registry and dump its "
                         "snapshot (plus serving stats) to this path")
    ap.add_argument("--streaming", action="store_true",
                    help="stream decode tokens chunk-by-chunk into the "
                         "assembler (docs/streaming.md)")
    ap.add_argument("--execution", choices=("objects", "compiled"),
                    default="objects",
                    help="execution substrate for the single-session "
                         "path (--sessions 1)")
    args = ap.parse_args()
    cfg = get_smoke_config("codeqwen15_7b")
    run_serving(cfg, num_requests=args.requests,
                microbatch=args.microbatch, prompt_len=args.prompt,
                decode_steps=args.decode, sessions=args.sessions,
                max_concurrent=args.concurrent,
                stats_json=args.stats_json, streaming=args.streaming,
                execution=args.execution)


if __name__ == "__main__":
    main()
