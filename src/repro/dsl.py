"""Pythonic builder for Logical Graph Templates.

The paper's Logical Graph Editor is a web UI; the programmatic equivalent is
this small DSL.  It builds ``LogicalGraphTemplate`` objects::

    g = GraphBuilder("imaging")
    with g.scatter("by_time", 4):
        ms = g.data("split_ms", volume=1e9)
        with g.scatter("by_chan", 8):
            d = g.data("chan_ms", volume=1e8)
            g.component("degrid", app="identity", time=2.0)
            ...
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

from .core.constructs import Construct, Kind
from .core.logical import LogicalGraph, LogicalGraphTemplate


class GraphBuilder:
    def __init__(self, name: str, version: str = "0",
                 parameters: Optional[Dict[str, Any]] = None) -> None:
        self.lgt = LogicalGraphTemplate(name=name, version=version,
                                        parameters=dict(parameters or {}))
        self._stack: List[str] = []

    # -- containers ---------------------------------------------------------
    @contextmanager
    def scatter(self, name: str, num_of_copies: int = 1,
                **params: Any) -> Iterator[Construct]:
        c = self.lgt.add(Construct(
            name=name, kind=Kind.SCATTER, num_of_copies=num_of_copies,
            parent=self._parent(), params=params))
        self._stack.append(name)
        try:
            yield c
        finally:
            self._stack.pop()

    @contextmanager
    def gather(self, name: str, num_of_inputs: int = 1,
               **params: Any) -> Iterator[Construct]:
        c = self.lgt.add(Construct(
            name=name, kind=Kind.GATHER, num_of_inputs=num_of_inputs,
            parent=self._parent(), params=params))
        self._stack.append(name)
        try:
            yield c
        finally:
            self._stack.pop()

    @contextmanager
    def group_by(self, name: str, **params: Any) -> Iterator[Construct]:
        c = self.lgt.add(Construct(
            name=name, kind=Kind.GROUPBY, parent=self._parent(),
            params=params))
        self._stack.append(name)
        try:
            yield c
        finally:
            self._stack.pop()

    @contextmanager
    def loop(self, name: str, num_of_iterations: int = 1,
             **params: Any) -> Iterator[Construct]:
        c = self.lgt.add(Construct(
            name=name, kind=Kind.LOOP,
            num_of_iterations=num_of_iterations,
            parent=self._parent(), params=params))
        self._stack.append(name)
        try:
            yield c
        finally:
            self._stack.pop()

    # -- leaves ---------------------------------------------------------------
    def data(self, name: str, volume: float = 0.0,
             payload: str = "memory", loop_entry: bool = False,
             loop_exit: bool = False, carries: Optional[str] = None,
             **params: Any) -> Construct:
        if carries:
            params["carries"] = carries
        return self.lgt.add(Construct(
            name=name, kind=Kind.DATA, data_volume=volume,
            payload_kind=payload, parent=self._parent(),
            loop_entry=loop_entry, loop_exit=loop_exit, params=params))

    def component(self, name: str, app: str, time: float = 0.0,
                  error_threshold: float = 0.0,
                  **params: Any) -> Construct:
        return self.lgt.add(Construct(
            name=name, kind=Kind.COMPONENT, app=app, execution_time=time,
            error_threshold=error_threshold, parent=self._parent(),
            params=params))

    # -- wiring -------------------------------------------------------------------
    def connect(self, src: str, dst: str, streaming: bool = False) -> None:
        self.lgt.connect(src, dst, streaming)

    def chain(self, *names: str) -> None:
        for a, b in zip(names, names[1:]):
            self.connect(a, b)

    def _parent(self) -> Optional[str]:
        return self._stack[-1] if self._stack else None

    # -- finish ----------------------------------------------------------------------
    def template(self) -> LogicalGraphTemplate:
        self.lgt.validate()
        return self.lgt

    def graph(self, **values: Any) -> LogicalGraph:
        return self.lgt.parametrise(**values)
