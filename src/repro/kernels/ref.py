"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  logit_cap: float = 0.0) -> jax.Array:
    """q: (B,Hq,Sq,D); k/v: (B,Hkv,Sk,D) -> (B,Hq,Sq,D).  GQA by head map."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(d)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_reference(x: jax.Array, dt: jax.Array, a: jax.Array,
                  b: jax.Array, c: jax.Array,
                  initial_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (exact) SSD recurrence.

    x: (B,H,S,P); dt: (B,H,S); a: (H,); b/c: (B,H,S,N).
    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t . h_t
    Returns (y: (B,H,S,P), final_state: (B,H,N,P)).
    """
    B, H, S, P = x.shape
    N = b.shape[-1]
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((B, H, N, P), jnp.float32))

    def step(h, t):
        dA = jnp.exp(dt[:, :, t] * a[None, :])          # (B,H)
        upd = jnp.einsum("bhn,bhp->bhnp", b[:, :, t],
                         x[:, :, t] * dt[:, :, t][..., None])
        h = h * dA[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", c[:, :, t], h)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 2)                          # (B,H,S,P)
    return y.astype(x.dtype), h.astype(x.dtype)
