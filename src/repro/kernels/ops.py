"""jit'd dispatch wrappers: model-layout in, kernel-layout inside.

``flash_attention`` / ``ssd_scan`` are what the model layers call when
``use_kernel=True``.  On CPU (this container) the Pallas body executes in
interpret mode for validation; on TPU the same ``pallas_call`` lowers to
Mosaic.  The jnp reference path (`repro.kernels.ref`) is the oracle and the
default dry-run path (the dry-run measures the XLA program, and Mosaic
kernels are opaque to HLO cost analysis anyway).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_bhsd
from .ssd_scan import ssd_scan_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Model layout (B,S,H,D) in/out; kernel runs (B,H,S,D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               logit_cap=logit_cap, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())
    return jnp.swapaxes(out, 1, 2)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int,
             initial_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Model layout x: (B,S,H,P), b/c: (B,S,G,N) -> (y, final_state).

    Groups are broadcast to heads; initial_state must be None (the kernel
    starts from zero state — prefill semantics).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    xt = jnp.transpose(x, (0, 2, 1, 3))              # (B,H,S,P)
    dtt = jnp.transpose(dt, (0, 2, 1))               # (B,H,S)
    bt = jnp.repeat(jnp.transpose(b, (0, 2, 1, 3)), rep, axis=1)
    ct = jnp.repeat(jnp.transpose(c, (0, 2, 1, 3)), rep, axis=1)
    if initial_state is not None:
        raise NotImplementedError(
            "kernel path starts from zero state; pass initial_state only "
            "on the jnp path")
    y, state = ssd_scan_bhsd(xt, dtt, a, bt, ct, chunk,
                             interpret=not _on_tpu())
    y = jnp.transpose(y, (0, 2, 1, 3))               # (B,S,H,P)
    # model layout state: (B,H,N,P)
    return y, state


# convenience: oracle access under one namespace
mha_reference = ref.mha_reference
ssd_reference = ref.ssd_reference
