"""Mamba2 SSD chunk kernel for TPU (Pallas).

One grid step processes one (batch, head, chunk) tile entirely in VMEM:
the chunk-local "attention-like" quadratic term, the inter-chunk
contribution from the carried state, and the state update.  The chunk axis
is the sequential (arbitrary) grid dimension; the running state
(N x P floats) lives in VMEM scratch — the TPU-native shape of the SSD
recurrence: all heavy ops are (Q x Q)/(Q x N)/(N x P) MXU matmuls, and HBM
traffic is exactly one read of x/dt/B/C and one write of y per token.

Validated against ``ref.ssd_reference`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, st_ref,
                state_scr, *, num_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0]                                    # scalar decay rate (f32)
    x = x_ref[0, 0].astype(jnp.float32)             # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)           # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)             # (Q, N)

    dA = dt * a                                     # (Q,) log-decays
    cum = jnp.cumsum(dA)                            # (Q,)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) (i >= j), att = (C B^T) * L * dt_j
    li = cum[:, None]
    lj = cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(li - lj), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    att = scores * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C * exp(cum)) @ state
    state = state_scr[...]                          # (N, P)
    y += jax.lax.dot_general(c * jnp.exp(cum)[:, None], state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: state' = exp(cum_Q) * state + B^T @ (x * dt * decay_to_end)
    decay_end = jnp.exp(cum[-1] - cum)              # (Q,)
    wx = x * (dt * decay_end)[:, None]
    state_new = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        b, wx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = state_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        st_ref[0, 0] = state_new.astype(st_ref.dtype)


def ssd_scan_bhsd(x: jax.Array, dt: jax.Array, a: jax.Array,
                  b: jax.Array, c: jax.Array, chunk: int, *,
                  interpret: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,H,S,P); dt: (B,H,S); a: (H,); b/c: (B,H,S,N) (groups
    pre-broadcast to heads).  Returns (y: (B,H,S,P), state: (B,H,N,P))."""
    B, H, S, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, num_chunks=nc, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), x, dt, b, c)
    return y, state
