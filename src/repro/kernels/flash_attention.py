"""Fused flash attention for TPU (Pallas): GQA + causal + window + softcap.

TPU adaptation notes (DESIGN.md §2): the online-softmax accumulation runs in
VMEM scratch across the sequential last grid dimension (kv blocks), with
(128 x 128) MXU-aligned tiles.  Block sizes are BlockSpec parameters, so the
working set (q tile + kv tile + accumulators = BQ*D + 2*BK*D + 2*BQ*BK
floats) is tuned to fit the ~16 MiB VMEM budget with D=128 head dims.

Layout: (batch, heads, seq, head_dim).  GQA maps query head h to kv head
h // (Hq // Hkv) in the kv index_map — no KV duplication in HBM.
Validated against ``ref.mha_reference`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  logit_cap: float, num_k_blocks: int, block_q: int,
                  block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)

    rows = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = cols < seq_k                           # padding guard
    mask &= rows < seq_q
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         logit_cap: float = 0.0,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, num_k_blocks=nk, block_q=block_q,
        block_k=block_k, seq_q=sq, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :sq, :]
    return out
