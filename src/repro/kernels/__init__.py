"""Pallas TPU kernels for the two compute hot spots.

flash_attention: fused GQA attention (causal/window/softcap).
ssd_scan: Mamba2 SSD chunk scan with VMEM-resident state.
ops: jit'd wrappers (kernel on TPU, interpret-mode on CPU); ref: jnp oracles.
"""
from . import ops, ref
from .flash_attention import flash_attention_bhsd
from .ssd_scan import ssd_scan_bhsd

__all__ = ["flash_attention_bhsd", "ops", "ref", "ssd_scan_bhsd"]
