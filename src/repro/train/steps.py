"""Step functions: microbatched train step, prefill and decode serve steps.

``make_train_step`` builds the jit-able update:

  * grad accumulation over ``num_microbatches`` via ``lax.scan`` — the
    per-microbatch gradient psum overlaps the next microbatch's compute
    (XLA schedules the DP all-reduce concurrently with the scan body),
  * remat (``jax.checkpoint``) inside each layer,
  * global-norm clip + AdamW + cosine LR,
  * optional int8 error-feedback gradient compression before the DP
    reduction (1000+-node bandwidth trick; off by default).

All functions are pure — they are the payloads of Application Drops.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.common import ArchConfig
from ..optim import (AdamWState, adamw_init, adamw_update,
                     clip_by_global_norm, cosine_schedule,
                     decompress_gradients, error_feedback_update)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residual: Optional[Any]   # error-feedback residual (compression on)


def train_state_init(cfg: ArchConfig, key: jax.Array,
                     compress: bool = False) -> TrainState:
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    residual = (jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)
        if compress else None)
    return TrainState(params, opt, residual)


def make_train_step(cfg: ArchConfig, *, num_microbatches: int = 1,
                    peak_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 1000, max_grad_norm: float = 1.0,
                    compress: bool = False, use_kernel: bool = False,
                    remat: bool = True) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        total, parts = M.forward_train(params, cfg, mb,
                                       use_kernel=use_kernel, remat=remat)
        return total, parts

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params = state.params
        if num_microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % num_microbatches == 0, (b, num_microbatches)
                return x.reshape(num_microbatches, b // num_microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = lsum / num_microbatches
        else:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        residual = state.residual
        if compress:
            assert residual is not None
            qs, scales, residual = error_feedback_update(grads, residual)
            grads = decompress_gradients(qs, scales)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(state.opt.step, peak_lr=peak_lr,
                             warmup_steps=warmup_steps,
                             total_steps=total_steps)
        new_params, new_opt = adamw_update(params, grads, state.opt, lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt.step}
        return TrainState(new_params, new_opt, residual), metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, *, use_kernel: bool = False
                      ) -> Callable:
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, cfg, batch, use_kernel=use_kernel)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), cache
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode_one(params, cache, tokens, pos):
        logits, cache = M.decode_step(params, cfg, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache
    return decode_one


def decode_fn(cfg: ArchConfig, params, cache, first_token, start_pos: int,
              steps: int):
    """Greedy multi-token decode loop (host-side driver for examples)."""
    step = jax.jit(make_decode_step(cfg))
    toks = [first_token]
    tok = first_token
    for i in range(steps):
        tok, cache = step(params, cache, tok, jnp.int32(start_pos + i))
        toks.append(tok)
    return jnp.concatenate(toks, axis=1), cache
