from .steps import (TrainState, decode_fn, make_decode_step,
                    make_prefill_step, make_train_step, train_state_init)

__all__ = ["TrainState", "decode_fn", "make_decode_step",
           "make_prefill_step", "make_train_step", "train_state_init"]
