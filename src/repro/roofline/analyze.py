"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` provides FLOPs/bytes; collective bytes are parsed out of
the (post-SPMD-partitioning) HLO text by summing operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

from ..models.common import ArchConfig, ShapeConfig

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  "bf16[16,256,512]{2,1,0}"  or "f32[128]"
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
# lines like:
#   %all-reduce.1 = bf16[...]{...} all-reduce(...)
#   %ar-start = (f32[2048,8512]{1,0}, f32[2048,8512]{1,0}) all-reduce-start(
# (async "-start" ops have tuple (operand, result) shapes WITH spaces)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind.

    ``-done`` ops are skipped (their ``-start`` is counted once).  Async
    ``-start`` ops carry tuple (operand, result) shapes — halve them so both
    sync and async forms count result bytes once.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        b = _shape_bytes(shape_str)
        if shape_str.startswith("(") and suffix == "-start":
            b //= 2
        out[kind] += b
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens.

    For decode shapes, D = batch tokens (one step).  Train triples the
    forward (fwd+bwd); 6ND already assumes that for train; for inference
    we use 2ND.
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * n * tokens


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   peak_flops: float, hbm_bw: float, ici_bw: float
                   ) -> Dict[str, float]:
    compute_s = flops / (chips * peak_flops)
    memory_s = bytes_accessed / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=lambda k: terms[k])
    terms["dominant"] = dom  # type: ignore[assignment]
    bound = max(compute_s, memory_s, collective_s)
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms
