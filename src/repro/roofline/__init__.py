from .analyze import (collective_bytes_from_hlo, model_flops,
                      roofline_terms)

__all__ = ["collective_bytes_from_hlo", "model_flops", "roofline_terms"]
