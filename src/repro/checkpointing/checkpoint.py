"""Sharded model/optimizer checkpointing with async writer.

Format: one ``.npz`` per pytree leaf-group shard + a JSON manifest holding
the treedef, shapes, dtypes and step.  Atomic via write-to-tmp + rename.
The async path hands a host copy to a writer thread so the training loop
never blocks on disk (the framework-level analogue of the paper's Drop
persistence, §4: "manage Drops through persistent check-pointing,
versioning and recovery after restart").
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    shards: int = 1) -> str:
    """Blocking save.  ``shards``: split leaves round-robin into N files."""
    d = Path(directory)
    tmp = d / f".tmp-{step}-{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)
    items, _ = _flatten(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": shards}
    buckets: List[Dict[str, np.ndarray]] = [dict() for _ in range(shards)]
    for i, (name, leaf) in enumerate(items):
        arr = np.asarray(leaf)
        key = f"leaf{i}"
        buckets[i % shards][key] = arr
        manifest["leaves"].append(
            {"name": name, "key": key, "shard": i % shards,
             "shape": list(arr.shape), "dtype": str(arr.dtype)})
    for s, bucket in enumerate(buckets):
        np.savez(tmp / f"shard{s}.npz", **bucket)
    with open(tmp / "manifest.json", "w") as fh:
        json.dump(manifest, fh)
    final = d / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, tree_like: Any,
                    step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure of ``tree_like`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = Path(directory) / f"step_{step:08d}"
    with open(d / "manifest.json") as fh:
        manifest = json.load(fh)
    shards = [np.load(d / f"shard{s}.npz")
              for s in range(manifest["shards"])]
    items, treedef = _flatten(tree_like)
    assert len(items) == len(manifest["leaves"]), \
        (len(items), len(manifest["leaves"]))
    leaves = []
    for (name, like), meta in zip(items, manifest["leaves"]):
        arr = shards[meta["shard"]][meta["key"]]
        assert list(np.shape(like)) == meta["shape"], \
            f"{name}: {np.shape(like)} != {meta['shape']}"
        leaves.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Async, bounded-keep checkpointer."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def save_async(self, step: int, tree: Any) -> None:
        host = jax.tree.map(np.asarray, tree)   # device->host copy now
        self.wait()

        def work() -> None:
            save_checkpoint(self.directory, step, host)
            self.saved_steps.append(step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        d = Path(self.directory)
        steps = sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                       if p.is_dir() and p.name.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)

    def restore_latest(self, tree_like: Any) -> Optional[Tuple[int, Any]]:
        self.wait()
        try:
            return load_checkpoint(self.directory, tree_like)
        except FileNotFoundError:
            return None
