"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  One shared transformer block (attention + MLP,
same parameters) applied every 6 Mamba2 layers — zamba2's parameter-sharing
trick, which keeps param count low while restoring attention's global mixing.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64,
    shared_attn_period=6,
    activation="gelu", tie_embeddings=True,
    sharding_strategy="dp", subquadratic=True,
    notes="runs long_500k: SSM state is O(1); the 9 shared-attn cache "
          "entries are the only seq-length-scaling decode state",
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16,
    shared_attn_period=2,
    activation="gelu", tie_embeddings=True, dtype="float32",
)
