"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(name)`` returns the exact published config;
``get_smoke_config(name)`` returns a tiny same-family variant for CPU tests;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for the
dry-run (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.common import SHAPES, ArchConfig, ShapeConfig

ARCH_NAMES = [
    "whisper_large_v3",
    "grok_1_314b",
    "granite_moe_3b_a800m",
    "nemotron_4_15b",
    "gemma2_27b",
    "codeqwen15_7b",
    "command_r_plus_104b",
    "zamba2_2_7b",
    "mamba2_1_3b",
    "chameleon_34b",
]

# public ids use dashes (``--arch whisper-large-v3``)
def _mod_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f".{_mod_name(name)}", __package__)
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f".{_mod_name(name)}", __package__)
    return mod.SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# Cell applicability (DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """Returns None if runnable, else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("needs sub-quadratic attention; "
                f"{cfg.name} is full-attention (see DESIGN.md)")
    return None


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — dry-run deliverable e.2)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                for_step: Optional[str] = None) -> Dict[str, Any]:
    """Abstract inputs for the given (arch, shape) cell.

    train/prefill: {tokens, labels?, frames?}
    decode:        {tokens(B,1), pos, cache}
    """
    from ..models import model as M
    B, S = shape.global_batch, shape.seq_len
    kind = for_step or shape.kind
    i32 = jnp.int32

    if kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "encdec":
            enc_len = max(S // cfg.encoder_ratio, 1)
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs

    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }


def abstract_params(cfg: ArchConfig) -> Any:
    """Parameter ShapeDtypeStructs without allocating anything."""
    from ..models import model as M
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
