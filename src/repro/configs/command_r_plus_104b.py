"""command-r-plus-104b [dense] — GQA, no-bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    use_bias=False, activation="swiglu", tie_embeddings=True,
    sharding_strategy="fsdp",
    notes="largest dense assigned arch; kv=8 < tp16 -> replicated baseline",
)

SMOKE = ArchConfig(
    name="command-r-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256,
    use_bias=False, activation="swiglu", tie_embeddings=True,
    dtype="float32",
)
