"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    num_experts=40, top_k=8,
    activation="swiglu",
    sharding_strategy="dp",
    notes="fine-grained MoE (40e top-8); heads 24 and kv 8 don't divide "
          "tp16 -> attention replicated across model axis (baseline)",
)

SMOKE = ArchConfig(
    name="granite-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    num_experts=8, top_k=4,
    activation="swiglu", dtype="float32",
)
