"""grok-1-314b [moe] — 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2
[hf:xai-org/grok-1; unverified].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    num_experts=8, top_k=2,
    activation="geglu",   # gated GeLU: 3 matmuls/expert -> ~314B total
    sharding_strategy="fsdp",
    notes="8-expert top-2 MoE; GQA kv=8 (< tp16 -> replicated baseline)",
)

SMOKE = ArchConfig(
    name="grok-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    num_experts=4, top_k=2,
    activation="geglu", dtype="float32",
)
