"""The paper's own workload: the CHILES imaging pipeline as an LGT (§5).

This is the versioned Logical Graph Template a staff astronomer would
release (paper stage 2); `examples/chiles_pipeline.py` binds its parameters
(stage 3) and executes it.  Kept as a config so the paper's own
"architecture" sits next to the 10 assigned LM configs.
"""


def build_template(days: int = 4, bands: int = 6):
    from ..dsl import GraphBuilder
    g = GraphBuilder("chiles-imaging", version="1",
                     parameters={"days": days, "bands": bands})
    g.data("obs")
    with g.scatter("day", days) as sc:
        sc.params["$num_of_copies"] = "days"
        with g.scatter("band", bands) as sb:
            sb.params["$num_of_copies"] = "bands"
            g.component("split", app="chiles_split", time=0.01)
            g.data("chunk", volume=2e8)
            g.component("subtract", app="chiles_subtract", time=0.01)
            g.data("sub", volume=2e8)
    with g.group_by("byband"):
        g.component("clean", app="chiles_clean", time=0.05)
        g.data("img", volume=4e7, payload="file")
    with g.gather("cube", bands) as ga:
        ga.params["$num_of_inputs"] = "bands"
        g.component("concat", app="chiles_concat", time=0.01)
    g.data("final", payload="file")
    g.chain("obs", "split", "chunk", "subtract", "sub", "clean", "img",
            "concat", "final")
    return g.template()
