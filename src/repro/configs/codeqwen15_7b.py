"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA, qkv bias, swiglu).

32L d_model=4096 32H (GQA kv=32 = MHA) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    use_bias=True, activation="swiglu",
    rope_theta=1000000.0,
    sharding_strategy="dp",
    notes="qwen1.5 architecture: MHA with qkv bias, rope theta 1e6",
)

SMOKE = ArchConfig(
    name="codeqwen-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    use_bias=True, activation="swiglu", dtype="float32",
)
