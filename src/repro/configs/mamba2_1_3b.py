"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
    tie_embeddings=True,
    sharding_strategy="dp", subquadratic=True,
    notes="pure SSM; runs long_500k with O(1) state",
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16,
    tie_embeddings=True, dtype="float32",
)
