"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118; hf].  Local window 4096 on even layers; attention logit
softcap 50.0; final logit softcap 30.0.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    local_window=4096, alternate_local_global=True,
    attn_softcap=50.0, final_softcap=30.0,
    activation="swiglu", tie_embeddings=True,
    sharding_strategy="fsdp",
    notes="half the layers are global full attention -> NOT subquadratic; "
          "long_500k skipped per assignment rule",
)

SMOKE = ArchConfig(
    name="gemma2-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    local_window=32, alternate_local_global=True,
    attn_softcap=50.0, final_softcap=30.0,
    activation="swiglu", tie_embeddings=True, dtype="float32",
)
