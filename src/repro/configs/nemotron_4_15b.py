"""nemotron-4-15b [dense] — GQA, squared-ReLU.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000
[arXiv:2402.16819; unverified].
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    activation="relu2",
    sharding_strategy="fsdp",
    notes="squared-ReLU MLP (2 matmuls, not swiglu's 3)",
)

SMOKE = ArchConfig(
    name="nemotron-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    activation="relu2", dtype="float32",
)
