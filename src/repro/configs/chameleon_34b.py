"""chameleon-34b [vlm] — early-fusion, VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified].  Early fusion means image patches are VQ
codes in the SAME token stream — the modality frontend (VQ-GAN tokenizer) is
a stub; ``input_specs`` provides token ids that already interleave text and
image codes, per the assignment's [vlm] rule.  qk-norm per chameleon.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, activation="swiglu",
    sharding_strategy="fsdp",
    notes="decoder-only over fused text+VQ-image ids; vocab 65536 = "
          "text + image codebook",
)

SMOKE = ArchConfig(
    name="chameleon-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    qk_norm=True, activation="swiglu", dtype="float32",
)
