"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB.

32L d_model=1280 20H (GQA kv=20, i.e. MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified].  The audio frontend (2x conv1d over
log-mel spectrogram) is a stub: ``input_specs`` provides precomputed frame
embeddings (B, seq/8, d_model), per the assignment's [audio] rule.
"""
from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, num_encoder_layers=32,
    d_model=1280, num_heads=20, num_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    activation="gelu", use_bias=True, tie_embeddings=True,
    encoder_ratio=8, sharding_strategy="dp",
    notes="encoder-decoder; sinusoidal positions; audio frontend stubbed",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    num_layers=2, num_encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    activation="gelu", use_bias=True, tie_embeddings=True,
    encoder_ratio=4, dtype="float32",
)
