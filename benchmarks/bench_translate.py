"""Translation throughput: unroll + partition vs logical-graph width (§3.4).

The paper streams JSON and unrolls logical graphs into millions of drops.
This benchmark compares the two translate paths:

* **dict** — the seed path: dict-of-``DropSpec`` + per-edge Python hashing
  (``unroll_dict`` + the simulation-validated ``min_time``),
* **csr**  — the array path: vectorized unroll straight into CSR arrays
  (``CompiledPGT``) + the union-find/critical-path ``min_time``,

reporting drops/second for each, plus a million-drop tier that only the
array path can reach (``--drops 1000000``).

Usage:
  python benchmarks/bench_translate.py              # full comparison suite
  python benchmarks/bench_translate.py --width 10000  # CSR smoke tier only
  python benchmarks/bench_translate.py --drops 2000000
"""
from __future__ import annotations

import argparse
import json
import resource
import time
from pathlib import Path
from typing import List, Tuple


def peak_rss_mb() -> float:
    """Cumulative peak RSS in MB (``ru_maxrss`` is KB on Linux); stamped
    after each stage of the big tiers (report-only, never gated)."""
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                 / 1024.0, 1)

from repro.core import min_time, unroll, unroll_dict
from repro.core.graph_io import load_pgt, save_pgt
from repro.dsl import GraphBuilder

# drops per unit width in make_lg (src + width * (depth apps + depth data))
DROPS_PER_WIDTH = 6

RESULTS_PATH = Path(__file__).resolve().parents[1] / "results" / \
    "bench_translate.json"

# scaled-down merge-trial budget for the dict path at the 50k-width
# comparison tier: the seed benchmark used max_trials=500 at width <= 2000;
# each trial re-simulates the full graph, which at 300k drops costs ~1s, so
# 500 trials would take ~10 minutes.  30 trials keeps the bench honest and
# finishable; the reported drops/s is correspondingly *optimistic* for dict.
DICT_MIN_TIME_TRIALS = 30


def make_lg(width: int, depth: int = 3):
    g = GraphBuilder(f"tr{width}")
    g.data("src")
    with g.scatter("sc", width):
        for i in range(depth):
            g.component(f"w{i}", app="noop", time=0.001)
            g.data(f"d{i}", volume=1e6)
    g.connect("src", "w0")
    for i in range(depth):
        g.connect(f"w{i}", f"d{i}")
        if i + 1 < depth:
            g.connect(f"d{i}", f"w{i+1}")
    return g.graph()


def make_loop_lg(iters: int, width: int):
    """CHILES-style self-cal shape: a carried value drives a scattered
    compute stage each iteration (~``2*width + 2`` drops/iteration)."""
    g = GraphBuilder(f"loop{iters}x{width}")
    g.data("init", volume=1e5)
    g.component("seed", app="identity", time=0.001)
    with g.loop("lp", iters):
        g.data("x", loop_entry=True)
        with g.scatter("sc", width):
            g.component("deg", app="noop", time=0.002)
            g.data("vis", volume=1e6)
        g.component("cal", app="noop", time=0.004)
        g.data("y", loop_exit=True, carries="x", volume=1e5)
    g.component("fin", app="identity", time=0.001)
    g.data("img")
    g.chain("init", "seed", "x", "deg", "vis", "cal", "y")
    g.chain("y", "fin", "img")
    return g.graph()


Row = Tuple[str, float, str]


def _unroll_rows(widths=(1000, 10000, 50000)) -> List[Row]:
    rows: List[Row] = []
    for width in widths:
        lg = make_lg(width)
        t0 = time.monotonic()
        old = unroll_dict(lg)
        t_dict = time.monotonic() - t0
        n = len(old)
        del old
        t1 = time.monotonic()
        new = unroll(lg)
        t_csr = time.monotonic() - t1
        assert len(new) == n
        rows.append((f"unroll_dict_drops_per_s[n={n}]", n / t_dict,
                     f"total_s={t_dict:.3f}"))
        rows.append((f"unroll_csr_drops_per_s[n={n}]", n / t_csr,
                     f"total_s={t_csr:.3f};speedup={t_dict / t_csr:.1f}x"))
    return rows


def _translate_rows(width: int = 50000) -> List[Row]:
    """unroll + min_time, old vs new, at the seed path's width ceiling."""
    rows: List[Row] = []
    lg = make_lg(width)

    t0 = time.monotonic()
    old = unroll_dict(lg)
    min_time(old, dop=8, max_trials=DICT_MIN_TIME_TRIALS)
    t_dict = time.monotonic() - t0
    n = len(old)
    del old
    rows.append((f"translate_dict_drops_per_s[w={width};n={n}]", n / t_dict,
                 f"total_s={t_dict:.3f};max_trials={DICT_MIN_TIME_TRIALS}"))

    t1 = time.monotonic()
    new = unroll(lg)
    res = min_time(new, dop=8)
    t_csr = time.monotonic() - t1
    rows.append((f"translate_csr_drops_per_s[w={width};n={n}]", n / t_csr,
                 f"total_s={t_csr:.3f};partitions={res.num_partitions};"
                 f"speedup={t_dict / t_csr:.1f}x"))
    return rows


def _million_row(target_drops: int = 1_000_000) -> List[Row]:
    """The paper's regime: a million-drop unroll + min_time partition."""
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    lg = make_lg(width)
    t0 = time.monotonic()
    pgt = unroll(lg)
    t_unroll = time.monotonic() - t0
    rss_unroll = peak_rss_mb()
    n = len(pgt)
    t1 = time.monotonic()
    res = min_time(pgt, dop=8)
    t_total = time.monotonic() - t0
    return [(f"translate_csr_drops_per_s[n={n}]", n / t_total,
             f"unroll_s={t_unroll:.3f};partition_s={time.monotonic()-t1:.3f};"
             f"partitions={res.num_partitions};"
             f"makespan={res.makespan:.4f};"
             f"rss_mb_unroll={rss_unroll};rss_mb_partition={peak_rss_mb()}")]


def _loop_rows(iters: int = 100, drops_per_iter: int = 10_000,
               compare_dict: bool = True) -> List[Row]:
    """Loop-carried tier: iteration aliasing through the array path.

    Before PR 5 loop graphs bypassed the vectorized unroll entirely
    (per-instance ``unroll_dict`` fallback, ~28x slower); the dict
    comparison runs at a small size to keep the tier finishable and
    reports the measured gap."""
    rows: List[Row] = []
    if compare_dict:
        small_iters, small_width = 20, 250
        lg = make_loop_lg(small_iters, small_width)
        t0 = time.monotonic()
        old = unroll_dict(lg)
        t_dict = time.monotonic() - t0
        n_small = len(old)
        del old
        t1 = time.monotonic()
        new = unroll(lg)
        t_csr = time.monotonic() - t1
        assert len(new) == n_small
        rows.append((
            f"unroll_loop_csr_drops_per_s[iters={small_iters};n={n_small}]",
            n_small / t_csr,
            f"total_s={t_csr:.3f};dict_s={t_dict:.3f};"
            f"speedup={t_dict / t_csr:.1f}x"))

    width = max((drops_per_iter - 2) // 2, 1)
    lg = make_loop_lg(iters, width)
    t0 = time.monotonic()
    pgt = unroll(lg)
    t_unroll = time.monotonic() - t0
    n = len(pgt)
    res = min_time(pgt, dop=8)
    t_total = time.monotonic() - t0
    rows.append((
        f"translate_loop_drops_per_s[iters={iters};n={n}]", n / t_total,
        f"unroll_s={t_unroll:.3f};total_s={t_total:.3f};"
        f"partitions={res.num_partitions};makespan={res.makespan:.4f}"))
    return rows


def _io_rows(width: int = 10000) -> List[Row]:
    # streaming (de)serialisation throughput (paper §3.7 ijson experiment)
    pgt = unroll(make_lg(width))
    import os
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.jsonl.gz")
        t0 = time.monotonic()
        save_pgt(pgt, path)
        t_save = time.monotonic() - t0
        t1 = time.monotonic()
        load_pgt(path)
        t_load = time.monotonic() - t1
    return [
        (f"pgt_save_us_per_drop[n={len(pgt)}]",
         1e6 * t_save / len(pgt), f"total_s={t_save:.3f}"),
        (f"pgt_load_us_per_drop[n={len(pgt)}]",
         1e6 * t_load / len(pgt), f"total_s={t_load:.3f}"),
    ]


def run(widths=(1000, 10000, 50000), compare_width: int = 50000,
        million_drops: int = 1_000_000) -> List[Row]:
    rows = _unroll_rows(widths)
    rows += _translate_rows(compare_width)
    rows += _million_row(million_drops)
    rows += _loop_rows()
    rows += _io_rows()
    return rows


def smoke(width: int) -> List[Row]:
    """CSR-only quick tier (CI: ``--width 10000``)."""
    lg = make_lg(width)
    t0 = time.monotonic()
    pgt = unroll(lg)
    rss_unroll = peak_rss_mb()
    res = min_time(pgt, dop=8)
    t = time.monotonic() - t0
    n = len(pgt)
    return [(f"translate_csr_drops_per_s[w={width};n={n}]", n / t,
             f"total_s={t:.3f};partitions={res.num_partitions};"
             f"rss_mb_unroll={rss_unroll};rss_mb_partition={peak_rss_mb()}")]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--width", type=int, default=None,
                    help="CSR-only smoke run at this logical width")
    ap.add_argument("--drops", type=int, default=1_000_000,
                    help="target physical-graph size for the big tier")
    ap.add_argument("--loop", action="store_true",
                    help="loop-carried tier only (iteration aliasing)")
    ap.add_argument("--loop-iters", type=int, default=100)
    ap.add_argument("--loop-drops-per-iter", type=int, default=10_000)
    args = ap.parse_args()
    if args.loop:
        rows = _loop_rows(args.loop_iters, args.loop_drops_per_iter)
    elif args.width:
        rows = smoke(args.width)
    else:
        rows = run(million_drops=args.drops)
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    emit_json(rows)


def emit_json(rows: List[Row]) -> None:
    """Merge rows into ``results/bench_translate.json`` (keyed by metric
    name, so a partial run — e.g. the CI smoke — keeps the other tiers'
    trend rows; same contract as ``bench_execute.py``).  Consumed by the
    ``scripts/check_bench.py`` regression gate."""
    new = [{"metric": name, "value": round(val, 2), "extra": extra}
           for name, val, extra in rows]
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    if RESULTS_PATH.exists():
        with open(RESULTS_PATH) as fh:
            old = json.load(fh).get("rows", [])
        fresh = {r["metric"] for r in new}
        new = [r for r in old if r.get("metric") not in fresh] + new
    with open(RESULTS_PATH, "w") as fh:
        json.dump({"benchmark": "bench_translate", "rows": new}, fh,
                  indent=2)
    print(f"# wrote {RESULTS_PATH}")


if __name__ == "__main__":
    main()
