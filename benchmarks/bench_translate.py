"""Translation cost: unroll + partition time vs logical-graph width (§3.4).

The paper streams JSON and unrolls logical graphs into millions of drops;
here we measure our unroll + min_time partitioning throughput
(drops/second) as the physical graph grows.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import min_time, unroll
from repro.core.graph_io import load_pgt, save_pgt
from repro.dsl import GraphBuilder


def make_lg(width: int, depth: int = 3):
    g = GraphBuilder(f"tr{width}")
    g.data("src")
    with g.scatter("sc", width):
        for i in range(depth):
            g.component(f"w{i}", app="noop", time=0.001)
            g.data(f"d{i}", volume=1e6)
    g.connect("src", "w0")
    for i in range(depth):
        g.connect(f"w{i}", f"d{i}")
        if i + 1 < depth:
            g.connect(f"d{i}", f"w{i+1}")
    return g.graph()


def run(widths=(1000, 10000, 50000),
        partition_widths=(500, 2000)) -> List[Tuple[str, float, str]]:
    rows = []
    for width in widths:
        lg = make_lg(width)
        t0 = time.monotonic()
        pgt = unroll(lg)
        t_unroll = time.monotonic() - t0
        n = len(pgt)
        rows.append((f"unroll_us_per_drop[n={n}]",
                     1e6 * t_unroll / n, f"total_s={t_unroll:.3f}"))
    for width in partition_widths:
        pgt = unroll(make_lg(width))
        n = len(pgt)
        t1 = time.monotonic()
        min_time(pgt, dop=8, max_trials=500)
        t_part = time.monotonic() - t1
        rows.append((f"partition_us_per_drop[n={n}]",
                     1e6 * t_part / n,
                     f"total_s={t_part:.3f};max_trials=500"))
    # streaming (de)serialisation throughput (paper §3.7 ijson experiment)
    pgt = unroll(make_lg(10000))
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.jsonl.gz")
        t0 = time.monotonic()
        save_pgt(pgt, path)
        t_save = time.monotonic() - t0
        t1 = time.monotonic()
        load_pgt(path)
        t_load = time.monotonic() - t1
    rows.append((f"pgt_save_us_per_drop[n={len(pgt)}]",
                 1e6 * t_save / len(pgt), f"total_s={t_save:.3f}"))
    rows.append((f"pgt_load_us_per_drop[n={len(pgt)}]",
                 1e6 * t_load / len(pgt), f"total_s={t_load:.3f}"))
    return rows


def main() -> None:
    for name, val, extra in run():
        print(f"{name},{val:.2f},{extra}")


if __name__ == "__main__":
    main()
