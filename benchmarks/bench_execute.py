"""Deploy+execute throughput: object engine vs compiled frontier engine.

The paper's headline regime is data-activated execution scaling to tens of
millions of concurrent tasks; the object engine (one Python ``Drop`` +
thread-pool future + event chain per drop) caps executable graphs around
10^4 drops.  This benchmark measures both deploy+execute substrates on the
same translated ``CompiledPGT`` at 1k/10k/100k-drop tiers (the compiled
path also opens a million-drop tier: ``--tiers 1000000`` runs translate +
deploy + execute end-to-end; the object engine is skipped past
``--max-object-drops``, default 100k):

* **objects**  — per-drop instantiation + event-propagated cascade,
* **compiled** — batched index-slice deploy + the frontier scheduler
  (``repro.core.exec_compiled``), no per-drop Python objects.

Reported per tier: per-stage walls (translate / deploy with its
map_partitions share / execute, plus which stage is largest), drops/s
over deploy+execute, the paper's Fig. 8 metric (execution overhead per
drop), and compiled-over-objects speedup.  Results also land as JSON in
``results/bench_execute.json`` (alongside the existing dryrun results)
for CI trending and the ``scripts/check_bench.py`` regression gate.

The ``recovery`` tier measures the resilience subsystem
(``core.resilience``): kill 1 of N nodes at 50% completion mid-run and
report the recovery latency (lost-set closure + remap + slice
re-registration) and the re-executed-drop count next to the clean
execute wall time — the acceptance bar is recovery overhead < 10% of
the original execute time.

Usage:
  python benchmarks/bench_execute.py                 # full tier suite
  python benchmarks/bench_execute.py --tiers 1000    # quick tier only
  python benchmarks/bench_execute.py --max-object-drops 10000
  python benchmarks/bench_execute.py --tier recovery # 100k-drop recovery
"""
from __future__ import annotations

import argparse
import gc
import json
import resource
import statistics
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.core import FailureScript, Pipeline, ResilienceConfig
from repro.dsl import GraphBuilder


def peak_rss_mb() -> float:
    """Process peak RSS in MB (``ru_maxrss`` is KB on Linux).

    A cumulative high-water mark: per-stage readings record the peak
    observed *up to the end of* that stage (report-only, not gated)."""
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                 / 1024.0, 1)

# drops per unit width in make_lg: src + width*(w, d, w2, d2) + r + out
DROPS_PER_WIDTH = 4

RESULTS_PATH = Path(__file__).resolve().parents[1] / "results" / \
    "bench_execute.json"


def make_lg(width: int, weighted: bool = False):
    # weighted: nonzero cost-model weights so the recovery tier's balance
    # assertion exercises weight-based (not just count-based) spreading
    # and the victim node is guaranteed real work to lose
    t, v = (1.0, 1.0) if weighted else (0.0, 0.0)
    g = GraphBuilder(f"ex{width}")
    g.data("src", volume=v)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=t)
        g.data("d", volume=v)
        g.component("w2", app="identity", time=t)
        g.data("d2", volume=v)
    with g.gather("ga", width):
        g.component("r", app="noop", time=t)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def run_tier(target_drops: int, execution: str,
             timeout: float = 600.0) -> Dict[str, float]:
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    lg = make_lg(width)
    with Pipeline(num_nodes=4, workers_per_node=8, dop=64,
                  execution=execution) as p:
        p.translate(lg)            # same array translate for both modes
        rss_translate = peak_rss_mb()
        t0 = time.monotonic()
        p.deploy()
        rss_deploy = peak_rss_mb()
        rep = p.execute(timeout=timeout, inputs={"src": 1})
        wall = time.monotonic() - t0
        rss_execute = peak_rss_mb()
        assert rep.ok, (rep.state, rep.errors[:3])
        n = sum(rep.status_counts.values())
    # per-stage walls: translate / deploy (mapping included) / execute —
    # the 1M-tier acceptance bar is deploy no longer the largest stage
    stages = {"translate": p.translate_time, "deploy": p.deploy_time,
              "execute": rep.wall_time}
    return {
        "tier": target_drops,
        "mode": execution,
        "drops": n,
        "translate_s": round(p.translate_time, 4),
        "map_s": round(p.map_time, 4),
        "deploy_s": round(p.deploy_time, 4),
        "execute_s": round(rep.wall_time, 4),
        "wall_s": round(wall, 4),
        "largest_stage": max(stages, key=stages.get),  # type: ignore[arg-type]
        "drops_per_s": round(n / wall, 1),
        "overhead_us_per_drop": round(rep.overhead_per_drop_us(), 3),
        # cumulative peak-RSS high-water after each stage (report-only)
        "rss_mb_translate": rss_translate,
        "rss_mb_deploy": rss_deploy,
        "rss_mb_execute": rss_execute,
    }


def run_recovery_tier(target_drops: int, num_nodes: int = 8,
                      at_fraction: float = 0.5, repeats: int = 5,
                      timeout: float = 600.0) -> Dict[str, float]:
    """Kill 1 of ``num_nodes`` nodes at ``at_fraction`` completion;
    report recovery latency + re-executed drops vs the clean execute wall
    time of the same graph.  Each measurement is the median over
    ``repeats`` runs (single-shot ms-scale walls are noise-dominated on
    shared machines).

    Placement comes straight from ``map_partitions`` — the multilevel
    mapper spreads uniform graphs ~1/N per node (the round-robin
    placement workaround this tier used to carry is gone), and each run
    asserts the produced placement is within 2x of balanced."""
    width = max(target_drops // DROPS_PER_WIDTH, 1)

    def deploy_mapped(p: Pipeline) -> None:
        p.translate(make_lg(width, weighted=True))
        p.deploy()
        pgt = p.pgt
        per_node = np.bincount(pgt.node_ids[pgt.node_ids >= 0],
                               minlength=num_nodes)
        limit = 2.0 * len(pgt) / num_nodes
        assert per_node.max() <= limit, (
            f"mapper placement badly unbalanced: max node holds "
            f"{int(per_node.max())} of {len(pgt)} drops (> 2/N = "
            f"{limit:.0f}): {per_node.tolist()}")

    clean_walls: List[float] = []
    n = 0
    for _ in range(repeats):
        with Pipeline(num_nodes=num_nodes, workers_per_node=8, dop=64,
                      execution="compiled") as p:
            deploy_mapped(p)
            rep = p.execute(timeout=timeout, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            clean_walls.append(rep.wall_time)
            n = sum(rep.status_counts.values())

    victim = f"node{num_nodes - 1}"
    recovery_walls: List[float] = []
    resilient_walls: List[float] = []
    recovered = 0
    for rep_i in range(repeats + 1):
        with Pipeline(num_nodes=num_nodes, workers_per_node=8, dop=64,
                      execution="compiled") as p:
            deploy_mapped(p)
            p.resilience = ResilienceConfig(
                failures=[FailureScript(victim, at_fraction=at_fraction)])
            gc.collect()   # keep GC pauses out of the ms-scale recovery
            rep = p.execute(timeout=timeout, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            if rep_i == 0:
                continue   # warmup: first-call allocator/import costs
            recovery_walls.append(p.fault_manager.stats.recovery_seconds)
            resilient_walls.append(rep.wall_time)
            recovered = rep.recovered_drops
    clean_s = statistics.median(clean_walls)
    recovery_s = statistics.median(recovery_walls)
    return {
        "tier": target_drops,
        "mode": "recovery",
        "drops": n,
        "victim": victim,
        "num_nodes": num_nodes,
        "execute_clean_s": round(clean_s, 4),
        "execute_resilient_s": round(statistics.median(resilient_walls), 4),
        "recovery_s": round(recovery_s, 4),
        "recovered_drops": recovered,
        "recovery_frac_of_execute": round(recovery_s / max(clean_s, 1e-9),
                                          4),
        "rss_mb_peak": peak_rss_mb(),
    }


DEFAULT_MAX_OBJECT_DROPS = 100_000   # objects cost ~100us+/drop; 1M would
#                                      take minutes and gigabytes


def run(tiers=(1_000, 10_000, 100_000),
        max_object_drops: Optional[int] = DEFAULT_MAX_OBJECT_DROPS
        ) -> List[Dict[str, float]]:
    rows: List[Dict[str, float]] = []
    for tier in tiers:
        compiled = run_tier(tier, "compiled")
        rows.append(compiled)
        if max_object_drops is not None and tier > max_object_drops:
            print(f"# objects skipped at tier {tier} "
                  f"(--max-object-drops {max_object_drops})", flush=True)
            continue
        objects = run_tier(tier, "objects")
        objects["speedup_compiled"] = round(
            compiled["drops_per_s"] / objects["drops_per_s"], 1)
        rows.append(objects)
    return rows


def emit(rows: List[Dict[str, float]], merge: bool = False) -> None:
    for r in rows:
        if r["mode"] == "recovery":
            print(f"execute_recovery_s[n={r['drops']}],{r['recovery_s']},"
                  f"recovered={r['recovered_drops']};"
                  f"frac_of_execute={r['recovery_frac_of_execute']}")
            continue
        extra = (f"translate_s={r.get('translate_s', '?')};"
                 f"deploy_s={r['deploy_s']};"
                 f"map_s={r.get('map_s', '?')};"
                 f"execute_s={r['execute_s']};"
                 f"largest_stage={r.get('largest_stage', '?')};"
                 f"overhead_us={r['overhead_us_per_drop']}")
        if "speedup_compiled" in r:
            extra += f";compiled_speedup={r['speedup_compiled']}x"
        print(f"execute_{r['mode']}_drops_per_s[n={r['drops']}],"
              f"{r['drops_per_s']:.2f},{extra}")
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    if merge and RESULTS_PATH.exists():
        # keep every other (mode, tier) cell — a partial run (e.g. the
        # CI 10k smoke) must not delete the other tiers' trend rows
        with open(RESULTS_PATH) as fh:
            old = json.load(fh).get("rows", [])
        new_keys = {(r["mode"], r["tier"]) for r in rows}
        rows = [r for r in old
                if (r.get("mode"), r.get("tier")) not in new_keys] + rows
    with open(RESULTS_PATH, "w") as fh:
        json.dump({"benchmark": "bench_execute", "rows": rows}, fh,
                  indent=2)
    print(f"# wrote {RESULTS_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tier", choices=["standard", "recovery"],
                    default="standard",
                    help="'recovery' = node-kill + lineage-recovery suite")
    ap.add_argument("--tiers", type=int, nargs="+", default=None,
                    help="target drop counts")
    ap.add_argument("--max-object-drops", type=int,
                    default=DEFAULT_MAX_OBJECT_DROPS,
                    help="skip the object engine above this tier "
                         "(it needs ~100us+ per drop)")
    args = ap.parse_args()
    if args.tier == "recovery":
        tiers = tuple(args.tiers or [100_000])
        emit([run_recovery_tier(t) for t in tiers], merge=True)
    else:
        tiers = tuple(args.tiers or [1_000, 10_000, 100_000])
        emit(run(tiers, args.max_object_drops), merge=True)


if __name__ == "__main__":
    main()
