"""Deploy+execute throughput: object engine vs compiled frontier engine.

The paper's headline regime is data-activated execution scaling to tens of
millions of concurrent tasks; the object engine (one Python ``Drop`` +
thread-pool future + event chain per drop) caps executable graphs around
10^4 drops.  This benchmark measures both deploy+execute substrates on the
same translated ``CompiledPGT`` at 1k/10k/100k-drop tiers (the compiled
path also opens a million-drop tier: ``--tiers 1000000`` runs translate +
deploy + execute end-to-end; the object engine is skipped past
``--max-object-drops``, default 100k):

* **objects**  — per-drop instantiation + event-propagated cascade,
* **compiled** — batched index-slice deploy + the frontier scheduler
  (``repro.core.exec_compiled``), no per-drop Python objects.

Reported per tier: per-stage walls (translate / deploy with its
map_partitions share / execute, plus which stage is largest), drops/s
over deploy+execute, the paper's Fig. 8 metric (execution overhead per
drop), and compiled-over-objects speedup.  Results also land as JSON in
``results/bench_execute.json`` (alongside the existing dryrun results)
for CI trending and the ``scripts/check_bench.py`` regression gate.

The ``recovery`` tier measures the resilience subsystem
(``core.resilience``): kill 1 of N nodes at 50% completion mid-run and
report the recovery latency (lost-set closure + remap + slice
re-registration) and the re-executed-drop count next to the clean
execute wall time — the acceptance bar is recovery overhead < 10% of
the original execute time.

The ``--telemetry`` mode measures the observability tax: interleaved
clean vs ``TelemetryConfig(timeline=True, metrics=True)`` execute runs
over one shared template, reported as ``telemetry_overhead_pct`` and
gated by a ceiling in ``results/baseline.json`` (≤10% required); the
instrumented run's Perfetto trace lands in ``results/traces/``.

Usage:
  python benchmarks/bench_execute.py                 # full tier suite
  python benchmarks/bench_execute.py --tiers 1000    # quick tier only
  python benchmarks/bench_execute.py --max-object-drops 10000
  python benchmarks/bench_execute.py --tier recovery # 100k-drop recovery
  python benchmarks/bench_execute.py --telemetry --tiers 100000 1000000
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import resource
import signal
import statistics
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.core import (EngineConfig, ExecHooks, FailureScript,
                        GraphTemplate, Pipeline, ResilienceConfig,
                        StreamConfig, TelemetryConfig, execute_frontier,
                        export_chrome_trace, make_cluster, register_app)
from repro.dsl import GraphBuilder


def peak_rss_mb() -> float:
    """Process peak RSS in MB (``ru_maxrss`` is KB on Linux).

    A cumulative high-water mark: per-stage readings record the peak
    observed *up to the end of* that stage (report-only, not gated)."""
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                 / 1024.0, 1)

# drops per unit width in make_lg: src + width*(w, d, w2, d2) + r + out
DROPS_PER_WIDTH = 4

RESULTS_PATH = Path(__file__).resolve().parents[1] / "results" / \
    "bench_execute.json"


def make_lg(width: int, weighted: bool = False):
    # weighted: nonzero cost-model weights so the recovery tier's balance
    # assertion exercises weight-based (not just count-based) spreading
    # and the victim node is guaranteed real work to lose
    t, v = (1.0, 1.0) if weighted else (0.0, 0.0)
    g = GraphBuilder(f"ex{width}")
    g.data("src", volume=v)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=t)
        g.data("d", volume=v)
        g.component("w2", app="identity", time=t)
        g.data("d2", volume=v)
    with g.gather("ga", width):
        g.component("r", app="noop", time=t)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def run_tier(target_drops: int, execution: str,
             timeout: float = 600.0) -> Dict[str, float]:
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    lg = make_lg(width)
    with Pipeline(EngineConfig(num_nodes=4, workers_per_node=8, dop=64,
                               execution=execution)) as p:
        p.translate(lg)            # same array translate for both modes
        rss_translate = peak_rss_mb()
        t0 = time.monotonic()
        p.deploy()
        rss_deploy = peak_rss_mb()
        rep = p.execute(timeout=timeout, inputs={"src": 1})
        wall = time.monotonic() - t0
        rss_execute = peak_rss_mb()
        assert rep.ok, (rep.state, rep.errors[:3])
        n = sum(rep.status_counts.values())
    # per-stage walls: translate / deploy (mapping included) / execute —
    # the 1M-tier acceptance bar is deploy no longer the largest stage
    stages = {"translate": p.translate_time, "deploy": p.deploy_time,
              "execute": rep.wall_time}
    return {
        "tier": target_drops,
        "mode": execution,
        "drops": n,
        "translate_s": round(p.translate_time, 4),
        "map_s": round(p.map_time, 4),
        "deploy_s": round(p.deploy_time, 4),
        "execute_s": round(rep.wall_time, 4),
        "wall_s": round(wall, 4),
        "largest_stage": max(stages, key=stages.get),  # type: ignore[arg-type]
        "drops_per_s": round(n / wall, 1),
        "overhead_us_per_drop": round(rep.overhead_per_drop_us(), 3),
        # cumulative peak-RSS high-water after each stage (report-only)
        "rss_mb_translate": rss_translate,
        "rss_mb_deploy": rss_deploy,
        "rss_mb_execute": rss_execute,
    }


def run_recovery_tier(target_drops: int, num_nodes: int = 8,
                      at_fraction: float = 0.5, repeats: int = 5,
                      timeout: float = 600.0) -> Dict[str, float]:
    """Kill 1 of ``num_nodes`` nodes at ``at_fraction`` completion;
    report recovery latency + re-executed drops vs the clean execute wall
    time of the same graph.  Each measurement is the median over
    ``repeats`` runs (single-shot ms-scale walls are noise-dominated on
    shared machines).

    Placement comes straight from ``map_partitions`` — the multilevel
    mapper spreads uniform graphs ~1/N per node (the round-robin
    placement workaround this tier used to carry is gone), and each run
    asserts the produced placement is within 2x of balanced."""
    width = max(target_drops // DROPS_PER_WIDTH, 1)

    def deploy_mapped(p: Pipeline) -> None:
        p.translate(make_lg(width, weighted=True))
        p.deploy()
        pgt = p.pgt
        per_node = np.bincount(pgt.node_ids[pgt.node_ids >= 0],
                               minlength=num_nodes)
        limit = 2.0 * len(pgt) / num_nodes
        assert per_node.max() <= limit, (
            f"mapper placement badly unbalanced: max node holds "
            f"{int(per_node.max())} of {len(pgt)} drops (> 2/N = "
            f"{limit:.0f}): {per_node.tolist()}")

    clean_walls: List[float] = []
    n = 0
    for _ in range(repeats):
        with Pipeline(EngineConfig(num_nodes=num_nodes, workers_per_node=8,
                                   dop=64, execution="compiled")) as p:
            deploy_mapped(p)
            rep = p.execute(timeout=timeout, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            clean_walls.append(rep.wall_time)
            n = sum(rep.status_counts.values())

    victim = f"node{num_nodes - 1}"
    recovery_walls: List[float] = []
    resilient_walls: List[float] = []
    recovered = 0
    for rep_i in range(repeats + 1):
        with Pipeline(EngineConfig(num_nodes=num_nodes, workers_per_node=8,
                                   dop=64, execution="compiled")) as p:
            deploy_mapped(p)
            p.resilience = ResilienceConfig(
                failures=[FailureScript(victim, at_fraction=at_fraction)])
            gc.collect()   # keep GC pauses out of the ms-scale recovery
            rep = p.execute(timeout=timeout, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            if rep_i == 0:
                continue   # warmup: first-call allocator/import costs
            recovery_walls.append(p.fault_manager.stats.recovery_seconds)
            resilient_walls.append(rep.wall_time)
            recovered = rep.recovered_drops
    clean_s = statistics.median(clean_walls)
    recovery_s = statistics.median(recovery_walls)
    return {
        "tier": target_drops,
        "mode": "recovery",
        "drops": n,
        "victim": victim,
        "num_nodes": num_nodes,
        "execute_clean_s": round(clean_s, 4),
        "execute_resilient_s": round(statistics.median(resilient_walls), 4),
        "recovery_s": round(recovery_s, 4),
        "recovered_drops": recovered,
        "recovery_frac_of_execute": round(recovery_s / max(clean_s, 1e-9),
                                          4),
        "rss_mb_peak": peak_rss_mb(),
    }


TRACES_DIR = RESULTS_PATH.parent / "traces"


def run_telemetry_tier(target_drops: int, repeats: Optional[int] = None,
                       timeout: float = 600.0) -> Dict[str, float]:
    """Telemetry overhead: clean vs instrumented execute over one shared
    template (translate+map paid once, outside the measurement).

    Runs interleave clean/instrumented so machine drift hits both arms
    equally; each arm's wall is the *best* of ``repeats`` — min-of-N is
    the standard noise-floor estimator for CPU benches (medians still
    jitter several percent run-to-run on a shared box, enough to trip a
    10% gate on their own).  Deferred timeline materialization (the
    batch-stamp replay) is timed separately and reported as
    ``timeline_replay_s`` — it is a one-time read-side cost, not an
    execute-path tax.  The last instrumented session's Perfetto trace
    is exported to ``results/traces/`` (what CI uploads as an
    artifact).
    """
    from repro.core import MetricsRegistry
    if repeats is None:
        # small tiers have ~20ms walls where scheduler jitter alone is
        # worth several percent — repeat them more, they are cheap
        repeats = 11 if target_drops <= 200_000 else 9
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    lg = make_lg(width)
    master, nodes = make_cluster(4, 1, 8)
    try:
        tpl = GraphTemplate.build(lg, nodes, dop=64)
        executors = master.node_executors()
        n = tpl.num_drops

        def one_run(instrumented: bool, run_i: int):
            session = tpl.materialize(
                f"tel-{'on' if instrumented else 'off'}-{run_i}",
                master=master)
            if instrumented:
                session.enable_timeline()
                session.metrics = MetricsRegistry()
            session.write("src", 1)
            t0 = time.monotonic()
            ok = execute_frontier(session, timeout=timeout,
                                  executors=executors)
            wall = time.monotonic() - t0
            assert ok and not session.error_info, "telemetry tier failed"
            return wall, session

        one_run(False, -1)     # warmup (allocator, CSR caches)
        clean_walls: List[float] = []
        instr_walls: List[float] = []
        last_session = None
        for k in range(repeats):
            gc.collect()
            w, _ = one_run(False, k)
            clean_walls.append(w)
            gc.collect()
            w, last_session = one_run(True, k)
            instr_walls.append(w)

        clean_s = min(clean_walls)
        instr_s = min(instr_walls)
        clean_dps = n / clean_s
        instr_dps = n / instr_s
        overhead_pct = (clean_dps / instr_dps - 1.0) * 100.0

        t0 = time.monotonic()
        last_session.timeline.stamped()       # force batch-stamp replay
        replay_s = time.monotonic() - t0

        TRACES_DIR.mkdir(parents=True, exist_ok=True)
        trace_path = TRACES_DIR / f"trace_execute_{target_drops}.json"
        trace = export_chrome_trace(last_session, trace_path)
    finally:
        master.shutdown()
    return {
        "tier": target_drops,
        "mode": "telemetry",
        "drops": n,
        "clean_execute_s": round(clean_s, 4),
        "telemetry_execute_s": round(instr_s, 4),
        # deliberately NOT named drops_per_s: these are execute-only
        # walls over a warm template and must not feed the end-to-end
        # throughput floors collected by check_bench.py
        "clean_drops_per_s": round(clean_dps, 1),
        "telemetry_drops_per_s": round(instr_dps, 1),
        "telemetry_overhead_pct": round(overhead_pct, 2),
        "timeline_replay_s": round(replay_s, 4),
        "trace_file": str(trace_path),
        "trace_events": trace["events"],
        "trace_slices": trace["slices"],
        "rss_mb_peak": peak_rss_mb(),
    }


STREAM_CHUNKS = 8          # chunks per stream in the streaming tier
STREAM_DT = 0.002          # per-chunk produce/consume work (seconds)


def make_stream_lg(width: int, chunks: int):
    """``width`` independent prefill -> decode-shaped chains: each
    producer emits ``chunks`` chunks onto a streaming edge consumed by a
    chunk handler — the overlap-measurement workload."""
    g = GraphBuilder(f"stream{width}")
    g.data("src")
    with g.scatter("sc", width):
        g.component("prod", app="bench/stream-prod", time=0.0)
        g.data("d")
        g.component("cons", app="bench/stream-cons", time=0.0)
        g.data("d2")
    with g.gather("ga", width):
        g.component("r", app="noop", time=0.0)
    g.data("out")
    g.chain("src", "prod", "d")
    g.connect("d", "cons", streaming=True)
    g.chain("cons", "d2", "r", "out")
    return g.graph()


def _interval_union(starts: np.ndarray, ends: np.ndarray) -> List[tuple]:
    order = np.argsort(starts)
    merged: List[tuple] = []
    for s, e in zip(starts[order], ends[order]):
        if merged and s <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], e))
        else:
            merged.append((float(s), float(e)))
    return merged


def overlap_fraction(chunk_rows: np.ndarray, prod_starts: np.ndarray,
                     prod_ends: np.ndarray) -> float:
    """Fraction of consumer chunk-processing time spent while at least
    one streaming producer was still executing."""
    union = _interval_union(prod_starts, prod_ends)
    total = 0.0
    inside = 0.0
    for _idx, _seq, t0, t1 in chunk_rows:
        total += t1 - t0
        for s, e in union:
            lo, hi = max(t0, s), min(t1, e)
            if hi > lo:
                inside += hi - lo
    return inside / total if total > 0 else 0.0


def run_streaming_tier(target_drops: int, repeats: int = 3,
                       chunks: int = STREAM_CHUNKS,
                       timeout: float = 600.0) -> Dict[str, float]:
    """Chunk-granular streaming on the compiled engine: ``width``
    producer->consumer chains where each producer emits ``chunks``
    chunks.  The headline metric is ``overlap_fraction`` — the share of
    chunk-processing time overlapping producer execution (1.0 = fully
    pipelined, 0.0 = strict batch behaviour); median over ``repeats``
    runs, floor-gated in ``results/baseline.json`` (≥ 0.3 required).
    The last run's Perfetto trace (with per-chunk slices) lands in
    ``results/traces/`` for the CI artifact."""
    width = max(target_drops // DROPS_PER_WIDTH, 1)

    @register_app("bench/stream-prod")
    def stream_prod(inputs, outputs, app):
        for i in range(chunks):
            time.sleep(STREAM_DT)      # per-chunk production work
            for o in outputs:
                o.write(i)

    def _cons_finish(inputs, outputs, app):
        for o in outputs:
            o.write(app.scratch.get("n", 0))

    @register_app("bench/stream-cons", streaming=True, finish=_cons_finish)
    def stream_cons(value, app):
        time.sleep(STREAM_DT)          # per-chunk consumption work
        app.scratch["n"] = app.scratch.get("n", 0) + 1

    lg = make_stream_lg(width, chunks)
    overlaps: List[float] = []
    walls: List[float] = []
    n = 0
    n_chunks = 0
    trace_path = None
    trace = {"events": 0, "slices": 0}
    for _ in range(repeats):
        cfg = EngineConfig(
            num_nodes=4, workers_per_node=8, dop=64, execution="compiled",
            stream=StreamConfig(ring_capacity=max(chunks, 4)),
            telemetry=TelemetryConfig(timeline=True, metrics=True))
        with Pipeline(cfg) as p:
            p.translate(lg)
            p.deploy()
            rep = p.execute(timeout=timeout, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            n = sum(rep.status_counts.values())
            session = p.session
            tbl = session.stream
            assert tbl is not None and tbl.n_edges == width, \
                "streaming tier must run through the chunk lane"
            tl = session.timeline
            chunk_rows = tl.chunk_spans()
            n_chunks = len(chunk_rows)
            assert n_chunks == width * chunks, \
                (n_chunks, width * chunks)
            # producers = apps feeding ring sources (not chunk handlers)
            pgt = session.pgt
            prod = np.zeros(len(pgt), dtype=bool)
            prod[pgt.edge_src[tbl.is_src[pgt.edge_dst]]] = True
            t0s, t1s = tl.t_start[prod], tl.t_end[prod]
            done = t1s > 0
            overlaps.append(
                overlap_fraction(chunk_rows, t0s[done], t1s[done]))
            walls.append(rep.wall_time)
            TRACES_DIR.mkdir(parents=True, exist_ok=True)
            trace_path = TRACES_DIR / f"trace_streaming_{target_drops}.json"
            trace = p.export_trace(str(trace_path))
    return {
        "tier": target_drops,
        "mode": "streaming",
        "drops": n,
        "streams": width,
        "chunks_per_stream": chunks,
        "chunks_total": n_chunks,
        "execute_s": round(statistics.median(walls), 4),
        "overlap_fraction": round(statistics.median(overlaps), 4),
        "trace_file": str(trace_path),
        "trace_events": trace["events"],
        "trace_slices": trace["slices"],
        "rss_mb_peak": peak_rss_mb(),
    }


# ---------------------------------------------------------------------------
# multiproc tier: CPU-bound throughput threads-vs-processes + recovery
# with a real SIGKILL (workers="process", PR-10)
# ---------------------------------------------------------------------------

MULTIPROC_SPIN = 60_000    # pure-Python iterations per app: GIL-bound work
MULTIPROC_ARR_N = 64 * 1024   # 512 KiB float64 arrays for the zero-copy leg

# NOTE on apps: spawn workers resolve these by module reference, so they
# must live at module level (this script re-imports cleanly in children
# because all driver code is under the __main__ guard).


@register_app("bench/cpu-spin")
def _cpu_spin(inputs, outputs, app):
    v = inputs[0].read() if inputs else 0
    acc = int(v) if np.isscalar(v) else 0
    for i in range(MULTIPROC_SPIN):
        acc = (acc + i * 31) % 1000003
    for o in outputs:
        o.write(acc)


@register_app("bench/arr-make")
def _arr_make(inputs, outputs, app):
    seed = inputs[0].read() if inputs else 1
    for o in outputs:
        o.write(np.full(MULTIPROC_ARR_N, float(seed)))


@register_app("bench/arr-scale")
def _arr_scale(inputs, outputs, app):
    v = inputs[0].read()
    for o in outputs:
        o.write(v * 2.0)


@register_app("bench/arr-reduce")
def _arr_reduce(inputs, outputs, app):
    total = sum(float(np.asarray(i.read()).sum()) for i in inputs)
    for o in outputs:
        o.write(total)


def make_spin_lg(width: int, depth: int = 2):
    g = GraphBuilder(f"spin{width}")
    g.data("src", volume=1.0)
    with g.scatter("sc", width):
        names = []
        for i in range(depth):
            g.component(f"w{i}", app="bench/cpu-spin", time=1.0)
            g.data(f"d{i}", volume=1.0)
            names += [f"w{i}", f"d{i}"]
    with g.gather("ga", width):
        g.component("r", app="bench/cpu-spin", time=1.0)
    g.data("out")
    g.chain("src", *names, "r", "out")
    return g.graph()


def make_array_lg(width: int):
    g = GraphBuilder(f"arr{width}")
    g.data("src", volume=1.0)
    with g.scatter("sc", width):
        g.component("mk", app="bench/arr-make", time=1.0)
        g.data("arr", volume=1.0)
        g.component("up", app="bench/arr-scale", time=1.0)
        g.data("arr2", volume=1.0)
    with g.gather("ga", width):
        g.component("r", app="bench/arr-reduce", time=1.0)
    g.data("out")
    g.chain("src", "mk", "arr", "up", "arr2", "r", "out")
    return g.graph()


def _count_pickled_arrays(master) -> Dict[str, object]:
    """Wrap each island plane's ``encode`` to count ndarray values that
    fell back to inline pickling (the zero-copy claim being gated)."""
    counter = {"n": 0}
    planes = {}
    for nm in master.node_managers().values():
        plane = getattr(nm, "plane", None)
        if plane is None or id(plane) in planes:
            continue
        planes[id(plane)] = plane
        orig = plane.encode

        def encode(value, _orig=orig):
            wire = _orig(value)
            if wire[0] == "raw" and isinstance(value, np.ndarray):
                counter["n"] += 1
            return wire

        plane.encode = encode
    return {"counter": counter, "planes": list(planes.values())}


def _spin_walls(mode: str, lg, num_workers: int, repeats: int,
                timeout: float) -> tuple:
    """Median execute wall for one worker mode over a warm cluster (one
    ``make_cluster`` per mode, so process workers spawn once, outside
    the measured repeats — matching the thread pool's warm threads)."""
    master, nodes = make_cluster(num_workers, 1, 4, workers=mode)
    try:
        tpl = GraphTemplate.build(lg, nodes, dop=num_workers)
        executors = master.node_executors()
        n = tpl.num_drops
        walls: List[float] = []
        for k in range(repeats + 1):
            session = tpl.materialize(f"mp-{mode}-{k}", master=master)
            session.write("src", 1)
            gc.collect()
            t0 = time.monotonic()
            ok = execute_frontier(session, timeout=timeout,
                                  executors=executors)
            wall = time.monotonic() - t0
            assert ok and not session.error_info, \
                f"multiproc tier failed ({mode})"
            if k > 0:          # run 0 is warmup (spawn / allocator)
                walls.append(wall)
    finally:
        master.shutdown()
    return statistics.median(walls), n


def run_multiproc_tier(num_workers: int = 4, repeats: int = 3,
                       timeout: float = 600.0) -> Dict[str, float]:
    """Threads-vs-processes on CPU-bound pure-Python apps, the zero-copy
    shared-memory leg, and recovery from a real worker SIGKILL.

    ``proc_speedup`` is process-over-thread throughput on GIL-bound
    work: ~num_workers on a box with that many free cores, ~1.0 on a
    single-core runner (both modes time-slice one core — parity IS the
    ceiling there, which is why the committed floor is calibrated from
    measurement, not fixed at the multi-core ideal)."""
    lg = make_spin_lg(width=2 * num_workers)
    thread_wall, n = _spin_walls("thread", lg, num_workers, repeats,
                                 timeout)
    proc_wall, _ = _spin_walls("process", lg, num_workers, repeats,
                               timeout)

    # zero-copy leg: every inter-app array edge must ride the plane
    master, nodes = make_cluster(num_workers, 1, 4, workers="process")
    try:
        probe = _count_pickled_arrays(master)
        tpl = GraphTemplate.build(make_array_lg(width=num_workers),
                                  nodes, dop=num_workers)
        session = tpl.materialize("mp-arrays", master=master)
        session.write("src", 1)
        ok = execute_frontier(session, timeout=timeout,
                              executors=master.node_executors())
        assert ok and not session.error_info, "zero-copy leg failed"
        pickled_arrays = probe["counter"]["n"]
        shm_results = sum(p.stats["shm_results"]
                          for p in probe["planes"])
        shm_exports = sum(p.stats["shm_exports"] +
                          p.stats["shm_passthrough"]
                          for p in probe["planes"])
    finally:
        master.shutdown()

    # recovery leg: SIGKILL one worker at >=30% completion mid-run and
    # let WorkerLost -> lineage recovery finish the session
    killed: List[int] = []

    def on_wave(session, done, total):
        if not killed and done / max(total, 1) >= 0.3:
            ex = p.master.node_managers()["node0"].executor
            if getattr(ex, "pid", None) is not None:
                os.kill(ex.pid, signal.SIGKILL)
                killed.append(ex.pid)

    with Pipeline(EngineConfig(num_nodes=num_workers, workers_per_node=4,
                               dop=num_workers, execution="compiled",
                               workers="process",
                               resilience=ResilienceConfig())) as p:
        p.translate(make_spin_lg(width=2 * num_workers))
        p.deploy()
        rep = p.execute(timeout=timeout, inputs={"src": 1},
                        hooks=ExecHooks(on_wave=on_wave))
        assert rep.ok, (rep.state, rep.errors[:3])
        assert killed, "kill hook never fired"
        assert rep.recoveries >= 1, "SIGKILL did not trigger recovery"
        recovery_wall = rep.wall_time
        recoveries = rep.recoveries
        recovered_drops = rep.recovered_drops

    return {
        "tier": num_workers,
        "mode": "multiproc",
        "drops": n,
        "num_workers": num_workers,
        "spin_iters": MULTIPROC_SPIN,
        "thread_wall_s": round(thread_wall, 4),
        "proc_wall_s": round(proc_wall, 4),
        "drops_per_s": round(n / proc_wall, 1),
        "proc_speedup": round(thread_wall / proc_wall, 3),
        "pickled_array_values": pickled_arrays,
        "shm_array_transfers": shm_exports + shm_results,
        "recovery_wall_s": round(recovery_wall, 4),
        "recoveries": recoveries,
        "recovered_drops": recovered_drops,
        "rss_mb_peak": peak_rss_mb(),
    }


DEFAULT_MAX_OBJECT_DROPS = 100_000   # objects cost ~100us+/drop; 1M would
#                                      take minutes and gigabytes


def run(tiers=(1_000, 10_000, 100_000),
        max_object_drops: Optional[int] = DEFAULT_MAX_OBJECT_DROPS
        ) -> List[Dict[str, float]]:
    rows: List[Dict[str, float]] = []
    for tier in tiers:
        compiled = run_tier(tier, "compiled")
        rows.append(compiled)
        if max_object_drops is not None and tier > max_object_drops:
            print(f"# objects skipped at tier {tier} "
                  f"(--max-object-drops {max_object_drops})", flush=True)
            continue
        objects = run_tier(tier, "objects")
        objects["speedup_compiled"] = round(
            compiled["drops_per_s"] / objects["drops_per_s"], 1)
        rows.append(objects)
    return rows


def emit(rows: List[Dict[str, float]], merge: bool = False) -> None:
    for r in rows:
        if r["mode"] == "recovery":
            print(f"execute_recovery_s[n={r['drops']}],{r['recovery_s']},"
                  f"recovered={r['recovered_drops']};"
                  f"frac_of_execute={r['recovery_frac_of_execute']}")
            continue
        if r["mode"] == "streaming":
            print(f"execute_streaming_overlap[n={r['drops']}],"
                  f"{r['overlap_fraction']},"
                  f"streams={r['streams']};"
                  f"chunks={r['chunks_total']};"
                  f"execute_s={r['execute_s']};"
                  f"trace={r['trace_file']}")
            continue
        if r["mode"] == "multiproc":
            print(f"execute_multiproc_speedup[workers={r['num_workers']}],"
                  f"{r['proc_speedup']},"
                  f"thread_wall_s={r['thread_wall_s']};"
                  f"proc_wall_s={r['proc_wall_s']};"
                  f"drops_per_s={r['drops_per_s']};"
                  f"pickled_array_values={r['pickled_array_values']};"
                  f"shm_array_transfers={r['shm_array_transfers']};"
                  f"recovery_wall_s={r['recovery_wall_s']};"
                  f"recoveries={r['recoveries']}")
            continue
        if r["mode"] == "telemetry":
            print(f"execute_telemetry_overhead_pct[n={r['drops']}],"
                  f"{r['telemetry_overhead_pct']},"
                  f"clean={r['clean_drops_per_s']};"
                  f"instrumented={r['telemetry_drops_per_s']};"
                  f"trace={r['trace_file']}")
            continue
        extra = (f"translate_s={r.get('translate_s', '?')};"
                 f"deploy_s={r['deploy_s']};"
                 f"map_s={r.get('map_s', '?')};"
                 f"execute_s={r['execute_s']};"
                 f"largest_stage={r.get('largest_stage', '?')};"
                 f"overhead_us={r['overhead_us_per_drop']}")
        if "speedup_compiled" in r:
            extra += f";compiled_speedup={r['speedup_compiled']}x"
        print(f"execute_{r['mode']}_drops_per_s[n={r['drops']}],"
              f"{r['drops_per_s']:.2f},{extra}")
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    if merge and RESULTS_PATH.exists():
        # keep every other (mode, tier) cell — a partial run (e.g. the
        # CI 10k smoke) must not delete the other tiers' trend rows
        with open(RESULTS_PATH) as fh:
            old = json.load(fh).get("rows", [])
        new_keys = {(r["mode"], r["tier"]) for r in rows}
        rows = [r for r in old
                if (r.get("mode"), r.get("tier")) not in new_keys] + rows
    with open(RESULTS_PATH, "w") as fh:
        json.dump({"benchmark": "bench_execute", "rows": rows}, fh,
                  indent=2)
    print(f"# wrote {RESULTS_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tier", choices=["standard", "recovery", "streaming",
                                       "multiproc"],
                    default="standard",
                    help="'recovery' = node-kill + lineage-recovery suite; "
                         "'streaming' = chunk-lane overlap measurement; "
                         "'multiproc' = threads-vs-processes throughput, "
                         "zero-copy plane audit + real-SIGKILL recovery")
    ap.add_argument("--tiers", type=int, nargs="+", default=None,
                    help="target drop counts")
    ap.add_argument("--max-object-drops", type=int,
                    default=DEFAULT_MAX_OBJECT_DROPS,
                    help="skip the object engine above this tier "
                         "(it needs ~100us+ per drop)")
    ap.add_argument("--telemetry", action="store_true",
                    help="measure instrumented-vs-clean execute overhead "
                         "(default tiers: 100k + 1M) and export a "
                         "Perfetto trace per tier")
    args = ap.parse_args()
    if args.telemetry:
        tiers = tuple(args.tiers or [100_000, 1_000_000])
        emit([run_telemetry_tier(t) for t in tiers], merge=True)
    elif args.tier == "recovery":
        tiers = tuple(args.tiers or [100_000])
        emit([run_recovery_tier(t) for t in tiers], merge=True)
    elif args.tier == "streaming":
        tiers = tuple(args.tiers or [1_000])
        emit([run_streaming_tier(t) for t in tiers], merge=True)
    elif args.tier == "multiproc":
        tiers = tuple(args.tiers or [4])
        emit([run_multiproc_tier(t) for t in tiers], merge=True)
    else:
        tiers = tuple(args.tiers or [1_000, 10_000, 100_000])
        emit(run(tiers, args.max_object_drops), merge=True)


if __name__ == "__main__":
    main()
