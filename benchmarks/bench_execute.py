"""Deploy+execute throughput: object engine vs compiled frontier engine.

The paper's headline regime is data-activated execution scaling to tens of
millions of concurrent tasks; the object engine (one Python ``Drop`` +
thread-pool future + event chain per drop) caps executable graphs around
10^4 drops.  This benchmark measures both deploy+execute substrates on the
same translated ``CompiledPGT`` at 1k/10k/100k-drop tiers:

* **objects**  — per-drop instantiation + event-propagated cascade,
* **compiled** — batched index-slice deploy + the frontier scheduler
  (``repro.core.exec_compiled``), no per-drop Python objects.

Reported per tier: wall seconds (deploy+execute), drops/s, the paper's
Fig. 8 metric (execution overhead per drop), and compiled-over-objects
speedup.  Results also land as JSON in ``results/bench_execute.json``
(alongside the existing dryrun results) for CI trending.

Usage:
  python benchmarks/bench_execute.py                 # full tier suite
  python benchmarks/bench_execute.py --tiers 1000    # quick tier only
  python benchmarks/bench_execute.py --max-object-drops 10000
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import Pipeline
from repro.dsl import GraphBuilder

# drops per unit width in make_lg: src + width*(w, d, w2, d2) + r + out
DROPS_PER_WIDTH = 4

RESULTS_PATH = Path(__file__).resolve().parents[1] / "results" / \
    "bench_execute.json"


def make_lg(width: int):
    g = GraphBuilder(f"ex{width}")
    g.data("src")
    with g.scatter("sc", width):
        g.component("w", app="noop", time=0.0)
        g.data("d")
        g.component("w2", app="identity", time=0.0)
        g.data("d2")
    with g.gather("ga", width):
        g.component("r", app="noop", time=0.0)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def run_tier(target_drops: int, execution: str,
             timeout: float = 600.0) -> Dict[str, float]:
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    lg = make_lg(width)
    with Pipeline(num_nodes=4, workers_per_node=8, dop=64,
                  execution=execution) as p:
        p.translate(lg)            # same array translate for both modes
        t0 = time.monotonic()
        p.deploy()
        rep = p.execute(timeout=timeout, inputs={"src": 1})
        wall = time.monotonic() - t0
        assert rep.ok, (rep.state, rep.errors[:3])
        n = sum(rep.status_counts.values())
    return {
        "tier": target_drops,
        "mode": execution,
        "drops": n,
        "deploy_s": round(p.deploy_time, 4),
        "execute_s": round(rep.wall_time, 4),
        "wall_s": round(wall, 4),
        "drops_per_s": round(n / wall, 1),
        "overhead_us_per_drop": round(rep.overhead_per_drop_us(), 3),
    }


def run(tiers=(1_000, 10_000, 100_000),
        max_object_drops: Optional[int] = None) -> List[Dict[str, float]]:
    rows: List[Dict[str, float]] = []
    for tier in tiers:
        compiled = run_tier(tier, "compiled")
        rows.append(compiled)
        if max_object_drops is not None and tier > max_object_drops:
            print(f"# objects skipped at tier {tier} "
                  f"(--max-object-drops {max_object_drops})", flush=True)
            continue
        objects = run_tier(tier, "objects")
        objects["speedup_compiled"] = round(
            compiled["drops_per_s"] / objects["drops_per_s"], 1)
        rows.append(objects)
    return rows


def emit(rows: List[Dict[str, float]]) -> None:
    for r in rows:
        extra = (f"deploy_s={r['deploy_s']};execute_s={r['execute_s']};"
                 f"overhead_us={r['overhead_us_per_drop']}")
        if "speedup_compiled" in r:
            extra += f";compiled_speedup={r['speedup_compiled']}x"
        print(f"execute_{r['mode']}_drops_per_s[n={r['drops']}],"
              f"{r['drops_per_s']:.2f},{extra}")
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    with open(RESULTS_PATH, "w") as fh:
        json.dump({"benchmark": "bench_execute", "rows": rows}, fh,
                  indent=2)
    print(f"# wrote {RESULTS_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", type=int, nargs="+",
                    default=[1_000, 10_000, 100_000],
                    help="target drop counts")
    ap.add_argument("--max-object-drops", type=int, default=None,
                    help="skip the object engine above this tier "
                         "(it needs ~100us+ per drop)")
    args = ap.parse_args()
    emit(run(tuple(args.tiers), args.max_object_drops))


if __name__ == "__main__":
    main()
