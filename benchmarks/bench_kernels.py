"""Kernel micro-bench: Pallas (interpret) vs jnp oracle on CPU + VMEM budget.

CPU wall time of interpret mode is NOT a TPU performance proxy; the useful
numbers are (a) allclose residuals (correctness at bench shapes), (b) the
analytic VMEM working set per BlockSpec (must fit the ~16 MiB v5e VMEM),
and (c) arithmetic intensity of the tile (MXU utilisation potential).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_bhsd


def vmem_flash(block_q: int, block_k: int, d: int) -> int:
    """bytes: q + k + v tiles (bf16) + scratch (f32 acc/m/l) + scores."""
    return (block_q * d * 2 + 2 * block_k * d * 2
            + block_q * d * 4 + 2 * block_q * 4
            + block_q * block_k * 4)


def vmem_ssd(chunk: int, p: int, n: int) -> int:
    return (chunk * p * 2 + 2 * chunk * n * 2 + chunk * 4
            + n * p * 4 + chunk * chunk * 4 + chunk * p * 2)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention residual + timing at a bench shape
    b, hq, hkv, s, d = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (b, hq, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), jnp.float32)
    t0 = time.monotonic()
    out = flash_attention_bhsd(q, k, v, block_q=128, block_k=128)
    out.block_until_ready()
    t_kernel = time.monotonic() - t0
    t1 = time.monotonic()
    want = ref.mha_reference(q, k, v)
    want.block_until_ready()
    t_ref = time.monotonic() - t1
    resid = float(jnp.max(jnp.abs(out - want)))
    rows.append(("flash_attn_interpret_us", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f};max_resid={resid:.2e}"))
    for bq, bk, hd in [(128, 128, 128), (256, 128, 128), (128, 128, 64)]:
        vm = vmem_flash(bq, bk, hd)
        inten = (2 * bq * bk * hd * 2) / max(vmem_flash(bq, bk, hd), 1)
        rows.append((f"flash_vmem_bytes[bq={bq},bk={bk},d={hd}]",
                     float(vm), f"fits_16MiB={vm < 16*2**20};"
                     f"flops_per_byte={inten:.1f}"))

    # ssd residual + timing
    b2, h2, s2, p2, n2 = 1, 4, 512, 64, 64
    x = jax.random.normal(key, (b2, h2, s2, p2), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                           (b2, h2, s2)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (h2,)) * 0.3)
    bb = jax.random.normal(jax.random.PRNGKey(5), (b2, h2, s2, n2)) * 0.5
    cc = jax.random.normal(jax.random.PRNGKey(6), (b2, h2, s2, n2)) * 0.5
    t0 = time.monotonic()
    y, st = ssd_scan_bhsd(x, dt, a, bb, cc, chunk=128)
    y.block_until_ready()
    t_kernel = time.monotonic() - t0
    t1 = time.monotonic()
    yr, _ = ref.ssd_reference(x, dt, a, bb, cc)
    yr.block_until_ready()
    t_ref = time.monotonic() - t1
    resid = float(jnp.max(jnp.abs(y - yr)))
    rows.append(("ssd_scan_interpret_us", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f};max_resid={resid:.2e}"))
    for ch, pp, nn in [(256, 64, 128), (128, 64, 64)]:
        vm = vmem_ssd(ch, pp, nn)
        rows.append((f"ssd_vmem_bytes[Q={ch},P={pp},N={nn}]",
                     float(vm), f"fits_16MiB={vm < 16*2**20}"))
    return rows


def main() -> None:
    for name, val, extra in run():
        print(f"{name},{val:.2f},{extra}")


if __name__ == "__main__":
    main()
