"""Partitioning quality: min_time vs min_res (paper §3.4 step 3).

Reports, for a representative imaging-like graph: makespan and partition
count for (a) no partitioning (every drop its own partition = all edges
remote), (b) min_time, (c) min_res under a 2x-critical-path deadline —
for BOTH translate paths: the seed dict path (``unroll_dict`` +
simulation-validated merging) and the array path (``CompiledPGT`` CSR +
union-find merging), so quality parity and throughput are visible side
by side.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

from repro.core import (NodeInfo, critical_path, map_partitions, min_res,
                        min_time, partition_stats, simulate_makespan,
                        unroll, unroll_dict)
from repro.dsl import GraphBuilder


def imaging_like_lg(days: int = 6, chans: int = 8):
    """CHILES-shaped: scatter by day -> scatter by channel -> groupby chan
    -> clean -> gather."""
    g = GraphBuilder("imaging")
    g.data("ms", volume=1e9)
    with g.scatter("day", days):
        g.component("split", app="noop", time=2.0)
        with g.scatter("chan", chans):
            g.data("chunk", volume=2e8)
            g.component("subtract", app="noop", time=3.0)
            g.data("sub", volume=2e8)
    with g.group_by("bychan"):
        g.component("clean", app="noop", time=5.0)
        g.data("img", volume=4e7)
    with g.gather("all", chans):
        g.component("concat", app="noop", time=1.0)
    g.data("cube", volume=3e8)
    g.chain("ms", "split", "chunk", "subtract", "sub", "clean", "img",
            "concat", "cube")
    return g.graph()


def run(dop: int = 8) -> List[Tuple[str, float, str]]:
    rows = []
    for label, do_unroll in (("csr", unroll), ("dict", unroll_dict)):
        pgt = do_unroll(imaging_like_lg())
        n = len(pgt)
        for i, s in enumerate(pgt.drops.values()):
            s.partition = i
        base = simulate_makespan(pgt, dop)
        rows.append((f"makespan_none_{label}[n={n}]", base * 1e6,
                     "partitions=%d" % n))

        t0 = time.monotonic()
        pgt_t = do_unroll(imaging_like_lg())
        rt = min_time(pgt_t, dop=dop)
        t_tr = time.monotonic() - t0
        st = partition_stats(pgt_t)
        rows.append((f"makespan_min_time_{label}[n={n}]", rt.makespan * 1e6,
                     f"partitions={rt.num_partitions};"
                     f"cross_GB={st['cross_volume']/1e9:.2f};"
                     f"speedup={base/max(rt.makespan,1e-9):.2f}x;"
                     f"translate_drops_per_s={n/t_tr:.0f}"))

        pgt_r = do_unroll(imaging_like_lg())
        deadline = critical_path(pgt_r, partitioned=False) * 2
        rr = min_res(pgt_r, deadline=deadline, dop=dop)
        rows.append((f"makespan_min_res_{label}[n={n}]", rr.makespan * 1e6,
                     f"partitions={rr.num_partitions};"
                     f"deadline={deadline*1e6:.0f};"
                     f"meets={rr.makespan <= deadline * 1.000001}"))
    return rows


def verbose_partition(num_nodes: int = 4, dop: int = 8,
                      refine_mode: str = "both") -> None:
    """Print the mapper's per-level uncoarsening stats (cut / imbalance
    before and after KL refinement at each hierarchy level, plus the
    refine wall) for the imaging-like graph — the substrate's multilevel
    path made visible.  ``refine_mode`` compares the boundary-only
    worklist inner loop against the full-sweep oracle per level."""
    modes = (["worklist", "sweep"] if refine_mode == "both"
             else [refine_mode])
    nodes = [NodeInfo(f"node{i}") for i in range(num_nodes)]
    for mode in modes:
        pgt = unroll(imaging_like_lg())
        min_time(pgt, dop=dop)
        hier = getattr(pgt, "_partition_hierarchy", None)
        nlv = hier.num_levels if hier is not None else 0
        print(f"# refine_mode={mode}: recorded hierarchy {nlv} level(s), "
              f"{int(pgt.partition.max()) + 1} partitions kept")
        stats: List[Dict[str, float]] = []
        map_partitions(pgt, nodes, refine_mode=mode, level_stats=stats)
        print("# mode,level,vertices,edges,cut_before,cut_after,"
              "imbalance_before,imbalance_after,refine_ms")
        for s in stats:
            print(f"{mode},level_{int(s['level'])},{int(s['vertices'])},"
                  f"{int(s['edges'])},{s['cut_before']:.1f},"
                  f"{s['cut_after']:.1f},{s['imbalance_before']:.3f},"
                  f"{s['imbalance_after']:.3f},"
                  f"{s['refine_s'] * 1e3:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dop", type=int, default=8)
    ap.add_argument("--verbose-partition", action="store_true",
                    help="also print the mapper's per-level cut/imbalance "
                         "stats from the shared partition hierarchy")
    ap.add_argument("--refine-mode", default="both",
                    choices=["worklist", "sweep", "both"],
                    help="KL inner loop(s) for --verbose-partition: "
                         "boundary-only worklist, full-sweep oracle, or "
                         "both side by side")
    args = ap.parse_args()
    for name, val, extra in run(dop=args.dop):
        print(f"{name},{val:.2f},{extra}")
    if args.verbose_partition:
        verbose_partition(dop=args.dop, refine_mode=args.refine_mode)


if __name__ == "__main__":
    main()
