"""Multi-tenant serving throughput: sessions/s through the resident engine.

The paper's managers translate a pipeline shape once and run it
per-observation; :class:`repro.core.manager.EngineManager` is that shape
for the compiled path — a template cache (translate+map paid once per
graph shape) plus N concurrent ``CompiledSession``s over shared node
pools with bounded admission.  This benchmark measures, per graph tier
(1k/10k/100k drops):

* **cold vs warm**: full translate+map (``get_template`` on an empty
  cache) against the median ``materialize()`` wall — the tentpole
  target is warm ≥10x faster than cold at the 100k tier,
* **sustained serving**: S sessions of the same shape submitted under
  ``--concurrent`` (default 4) concurrent execution — sessions/s plus
  p50/p99 *session latency* (submit-to-report, queueing included).

Rows land JSON-merged by (mode, tier) in ``results/bench_serve.json``
for the ``scripts/check_bench.py`` gate: ``sessions_per_s`` and
``materialize_speedup`` are floor metrics, ``p99_session_s`` is a
lower-is-better ceiling.

Usage:
  python benchmarks/bench_serve.py                    # full tier suite
  python benchmarks/bench_serve.py --tiers 10000      # CI smoke tier
  python benchmarks/bench_serve.py --sessions 16 --concurrent 8
"""
from __future__ import annotations

import argparse
import json
import resource
import statistics
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import EngineManager
from repro.dsl import GraphBuilder


def peak_rss_mb() -> float:
    """Process peak RSS in MB (cumulative high-water; report-only)."""
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                 / 1024.0, 1)

# drops per unit width in make_lg: src + width*(w, d, w2, d2) + r + out
DROPS_PER_WIDTH = 4

# sessions per tier: enough for stable quantiles at small tiers without
# making the 100k tier (whose per-session wall is ~100x larger) crawl
SESSIONS_PER_TIER = {1_000: 64, 10_000: 32, 100_000: 8}

RESULTS_PATH = Path(__file__).resolve().parents[1] / "results" / \
    "bench_serve.json"


def make_lg(width: int):
    g = GraphBuilder(f"serve{width}")
    g.data("src")
    with g.scatter("sc", width):
        g.component("w", app="noop", time=0.0)
        g.data("d")
        g.component("w2", app="identity", time=0.0)
        g.data("d2")
    with g.gather("ga", width):
        g.component("r", app="noop", time=0.0)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def _close_probe(mgr: EngineManager, session) -> None:
    """Release a session materialized outside submit() (timing probes)."""
    for nm in mgr.master.node_managers().values():
        nm.compiled_sessions.pop(session.session_id, None)
    mgr.master._sessions.pop(session.session_id, None)
    session.close()


def run_tier(target_drops: int, sessions: Optional[int] = None,
             concurrent: int = 4, materialize_probes: int = 5,
             timeout: float = 600.0) -> Dict[str, float]:
    width = max(target_drops // DROPS_PER_WIDTH, 1)
    if sessions is None:
        sessions = SESSIONS_PER_TIER.get(target_drops, 8)
    lg = make_lg(width)
    with EngineManager(num_nodes=4, workers_per_node=8, dop=64,
                       max_concurrent=concurrent,
                       max_pending=sessions) as mgr:
        # cold: full translate + map + node-slice argsort (empty cache)
        t0 = time.monotonic()
        template = mgr.get_template(lg)
        cold_s = time.monotonic() - t0
        n = template.num_drops
        # warm: median of repeated O(drops) materializations
        walls: List[float] = []
        for i in range(materialize_probes):
            t0 = time.monotonic()
            s = template.materialize(f"probe-{target_drops}-{i}",
                                     master=mgr.master)
            walls.append(time.monotonic() - t0)
            _close_probe(mgr, s)
        warm_s = statistics.median(walls)
        # sustained concurrent serving: S sessions, blocking admission
        t0 = time.monotonic()
        tickets = [mgr.submit(lg, inputs={"src": 1}, timeout=timeout,
                              block=True) for _ in range(sessions)]
        reports = [t.result() for t in tickets]
        wall = time.monotonic() - t0
        for rep in reports:
            assert rep.ok, (rep.state, rep.errors[:3])
        lats = sorted(t.latency for t in tickets)
        stats = mgr.stats()
    return {
        "tier": target_drops,
        "mode": "serve",
        "drops": n,
        "sessions": sessions,
        "concurrent": concurrent,
        "cold_translate_map_s": round(cold_s, 4),
        "materialize_s": round(warm_s, 6),
        "materialize_speedup": round(cold_s / max(warm_s, 1e-9), 1),
        "wall_s": round(wall, 4),
        "sessions_per_s": round(sessions / wall, 2),
        "session_drops_per_s": round(sessions * n / wall, 1),
        "p50_session_s": round(lats[len(lats) // 2], 4),
        "p99_session_s": round(
            lats[min(len(lats) - 1, int(0.99 * (len(lats) - 1)))], 4),
        "template_hits": stats["templates"]["hits"],
        "rss_mb_peak": peak_rss_mb(),
    }


def run(tiers=(1_000, 10_000, 100_000), sessions: Optional[int] = None,
        concurrent: int = 4) -> List[Dict[str, float]]:
    return [run_tier(t, sessions=sessions, concurrent=concurrent)
            for t in tiers]


def emit(rows: List[Dict[str, float]], merge: bool = False) -> None:
    for r in rows:
        print(f"serve_sessions_per_s[n={r['drops']}],"
              f"{r['sessions_per_s']:.2f},"
              f"sessions={r['sessions']};concurrent={r['concurrent']};"
              f"cold_s={r['cold_translate_map_s']};"
              f"materialize_s={r['materialize_s']};"
              f"materialize_speedup={r['materialize_speedup']}x;"
              f"p50_s={r['p50_session_s']};p99_s={r['p99_session_s']};"
              f"hits={r['template_hits']}")
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    if merge and RESULTS_PATH.exists():
        # keep every other (mode, tier) cell — a partial run (e.g. the
        # CI smoke tier) must not delete the other tiers' trend rows
        with open(RESULTS_PATH) as fh:
            old = json.load(fh).get("rows", [])
        new_keys = {(r["mode"], r["tier"]) for r in rows}
        rows = [r for r in old
                if (r.get("mode"), r.get("tier")) not in new_keys] + rows
    with open(RESULTS_PATH, "w") as fh:
        json.dump({"benchmark": "bench_serve", "rows": rows}, fh,
                  indent=2)
    print(f"# wrote {RESULTS_PATH}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", type=int, nargs="+", default=None,
                    help="target drop counts (default 1k 10k 100k)")
    ap.add_argument("--sessions", type=int, default=None,
                    help="sessions per tier (default: tier-dependent, "
                         f"{SESSIONS_PER_TIER})")
    ap.add_argument("--concurrent", type=int, default=4,
                    help="max concurrently executing sessions")
    args = ap.parse_args()
    tiers = tuple(args.tiers or [1_000, 10_000, 100_000])
    emit(run(tiers, sessions=args.sessions, concurrent=args.concurrent),
         merge=True)


if __name__ == "__main__":
    main()
