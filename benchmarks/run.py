"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per measurement):
  bench_overhead  — paper Fig. 8 (framework overhead/drop, 1 vs 2 islands)
  bench_translate — paper §3.4/§3.7 (unroll + partition + stream-IO cost)
  bench_partition — paper §3.4 step 3 (min_time vs min_res quality)
  bench_execute   — deploy+execute: object engine vs compiled frontier
  bench_kernels   — TPU kernels: residuals + VMEM working sets
  bench_roofline  — dry-run roofline terms per (arch x shape), single pod
"""
import sys
import traceback


def main() -> None:
    from . import (bench_execute, bench_kernels, bench_overhead,
                   bench_partition, bench_roofline, bench_translate)
    modules = [
        ("overhead", bench_overhead),
        ("translate", bench_translate),
        ("partition", bench_partition),
        ("execute", bench_execute),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
    ]
    failed = False
    for name, mod in modules:
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failed = True
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
