"""Roofline table from the dry-run artifacts (deliverable g source).

Reads results/dryrun/*.json and prints per-cell terms.  ``python -m
benchmarks.bench_roofline --markdown`` emits the EXPERIMENTS.md tables.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import List, Tuple

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "single"):
    rows = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("variant", "baseline") == "baseline":
            rows.append(rec)
    return rows


def run() -> List[Tuple[str, float, str]]:
    out = []
    for rec in load("single"):
        cell = f"{rec['arch']}/{rec['shape']}"
        if rec["status"] != "ok":
            out.append((f"roofline[{cell}]", 0.0, rec["status"]))
            continue
        r = rec["roofline"]
        out.append((
            f"roofline[{cell}]",
            r["roofline_fraction"],
            f"dom={r['dominant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};"
            f"collective_s={r['collective_s']:.3g};"
            f"useful={r['useful_fraction']:.3f}"))
    return out


def markdown(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| roofline frac | MODEL/HLO flops | per-dev GB | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        cell = f"{rec['arch']} | {rec['shape']}"
        if rec["status"] == "skipped":
            lines.append(f"| {cell} | — | — | — | skipped | — | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {cell} | — | — | — | ERROR | — | — | — | — |")
            continue
        r = rec["roofline"]
        mem_gb = rec["memory"]["per_device_bytes"] / 1e9
        lines.append(
            f"| {cell} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | {r['dominant'].replace('_s','')} | "
            f"{r['roofline_fraction']:.3f} | {r['useful_fraction']:.3f} | "
            f"{mem_gb:.2f} | {rec.get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def main() -> None:
    if "--markdown" in sys.argv:
        mesh = "multi" if "--multi" in sys.argv else "single"
        print(markdown(mesh))
        return
    for name, val, extra in run():
        print(f"{name},{val:.4f},{extra}")


if __name__ == "__main__":
    main()
