"""Framework execution overhead per Drop (paper Fig. 8).

The paper's metric: wall-clock overhead per Drop (execution time minus
payload time, divided by drop count), as graph size grows, for 1 island vs
multiple islands.  Paper claim: < 10 us/drop at 400 nodes; multi-island
roughly halves single-island overhead.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import EngineConfig, Pipeline
from repro.dsl import GraphBuilder


def make_graph(width: int):
    g = GraphBuilder(f"ov{width}")
    g.data("src")
    with g.scatter("sc", width):
        g.component("w", app="noop", time=0.0)
        g.data("d")
        g.component("w2", app="noop", time=0.0)
        g.data("d2")
    with g.gather("ga", width):
        g.component("r", app="noop", time=0.0)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def run(widths=(100, 500, 2000), islands=(1, 2), nodes=4,
        repeats: int = 2) -> List[Tuple[str, float, str]]:
    rows = []
    for width in widths:
        for isl in islands:
            best = float("inf")
            drops = 0
            for _ in range(repeats):
                with Pipeline(EngineConfig(
                        num_nodes=nodes, num_islands=isl,
                        workers_per_node=8, algorithm="none")) as p:
                    rep = p.run(make_graph(width), timeout=300)
                    assert rep.ok, rep.errors[:2]
                    drops = sum(rep.status_counts.values())
                    best = min(best, rep.overhead_per_drop_us())
            rows.append((f"overhead_us_per_drop[w={width},islands={isl}]",
                         best, f"drops={drops}"))
    return rows


def main() -> None:
    for name, val, extra in run():
        print(f"{name},{val:.2f},{extra}")


if __name__ == "__main__":
    main()
