#!/usr/bin/env python
"""CI bench-regression gate.

Compares fresh benchmark JSON (``results/bench_execute.json`` and
``results/bench_translate.json``, written by the smoke benches in
``scripts/ci.sh``) against the committed ``results/baseline.json`` and
fails (exit 1) when any throughput metric regressed by more than the
tolerance — the hard-won compiled-engine numbers must not silently rot.

Metric keys:

* ``execute:<mode>:<tier>:drops_per_s``  — from bench_execute rows,
* ``translate:<metric name>``            — from bench_translate rows
  (``drops_per_s`` metrics only; us-per-drop rows are latencies, not
  throughputs, and are skipped),
* ``serve:<mode>:<tier>:sessions_per_s`` and
  ``serve:<mode>:<tier>:materialize_speedup`` — from bench_serve rows
  (the resident-manager serving bench).

Two metric classes:

* **floors** (higher is better — every throughput above) must satisfy
  ``current >= baseline * (1 - tolerance)``;
* **ceilings** (lower is better — the baseline's ``ceilings`` section,
  e.g. ``serve:<mode>:<tier>:p99_session_s`` session latency) must
  satisfy ``current <= baseline * (1 + tolerance)``.

Shared rules:

* metrics missing on either side are reported but never fail the gate
  (partial runs — e.g. the 10k CI smoke — are legitimate);
* the comparison (every metric, its delta, and any failures) is written
  to ``results/bench_regression.json`` so CI can upload it as an
  artifact next to the raw results.

The committed baseline is set *conservatively below* locally-measured
throughput (CI runners are slower and noisier than dev machines); the
30% default tolerance then catches real order-of-magnitude regressions
— an accidental de-vectorisation, a quadratic loop — not machine jitter.

Usage:
  python scripts/check_bench.py                    # gate with defaults
  python scripts/check_bench.py --tolerance 0.5
  python scripts/check_bench.py --write-baseline   # refresh baseline
                                                   # from current results
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

ROOT = Path(__file__).resolve().parents[1]
RESULTS_DIR = ROOT / "results"
BASELINE_PATH = RESULTS_DIR / "baseline.json"
REPORT_PATH = RESULTS_DIR / "bench_regression.json"
DEFAULT_TOLERANCE = 0.30


def _warn(msg: str) -> None:
    print(f"check_bench: warning: {msg}", file=sys.stderr)


def execute_metrics(path: Path) -> Dict[str, float]:
    """``execute:<mode>:<tier>:drops_per_s`` from a bench_execute JSON.

    Malformed rows (missing ``mode``/``tier``, non-numeric throughput)
    are warned about and skipped — a truncated or hand-edited results
    file must not crash the gate."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if "drops_per_s" not in r:
            continue
        try:
            out[f"execute:{r['mode']}:{r['tier']}:drops_per_s"] = \
                float(r["drops_per_s"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def translate_metrics(path: Path) -> Dict[str, float]:
    """``translate:<metric>`` throughput rows from a bench_translate
    JSON (higher-is-better ``drops_per_s`` metrics only).

    Malformed rows (missing ``value``, non-numeric value) are warned
    about and skipped rather than crashing the gate."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if "drops_per_s" not in r.get("metric", ""):
            continue
        try:
            out[f"translate:{r['metric']}"] = float(r["value"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def serve_metrics(path: Path) -> Dict[str, float]:
    """Floor metrics from a bench_serve JSON:
    ``serve:<mode>:<tier>:sessions_per_s`` and
    ``serve:<mode>:<tier>:materialize_speedup`` (both higher-is-better).
    Malformed rows are warned about and skipped."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        for field in ("sessions_per_s", "materialize_speedup"):
            if field not in r:
                continue
            try:
                out[f"serve:{r['mode']}:{r['tier']}:{field}"] = \
                    float(r[field])
            except (KeyError, TypeError, ValueError) as exc:
                _warn(f"skipping malformed row {i} in {path.name}: "
                      f"{exc!r}")
    return out


def serve_ceilings(path: Path) -> Dict[str, float]:
    """Ceiling (lower-is-better) metrics from a bench_serve JSON:
    ``serve:<mode>:<tier>:p99_session_s`` session latency."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if "p99_session_s" not in r:
            continue
        try:
            out[f"serve:{r['mode']}:{r['tier']}:p99_session_s"] = \
                float(r["p99_session_s"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def telemetry_ceilings(path: Path) -> Dict[str, float]:
    """Ceiling metrics from bench_execute telemetry rows:
    ``execute:telemetry:<tier>:overhead_pct`` — the instrumented-vs-clean
    execute tax, which must stay low (ISSUE 8 bar: ≤10% effective)."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if r.get("mode") != "telemetry" \
                or "telemetry_overhead_pct" not in r:
            continue
        try:
            out[f"execute:telemetry:{r['tier']}:overhead_pct"] = \
                float(r["telemetry_overhead_pct"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def streaming_metrics(path: Path) -> Dict[str, float]:
    """Floor metrics from bench_execute streaming rows:
    ``execute:streaming:<tier>:overlap_fraction`` — the share of
    consumer chunk-processing time overlapping producer execution.
    Higher is better; the committed floor enforces the ISSUE 9 bar of
    ≥ 0.3 effective overlap (floor x (1 - tolerance))."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if r.get("mode") != "streaming" or "overlap_fraction" not in r:
            continue
        try:
            out[f"execute:streaming:{r['tier']}:overlap_fraction"] = \
                float(r["overlap_fraction"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def multiproc_metrics(path: Path) -> Dict[str, float]:
    """Floor metrics from bench_execute multiproc rows:
    ``execute:multiproc:<tier>:proc_speedup`` — process-over-thread
    throughput on GIL-bound work.  (The tier's ``drops_per_s`` floor is
    collected by the generic :func:`execute_metrics` pass.)  The
    committed floor is calibrated from measurement on the CI box — on a
    single-core runner both worker modes time-slice one CPU and parity
    (~1.0) is the physical ceiling; on >=4 free cores expect >=2x and
    raise the floor."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if r.get("mode") != "multiproc" or "proc_speedup" not in r:
            continue
        try:
            out[f"execute:multiproc:{r['tier']}:proc_speedup"] = \
                float(r["proc_speedup"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def multiproc_ceilings(path: Path) -> Dict[str, float]:
    """Ceiling metrics from bench_execute multiproc rows:
    ``execute:multiproc:<tier>:pickled_array_values`` — array values
    that fell off the shared-memory plane onto pickle.  The baseline is
    0.0: any pickled array is a zero-copy regression."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if r.get("mode") != "multiproc" \
                or "pickled_array_values" not in r:
            continue
        try:
            out[f"execute:multiproc:{r['tier']}:pickled_array_values"] = \
                float(r["pickled_array_values"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def collect_current(results_dir: Path = RESULTS_DIR) -> Dict[str, float]:
    out = execute_metrics(results_dir / "bench_execute.json")
    out.update(translate_metrics(results_dir / "bench_translate.json"))
    out.update(serve_metrics(results_dir / "bench_serve.json"))
    out.update(streaming_metrics(results_dir / "bench_execute.json"))
    out.update(multiproc_metrics(results_dir / "bench_execute.json"))
    return out


def collect_ceilings(results_dir: Path = RESULTS_DIR) -> Dict[str, float]:
    """Lower-is-better metrics, kept separate from the floor dict so a
    number can never be gated in the wrong direction."""
    out = serve_ceilings(results_dir / "bench_serve.json")
    out.update(telemetry_ceilings(results_dir / "bench_execute.json"))
    out.update(multiproc_ceilings(results_dir / "bench_execute.json"))
    return out


def compare(current: Dict[str, float], baseline: Dict[str, float],
            tolerance: float,
            ceil_current: Optional[Dict[str, float]] = None,
            ceil_baseline: Optional[Dict[str, float]] = None
            ) -> Dict[str, object]:
    """Gate ``current`` against ``baseline``; returns the full report.

    ``baseline`` holds floors (higher is better); ``ceil_baseline``
    holds ceilings (lower is better, e.g. p99 latency), gated against
    ``ceil_current`` with the inverted rule
    ``current <= baseline * (1 + tolerance)``."""
    checked: List[Dict[str, object]] = []
    failures: List[Dict[str, object]] = []

    def _check(base_map: Dict[str, float], cur_map: Dict[str, float],
               kind: str) -> None:
        for key in sorted(base_map):
            base = float(base_map[key])
            cur = cur_map.get(key)
            if cur is None:
                checked.append({"metric": key, "kind": kind,
                                "baseline": base, "current": None,
                                "status": "missing"})
                continue
            if kind == "ceiling":
                bound = base * (1.0 + tolerance)
                ok = cur <= bound
            else:
                bound = base * (1.0 - tolerance)
                ok = cur >= bound
            ratio = cur / base if base else float("inf")
            row: Dict[str, object] = {
                "metric": key, "kind": kind, "baseline": base,
                "current": cur, "bound": round(bound, 4),
                "ratio": round(ratio, 4),
                "status": "ok" if ok else "regressed",
            }
            checked.append(row)
            if not ok:
                failures.append(row)

    _check(baseline, current, "floor")
    _check(ceil_baseline or {}, ceil_current or {}, "ceiling")
    extra = sorted((set(current) - set(baseline))
                   | (set(ceil_current or {}) - set(ceil_baseline or {})))
    return {"tolerance": tolerance, "checked": checked,
            "failures": failures, "unbaselined": extra}


def write_baseline(current: Dict[str, float],
                   path: Path = BASELINE_PATH,
                   headroom: float = 0.5,
                   ceilings: Optional[Dict[str, float]] = None) -> None:
    """Refresh the committed baseline from current results, discounted by
    ``headroom`` so slower CI machines don't trip the gate.  Floors are
    discounted down; ceilings (lower-is-better latencies) are inflated
    up by the same headroom."""
    metrics = {k: round(v * (1.0 - headroom), 1)
               for k, v in sorted(current.items())}
    doc = {
        "comment": "bench-regression floors (scripts/check_bench.py);"
                   " values are measured throughput discounted by"
                   f" {headroom:.0%} machine headroom"
                   " (ceilings: measured latency inflated by the same)",
        "metrics": metrics,
    }
    if ceilings:
        doc["ceilings"] = {k: round(v * (1.0 + headroom), 4)
                           for k, v in sorted(ceilings.items())}
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
    print(f"# wrote {path} ({len(metrics)} floors, "
          f"{len(ceilings or {})} ceilings)")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH)
    ap.add_argument("--results-dir", type=Path, default=RESULTS_DIR)
    ap.add_argument("--report", type=Path, default=REPORT_PATH)
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional drop vs baseline "
                         f"(default: baseline file's, else "
                         f"{DEFAULT_TOLERANCE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current results "
                         "instead of gating")
    ap.add_argument("--headroom", type=float, default=0.5,
                    help="fractional discount applied when writing the "
                         "baseline (CI machines are slower than dev)")
    args = ap.parse_args(argv)

    current = collect_current(args.results_dir)
    ceilings = collect_ceilings(args.results_dir)
    if args.write_baseline:
        if not current:
            print("check_bench: no current results to baseline from",
                  file=sys.stderr)
            return 2
        write_baseline(current, args.baseline, headroom=args.headroom,
                       ceilings=ceilings)
        return 0

    if not args.baseline.exists():
        print(f"check_bench: no baseline at {args.baseline} — run "
              f"--write-baseline after a bench pass", file=sys.stderr)
        return 2
    with open(args.baseline) as fh:
        base_doc = json.load(fh)
    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(base_doc.get("tolerance", DEFAULT_TOLERANCE))
    report = compare(current, base_doc.get("metrics", {}), tolerance,
                     ceil_current=ceilings,
                     ceil_baseline=base_doc.get("ceilings", {}))
    for row in report["checked"]:                     # type: ignore[index]
        if row["status"] == "missing":
            _warn(f"baseline floor {row['metric']!r} has no matching "
                  "tier in current results; skipping it")

    args.report.parent.mkdir(parents=True, exist_ok=True)
    with open(args.report, "w") as fh:
        json.dump(report, fh, indent=2)

    for row in report["checked"]:                     # type: ignore[index]
        cur = row["current"]
        kind = row.get("kind", "floor")
        sign = 1.0 if kind == "ceiling" else -1.0
        bound = float(row["baseline"]) * (1 + sign * tolerance)
        print(f"{row['status']:>9}  {row['metric']}: "
              f"{'-' if cur is None else f'{cur:,.4g}'} "
              f"({kind} {bound:,.4g})")
    failures = report["failures"]                     # type: ignore[index]
    if failures:
        print(f"check_bench: {len(failures)} metric(s) regressed more "
              f"than {tolerance:.0%} vs {args.baseline} "
              f"(report: {args.report})", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(report['checked'])} metrics, "  # type: ignore[arg-type]
          f"tolerance {tolerance:.0%}; report: {args.report})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
