#!/usr/bin/env python
"""CI bench-regression gate.

Compares fresh benchmark JSON (``results/bench_execute.json`` and
``results/bench_translate.json``, written by the smoke benches in
``scripts/ci.sh``) against the committed ``results/baseline.json`` and
fails (exit 1) when any throughput metric regressed by more than the
tolerance — the hard-won compiled-engine numbers must not silently rot.

Metric keys:

* ``execute:<mode>:<tier>:drops_per_s``  — from bench_execute rows,
* ``translate:<metric name>``            — from bench_translate rows
  (``drops_per_s`` metrics only; us-per-drop rows are latencies, not
  throughputs, and are skipped).

Rules:

* a metric present in both current results and baseline must satisfy
  ``current >= baseline * (1 - tolerance)``;
* metrics missing on either side are reported but never fail the gate
  (partial runs — e.g. the 10k CI smoke — are legitimate);
* the comparison (every metric, its delta, and any failures) is written
  to ``results/bench_regression.json`` so CI can upload it as an
  artifact next to the raw results.

The committed baseline is set *conservatively below* locally-measured
throughput (CI runners are slower and noisier than dev machines); the
30% default tolerance then catches real order-of-magnitude regressions
— an accidental de-vectorisation, a quadratic loop — not machine jitter.

Usage:
  python scripts/check_bench.py                    # gate with defaults
  python scripts/check_bench.py --tolerance 0.5
  python scripts/check_bench.py --write-baseline   # refresh baseline
                                                   # from current results
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

ROOT = Path(__file__).resolve().parents[1]
RESULTS_DIR = ROOT / "results"
BASELINE_PATH = RESULTS_DIR / "baseline.json"
REPORT_PATH = RESULTS_DIR / "bench_regression.json"
DEFAULT_TOLERANCE = 0.30


def _warn(msg: str) -> None:
    print(f"check_bench: warning: {msg}", file=sys.stderr)


def execute_metrics(path: Path) -> Dict[str, float]:
    """``execute:<mode>:<tier>:drops_per_s`` from a bench_execute JSON.

    Malformed rows (missing ``mode``/``tier``, non-numeric throughput)
    are warned about and skipped — a truncated or hand-edited results
    file must not crash the gate."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if "drops_per_s" not in r:
            continue
        try:
            out[f"execute:{r['mode']}:{r['tier']}:drops_per_s"] = \
                float(r["drops_per_s"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def translate_metrics(path: Path) -> Dict[str, float]:
    """``translate:<metric>`` throughput rows from a bench_translate
    JSON (higher-is-better ``drops_per_s`` metrics only).

    Malformed rows (missing ``value``, non-numeric value) are warned
    about and skipped rather than crashing the gate."""
    if not path.exists():
        return {}
    with open(path) as fh:
        rows = json.load(fh).get("rows", [])
    out: Dict[str, float] = {}
    for i, r in enumerate(rows):
        if "drops_per_s" not in r.get("metric", ""):
            continue
        try:
            out[f"translate:{r['metric']}"] = float(r["value"])
        except (KeyError, TypeError, ValueError) as exc:
            _warn(f"skipping malformed row {i} in {path.name}: {exc!r}")
    return out


def collect_current(results_dir: Path = RESULTS_DIR) -> Dict[str, float]:
    out = execute_metrics(results_dir / "bench_execute.json")
    out.update(translate_metrics(results_dir / "bench_translate.json"))
    return out


def compare(current: Dict[str, float], baseline: Dict[str, float],
            tolerance: float) -> Dict[str, object]:
    """Gate ``current`` against ``baseline``; returns the full report."""
    checked: List[Dict[str, object]] = []
    failures: List[Dict[str, object]] = []
    for key in sorted(baseline):
        base = float(baseline[key])
        cur = current.get(key)
        if cur is None:
            checked.append({"metric": key, "baseline": base,
                            "current": None, "status": "missing"})
            continue
        floor = base * (1.0 - tolerance)
        ratio = cur / base if base else float("inf")
        row: Dict[str, object] = {
            "metric": key, "baseline": base, "current": cur,
            "ratio": round(ratio, 4),
            "status": "ok" if cur >= floor else "regressed",
        }
        checked.append(row)
        if cur < floor:
            failures.append(row)
    extra = sorted(set(current) - set(baseline))
    return {"tolerance": tolerance, "checked": checked,
            "failures": failures, "unbaselined": extra}


def write_baseline(current: Dict[str, float],
                   path: Path = BASELINE_PATH,
                   headroom: float = 0.5) -> None:
    """Refresh the committed baseline from current results, discounted by
    ``headroom`` so slower CI machines don't trip the gate."""
    metrics = {k: round(v * (1.0 - headroom), 1)
               for k, v in sorted(current.items())}
    with open(path, "w") as fh:
        json.dump({
            "comment": "bench-regression floors (scripts/check_bench.py);"
                       " values are measured throughput discounted by"
                       f" {headroom:.0%} machine headroom",
            "metrics": metrics,
        }, fh, indent=2)
    print(f"# wrote {path} ({len(metrics)} metrics)")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH)
    ap.add_argument("--results-dir", type=Path, default=RESULTS_DIR)
    ap.add_argument("--report", type=Path, default=REPORT_PATH)
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional drop vs baseline "
                         f"(default: baseline file's, else "
                         f"{DEFAULT_TOLERANCE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current results "
                         "instead of gating")
    ap.add_argument("--headroom", type=float, default=0.5,
                    help="fractional discount applied when writing the "
                         "baseline (CI machines are slower than dev)")
    args = ap.parse_args(argv)

    current = collect_current(args.results_dir)
    if args.write_baseline:
        if not current:
            print("check_bench: no current results to baseline from",
                  file=sys.stderr)
            return 2
        write_baseline(current, args.baseline, headroom=args.headroom)
        return 0

    if not args.baseline.exists():
        print(f"check_bench: no baseline at {args.baseline} — run "
              f"--write-baseline after a bench pass", file=sys.stderr)
        return 2
    with open(args.baseline) as fh:
        base_doc = json.load(fh)
    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(base_doc.get("tolerance", DEFAULT_TOLERANCE))
    report = compare(current, base_doc.get("metrics", {}), tolerance)
    for row in report["checked"]:                     # type: ignore[index]
        if row["status"] == "missing":
            _warn(f"baseline floor {row['metric']!r} has no matching "
                  "tier in current results; skipping it")

    args.report.parent.mkdir(parents=True, exist_ok=True)
    with open(args.report, "w") as fh:
        json.dump(report, fh, indent=2)

    for row in report["checked"]:                     # type: ignore[index]
        cur = row["current"]
        print(f"{row['status']:>9}  {row['metric']}: "
              f"{'-' if cur is None else f'{cur:,.1f}'} "
              f"(floor {float(row['baseline']) * (1 - tolerance):,.1f})")
    failures = report["failures"]                     # type: ignore[index]
    if failures:
        print(f"check_bench: {len(failures)} metric(s) regressed more "
              f"than {tolerance:.0%} vs {args.baseline} "
              f"(report: {args.report})", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(report['checked'])} metrics, "  # type: ignore[arg-type]
          f"tolerance {tolerance:.0%}; report: {args.report})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
