#!/usr/bin/env bash
# Tier-1 CI: full test suite + a translate-throughput smoke tier.
#
#   ./scripts/ci.sh            # tests + smoke bench
#   SKIP_BENCH=1 ./scripts/ci.sh   # tests only
#
# Dev deps (optional; the suite collects cleanly without hypothesis):
#   pip install -r requirements-dev.txt
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ -z "${SKIP_BENCH:-}" ]]; then
    echo "== translate smoke bench (width 10000) =="
    python benchmarks/bench_translate.py --width 10000
    echo "== execute smoke bench (10k drops, objects vs compiled) =="
    python benchmarks/bench_execute.py --tiers 10000
    echo "== recovery smoke bench (10k drops, kill 1 of 8 nodes at 50%) =="
    python benchmarks/bench_execute.py --tier recovery --tiers 10000
fi
