#!/usr/bin/env bash
# Tier-1 CI: full test suite + smoke benches + the bench-regression gate.
#
#   ./scripts/ci.sh                 # tests + smoke benches + gate
#   SKIP_BENCH=1 ./scripts/ci.sh    # tests only (CI "tier1" job)
#   ONLY_BENCH=1 ./scripts/ci.sh    # benches + gate only (CI "bench" job)
#
# Dev deps (optional; the suite collects cleanly without hypothesis):
#   pip install -r requirements-dev.txt
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# reproducible property runs: the "ci" profile (tests/conftest.py) pins
# hypothesis to derandomized examples, so red CI is re-runnable locally
export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-ci}"

if [[ -z "${ONLY_BENCH:-}" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

if [[ -z "${SKIP_BENCH:-}" ]]; then
    echo "== translate smoke bench (width 10000) =="
    python benchmarks/bench_translate.py --width 10000
    echo "== translate loop smoke bench (20 iters x 500 drops/iter) =="
    python benchmarks/bench_translate.py --loop --loop-iters 20 \
        --loop-drops-per-iter 500
    echo "== execute smoke bench (10k drops, objects vs compiled) =="
    python benchmarks/bench_execute.py --tiers 10000
    echo "== execute 10M-drop tier (compiled only; substrate capacity) =="
    python benchmarks/bench_execute.py --tiers 10000000 \
        --max-object-drops 100000
    echo "== recovery smoke bench (10k drops, kill 1 of 8 nodes at 50%) =="
    python benchmarks/bench_execute.py --tier recovery --tiers 10000
    echo "== telemetry overhead bench (100k + 1M, exports Perfetto traces) =="
    python benchmarks/bench_execute.py --telemetry --tiers 100000 1000000
    echo "== streaming overlap bench (chunk lane; exports Perfetto trace) =="
    python benchmarks/bench_execute.py --tier streaming --tiers 1000
    echo "== serve smoke bench (10k drops, resident manager sessions/s) =="
    python benchmarks/bench_serve.py --tiers 10000
    echo "== multiproc bench (threads vs process workers, shm plane, SIGKILL recovery) =="
    python benchmarks/bench_execute.py --tier multiproc
    echo "== bench-regression gate (results vs results/baseline.json) =="
    python scripts/check_bench.py
fi
