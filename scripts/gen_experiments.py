"""Assemble EXPERIMENTS.md from dry-run JSONs + logs.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
"""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
RESULTS = ROOT / "results" / "dryrun"

from repro.models.common import SHAPES  # noqa: E402


def load(mesh, variant=None):
    rows = {}
    for p in sorted(RESULTS.glob(f"*__{mesh}*.json")):
        rec = json.loads(p.read_text())
        if variant is None and rec.get("variant", "baseline") != "baseline":
            continue
        if variant is not None and rec.get("variant") != variant:
            continue
        rows[(rec["arch"], rec["shape"])] = rec
    return rows


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(mesh):
    rows = load(mesh)
    lines = [
        "| arch | shape | status | chips | compile s | per-dev GB (args+temp)"
        " | HLO GFLOPs/dev | collective MB/dev (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape) in sorted(rows):
        rec = rows[(arch, shape)]
        if rec["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skipped (see DESIGN.md) "
                         "| — | — | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {arch} | {shape} | **ERROR** | — | — | — | — "
                         f"| {rec.get('error','')[:60]} |")
            continue
        m = rec["memory"]
        c = rec.get("collectives", {})
        coll = "/".join(
            f"{c.get(k, 0)/1e6:.0f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"))
        flops = rec.get("cost", {}).get("flops", 0) / 1e9
        lines.append(
            f"| {arch} | {shape} | ok | {rec['chips']} "
            f"| {rec.get('compile_s', 0):.0f} "
            f"| {fmt_bytes(m.get('per_device_bytes', 0))} "
            f"| {flops:,.0f} | {coll} |")
    return "\n".join(lines)


def roofline_table():
    rows = load("single")
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| roofline frac | MODEL/HLO | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        ("moe", "train"): "shard experts (EP all-to-all) / sequence-parallel"
                          " residual to kill the combine all-reduce",
        ("moe", "prefill"): "fuse attention (flash kernel holds logits in "
                            "VMEM); dispatch buffers in bf16",
        ("dense", "train"): "fused attention + remat policy keeps logits/"
                            "scores out of HBM",
        ("dense", "prefill"): "flash attention kernel (scores never hit "
                              "HBM)",
        ("ssm", "train"): "use the whole mesh as DP (dp_all) — model axis "
                          "idles; then SSD kernel keeps chunk tensors in "
                          "VMEM",
        ("hybrid", "train"): "same as ssm: dp_all; SSD kernel",
        ("ssm", "decode"): "decode is weight-streaming bound: batch up / "
                           "quantise weights",
        ("hybrid", "decode"): "weight-streaming bound: batch up / quantise",
    }
    for (arch, shape) in sorted(rows):
        rec = rows[(arch, shape)]
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        fam = rec["family"]
        kind = rec["kind"]
        note = notes.get((fam, kind),
                         "batch 1 token/seq: weight+cache streaming bound — "
                         "batch more sequences or quantise"
                         if kind == "decode" else
                         "fused attention + activation sharding")
        lines.append(
            f"| {arch} | {shape} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['roofline_fraction']:.3f} | {r['useful_fraction']:.3f} | "
            f"{note} |")
    return "\n".join(lines)


def variant_rows(arch, shape, variants):
    out = []
    base = load("single").get((arch, shape))
    rows = [("baseline", base)]
    for v in variants:
        rec = load("single", variant=v).get((arch, shape))
        rows.append((v, rec))
    for name, rec in rows:
        if rec is None or rec.get("status") != "ok":
            out.append(f"| {name} | — | — | — | — | — | (missing) |")
            continue
        r = rec["roofline"]
        m = rec["memory"]
        out.append(
            f"| {name} | {r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | {r['dominant'].replace('_s','')} | "
            f"{r['useful_fraction']:.3f} | "
            f"{fmt_bytes(m.get('per_device_bytes', 0))} GB |")
    return "\n".join(out)


def main():
    tmpl = (ROOT / "scripts" / "experiments_template.md").read_text()
    out = tmpl
    out = out.replace("{{DRYRUN_SINGLE}}", dryrun_table("single"))
    out = out.replace("{{DRYRUN_MULTI}}", dryrun_table("multi"))
    out = out.replace("{{ROOFLINE}}", roofline_table())
    out = out.replace("{{VAR_MAMBA}}", variant_rows(
        "mamba2_1_3b", "train_4k",
        ["dp_all", "dp_all+nm1", "dp_all+nm1+chunk128"]))
    out = out.replace("{{VAR_GRANITE}}", variant_rows(
        "granite_moe_3b_a800m", "train_4k",
        ["sp", "dp_all+nm1", "dp_all+nm1+cf1.0",
         "dp_all+nm1+cf1.0+pin"]))
    out = out.replace("{{VAR_GROK}}", variant_rows(
        "grok_1_314b", "train_4k", ["sp", "ep", "ep+nm4", "sp+nm4"]))
    (ROOT / "EXPERIMENTS.md").write_text(out)
    print("EXPERIMENTS.md written",
          len(out.splitlines()), "lines")


if __name__ == "__main__":
    main()
