"""Per-kernel shape/dtype sweeps against the jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_bhsd


def rnd(key, shape, dtype, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape,
                              jnp.float32) * scale).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
        (1, 1, 1, 32, 32, 16),
        (2, 4, 2, 64, 64, 32),       # GQA 2:1
        (1, 8, 2, 128, 128, 64),     # GQA 4:1
        (2, 2, 2, 48, 80, 32),       # non-square, non-block-multiple
        (1, 4, 4, 17, 33, 8),        # ragged (padding path)
    ])
    def test_shapes_vs_oracle(self, b, hq, hkv, sq, sk, d):
        q = rnd(0, (b, hq, sq, d), jnp.float32)
        k = rnd(1, (b, hkv, sk, d), jnp.float32)
        v = rnd(2, (b, hkv, sk, d), jnp.float32)
        out = flash_attention_bhsd(q, k, v, causal=False,
                                   block_q=32, block_k=32)
        want = ref.mha_reference(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("causal,window,cap", [
        (True, 0, 0.0), (True, 16, 0.0), (False, 0, 0.0),
        (True, 0, 30.0), (True, 8, 50.0), (False, 0, 20.0),
    ])
    def test_mask_and_softcap_variants(self, causal, window, cap):
        q = rnd(3, (2, 4, 64, 32), jnp.float32)
        k = rnd(4, (2, 2, 64, 32), jnp.float32)
        v = rnd(5, (2, 2, 64, 32), jnp.float32)
        out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                                   logit_cap=cap, block_q=32, block_k=32)
        want = ref.mha_reference(q, k, v, causal=causal, window=window,
                                 logit_cap=cap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype,atol", [
        (jnp.float32, 2e-5), (jnp.bfloat16, 2e-2),
    ])
    def test_dtypes(self, dtype, atol):
        q = rnd(6, (1, 2, 64, 32), dtype, 0.5)
        k = rnd(7, (1, 2, 64, 32), dtype, 0.5)
        v = rnd(8, (1, 2, 64, 32), dtype, 0.5)
        out = flash_attention_bhsd(q, k, v, block_q=32, block_k=32)
        want = ref.mha_reference(q.astype(jnp.float32),
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want), atol=atol,
            rtol=atol)

    def test_block_size_invariance(self):
        q = rnd(9, (1, 2, 128, 32), jnp.float32)
        k = rnd(10, (1, 2, 128, 32), jnp.float32)
        v = rnd(11, (1, 2, 128, 32), jnp.float32)
        o1 = flash_attention_bhsd(q, k, v, block_q=32, block_k=32)
        o2 = flash_attention_bhsd(q, k, v, block_q=64, block_k=128)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)

    def test_under_jit(self):
        q = rnd(12, (1, 2, 64, 16), jnp.float32)
        k = rnd(13, (1, 1, 64, 16), jnp.float32)
        v = rnd(14, (1, 1, 64, 16), jnp.float32)
        f = jax.jit(lambda a, b, c: flash_attention_bhsd(
            a, b, c, block_q=32, block_k=32))
        np.testing.assert_allclose(
            np.asarray(f(q, k, v)),
            np.asarray(ref.mha_reference(q, k, v)), atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("b,h,s,p,n,chunk", [
        (1, 1, 32, 8, 4, 8),
        (2, 3, 64, 16, 8, 16),
        (1, 2, 128, 32, 16, 32),
        (2, 1, 64, 8, 8, 64),        # single chunk
    ])
    def test_shapes_vs_oracle(self, b, h, s, p, n, chunk):
        x = rnd(0, (b, h, s, p), jnp.float32, 0.5)
        dt = jax.nn.softplus(rnd(1, (b, h, s), jnp.float32))
        a = -jnp.exp(rnd(2, (h,), jnp.float32, 0.3))
        bb = rnd(3, (b, h, s, n), jnp.float32, 0.5)
        cc = rnd(4, (b, h, s, n), jnp.float32, 0.5)
        y, st = ssd_scan_bhsd(x, dt, a, bb, cc, chunk)
        yr, str_ = ref.ssd_reference(x, dt, a, bb, cc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                                   atol=2e-4, rtol=2e-4)

    def test_chunk_invariance(self):
        x = rnd(5, (1, 2, 64, 8), jnp.float32, 0.5)
        dt = jax.nn.softplus(rnd(6, (1, 2, 64), jnp.float32))
        a = -jnp.exp(rnd(7, (2,), jnp.float32, 0.3))
        bb = rnd(8, (1, 2, 64, 4), jnp.float32, 0.5)
        cc = rnd(9, (1, 2, 64, 4), jnp.float32, 0.5)
        y1, s1 = ssd_scan_bhsd(x, dt, a, bb, cc, 8)
        y2, s2 = ssd_scan_bhsd(x, dt, a, bb, cc, 32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=2e-4, rtol=2e-4)

    def test_bf16(self):
        x = rnd(10, (1, 2, 32, 8), jnp.bfloat16, 0.5)
        dt = jax.nn.softplus(rnd(11, (1, 2, 32), jnp.float32))
        a = -jnp.exp(rnd(12, (2,), jnp.float32, 0.3))
        bb = rnd(13, (1, 2, 32, 4), jnp.bfloat16, 0.5)
        cc = rnd(14, (1, 2, 32, 4), jnp.bfloat16, 0.5)
        y, _ = ssd_scan_bhsd(x, dt, a, bb, cc, 8)
        yr, _ = ref.ssd_reference(x.astype(jnp.float32), dt, a,
                                  bb.astype(jnp.float32),
                                  cc.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr), atol=5e-2, rtol=5e-2)


class TestModelScanAgreement:
    """The associative-scan jnp path must equal the sequential oracle and the
    Pallas kernel — three implementations, one math."""

    def test_three_way_agreement(self):
        from repro.models.ssm import ssd_chunked
        b, h, s, p, n = 2, 4, 64, 8, 4
        x = rnd(20, (b, s, h, p), jnp.float32, 0.5)    # model layout
        dt = jax.nn.softplus(rnd(21, (b, s, h), jnp.float32))
        a = -jnp.exp(rnd(22, (h,), jnp.float32, 0.3))
        bb = rnd(23, (b, s, 1, n), jnp.float32, 0.5)   # one group
        cc = rnd(24, (b, s, 1, n), jnp.float32, 0.5)
        y_model, st_model = ssd_chunked(x, dt, a, bb, cc, chunk=16)
        # oracle layout
        xt = jnp.transpose(x, (0, 2, 1, 3))
        dtt = jnp.transpose(dt, (0, 2, 1))
        bt = jnp.repeat(jnp.transpose(bb, (0, 2, 1, 3)), h, axis=1)
        ct = jnp.repeat(jnp.transpose(cc, (0, 2, 1, 3)), h, axis=1)
        y_ref, st_ref = ref.ssd_reference(xt, dtt, a, bt, ct)
        y_kern, st_kern = ssd_scan_bhsd(xt, dtt, a, bt, ct, 16)
        y_model_t = jnp.transpose(y_model, (0, 2, 1, 3))
        np.testing.assert_allclose(np.asarray(y_model_t),
                                   np.asarray(y_ref), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(y_kern),
                                   np.asarray(y_ref), atol=2e-4, rtol=2e-4)
        # states: model layout (B,H,N,P)
        np.testing.assert_allclose(np.asarray(st_model),
                                   np.asarray(st_ref), atol=2e-4, rtol=2e-4)
