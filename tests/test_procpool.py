"""Process-backed node execution + shared-memory payload plane (PR-10).

``EngineConfig(workers="process")`` gives every node a crash-isolated
spawn worker (``ProcExecutor``) and every island a ``PayloadPlane`` of
``multiprocessing.shared_memory`` segments; the thread-backed compiled
engine and the object engine are the semantic oracles.  Covered here:

* plane wire encoding (raw below threshold, shm descriptor above,
  passthrough cache hits, zero-copy attach, unlink-on-close),
* a full worker mailbox round trip with zero-copy arrays in and out,
* engine equivalence: process mode ≡ objects oracle on an array graph,
* error isolation: a non-picklable app poisons only its own drop,
* clean pool shutdown with no leaked worker processes,
* satellite regressions: ``MemoryPayload.nbytes`` must not pickle
  buffer values; ``NodeDropManager.shutdown`` drains with bounded grace
  and marks sessions FAILED instead of silently abandoning app calls;
  a wedged stream-consumer survives lane shutdown only as a warned,
  *fenced* thread whose stale writes raise ``StreamAbort``.

Apps used by worker processes are module-level: spawn workers resolve
functions by reference (module re-import), so test-local closures are
exactly the "not picklable" failure mode exercised below.
"""
import os
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core import (CompiledSession, EngineConfig, MemoryPayload,
                        PayloadPlane, Pipeline, ProcExecutor,
                        ProcNodeDropManager, SessionState, StreamAbort,
                        StreamConfig, WorkerLost, register_app, unroll)
from repro.core import drop as drop_mod
from repro.core.drop import DropState, buffer_nbytes
from repro.core.managers import NodeDropManager
from repro.core.mapping import NodeInfo
from repro.core.procpool import DEFAULT_SHM_MIN_BYTES
from repro.dsl import GraphBuilder

# 256 KiB of float64 — comfortably above DEFAULT_SHM_MIN_BYTES
ARR_N = 32 * 1024


# ---------------------------------------------------------------------------
# module-level apps (importable by spawn workers)
# ---------------------------------------------------------------------------


@register_app("pp/make")
def pp_make(inputs, outputs, app):
    seed = inputs[0].read() if inputs else 1
    for o in outputs:
        o.write(np.full(ARR_N, float(seed)))


@register_app("pp/scale")
def pp_scale(inputs, outputs, app):
    v = inputs[0].read()
    for o in outputs:
        o.write(v * 2.0)


@register_app("pp/reduce")
def pp_reduce(inputs, outputs, app):
    total = sum(float(np.asarray(i.read()).sum()) for i in inputs)
    for o in outputs:
        o.write(total)


@register_app("pp/double")
def pp_double(inputs, outputs, app):
    v = sum(i.read() for i in inputs) if inputs else 1
    for o in outputs:
        o.write(v * 2)


@register_app("pp/boom")
def pp_boom(inputs, outputs, app):
    raise RuntimeError("scripted worker-side failure")


def array_lg(width=3):
    """Scatter of array producers/scalers, gathered into one scalar."""
    g = GraphBuilder("pp_arrays")
    g.data("src")
    with g.scatter("sc", width):
        g.component("mk", app="pp/make", time=1.0)
        g.data("arr", volume=10)
        g.component("up", app="pp/scale", time=1.0)
        g.data("arr2", volume=10)
    with g.gather("ga", width):
        g.component("r", app="pp/reduce", time=1.0)
    g.data("out")
    g.chain("src", "mk", "arr", "up", "arr2", "r", "out")
    return g.graph()


def chain_lg():
    g = GraphBuilder("pp_chain")
    g.data("src")
    g.component("a1", app="pp/double", time=1.0)
    g.data("d1", volume=10)
    g.component("a2", app="pp/double", time=1.0)
    g.data("out")
    g.chain("src", "a1", "d1", "a2", "out")
    return g.graph()


def _pid_gone(pid, wait=3.0):
    deadline = time.monotonic() + wait
    while time.monotonic() < deadline:
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            return True
        time.sleep(0.02)
    return False


# ---------------------------------------------------------------------------
# PayloadPlane wire encoding (parent-side, no processes)
# ---------------------------------------------------------------------------


class TestPayloadPlane:
    def test_small_and_opaque_values_ship_raw(self):
        plane = PayloadPlane()
        try:
            assert plane.encode(7) == ("raw", 7)
            assert plane.encode({"k": [1, 2]})[0] == "raw"
            # sub-threshold arrays are cheaper to copy than to segment
            small = np.arange(8)
            assert small.nbytes < DEFAULT_SHM_MIN_BYTES
            assert plane.encode(small)[0] == "raw"
            assert plane.stats["raw_values"] == 3
            assert plane.stats["shm_exports"] == 0
        finally:
            plane.close()

    def test_large_array_exports_once_then_passthrough(self):
        plane = PayloadPlane(shm_min_bytes=1024)
        try:
            arr = np.arange(1024, dtype=np.float64)
            tag, desc = plane.encode(arr)
            assert tag == "shm"
            assert plane.stats["shm_exports"] == 1
            # same object again: descriptor cache hit, no second copy
            tag2, desc2 = plane.encode(arr)
            assert (tag2, desc2) == (tag, desc)
            assert plane.stats["shm_passthrough"] == 1
            assert plane.stats["shm_exports"] == 1
            # decode maps the segment zero-copy: two attaches of the same
            # descriptor share one buffer
            a1 = plane.decode((tag, desc))
            a2 = plane.decode((tag, desc))
            np.testing.assert_array_equal(a1, arr)
            assert np.shares_memory(a1, a2)
        finally:
            plane.close()

    def test_close_unlinks_segments(self):
        from multiprocessing.shared_memory import SharedMemory

        plane = PayloadPlane(shm_min_bytes=1024)
        _, (name, _, _) = plane.encode(np.zeros(1024))
        plane.close()
        with pytest.raises(FileNotFoundError):
            SharedMemory(name=name)


# ---------------------------------------------------------------------------
# worker mailbox round trip (one real spawn process)
# ---------------------------------------------------------------------------


class TestProcExecutorRoundTrip:
    def _spec(self, func, idx, uid, inputs, outputs):
        return {"idx": idx, "uid": uid, "func": func, "meta": {},
                "inputs": inputs, "outputs": outputs}

    def test_zero_copy_arrays_in_and_out(self):
        plane = PayloadPlane(shm_min_bytes=1024)
        ex = ProcExecutor("nodeT", plane)
        try:
            arr = np.arange(2048, dtype=np.float64)
            spec = self._spec(pp_scale, 0, "up",
                              [("arr", {}, arr, None)], [(1, "arr2", {})])
            (res,) = ex.run_batch([spec], budget=30.0)
            assert res["status"] == "ok", res.get("tb")
            [(j, out)] = res["writes"]
            assert j == 1
            np.testing.assert_array_equal(out, arr * 2.0)
            # input rode the plane out, the result rode it back
            assert plane.stats["shm_exports"] == 1
            assert plane.stats["shm_results"] == 1
            assert plane.stats["raw_values"] == 0
        finally:
            ex.shutdown()
            plane.close()

    def test_worker_error_reports_traceback(self):
        plane = PayloadPlane()
        ex = ProcExecutor("nodeT", plane)
        try:
            spec = self._spec(pp_boom, 0, "b", [], [(1, "out", {})])
            (res,) = ex.run_batch([spec], budget=30.0)
            assert res["status"] == "err"
            assert "scripted worker-side failure" in res["tb"]
        finally:
            ex.shutdown()
            plane.close()

    def test_killed_worker_raises_worker_lost_and_stays_dead(self):
        plane = PayloadPlane()
        ex = ProcExecutor("nodeT", plane)
        try:
            spec = self._spec(pp_double, 0, "a",
                              [("src", {}, 3, None)], [(1, "out", {})])
            ex.run_batch([spec], budget=30.0)
            ex.kill()
            with pytest.raises(WorkerLost) as ei:
                ex.run_batch([spec], budget=30.0)
            assert ei.value.nodes == ["nodeT"]
            assert ex.dead
            # dead executors fail fast; workers are never respawned
            with pytest.raises(WorkerLost):
                ex.run_batch([spec], budget=30.0)
        finally:
            ex.shutdown()
            plane.close()

    def test_shutdown_leaves_no_process(self):
        plane = PayloadPlane()
        ex = ProcExecutor("nodeT", plane)
        try:
            spec = self._spec(pp_double, 0, "a", [], [(1, "out", {})])
            ex.run_batch([spec], budget=30.0)
            pid = ex.pid
            assert pid is not None
        finally:
            ex.shutdown()
            plane.close()
        assert _pid_gone(pid), f"worker {pid} leaked past shutdown"


# ---------------------------------------------------------------------------
# engine equivalence: workers="process" ≡ objects oracle
# ---------------------------------------------------------------------------


class TestProcessEngineEquivalence:
    def test_array_graph_matches_objects_oracle(self):
        with Pipeline(num_nodes=2, algorithm="none") as p:
            rep = p.run(array_lg(), inputs={"src": 3})
            assert rep.ok, rep.errors
            oracle = {u: d.read() for u, d in p.session.drops.items()
                      if d.state is DropState.COMPLETED
                      and getattr(d, "payload", None) is not None
                      and d.payload.exists()}
        with Pipeline(num_nodes=2, algorithm="none", execution="compiled",
                      workers="process") as p:
            rep = p.run(array_lg(), inputs={"src": 3})
            assert rep.ok, rep.errors
            nms = p.master.node_managers()
            assert all(isinstance(nm, ProcNodeDropManager)
                       for nm in nms.values())
            s = p.session
            for u, want in oracle.items():
                got = s.read(u)
                if isinstance(want, np.ndarray):
                    np.testing.assert_array_equal(got, want)
                else:
                    assert got == want, u
            # array edges actually used the plane (not pickle): every
            # node of the island shares one plane and it saw shm traffic
            planes = {id(nm.plane): nm.plane for nm in nms.values()}
            assert len(planes) == 1
            st = next(iter(planes.values())).stats
            assert st["shm_exports"] + st["shm_results"] > 0
            pids = [nm.executor.pid for nm in nms.values()
                    if nm.executor.pid is not None]
            assert pids, "no worker process was ever spawned"
        # context exit shut the cluster down: nothing may leak
        for pid in pids:
            assert _pid_gone(pid), f"worker {pid} leaked past shutdown"

    def test_worker_app_error_isolated_to_drop(self):
        g = GraphBuilder("pp_err")
        g.data("src")
        g.component("good", app="pp/double", time=1.0)
        g.data("gout")
        g.chain("src", "good", "gout")
        g.component("bad", app="pp/boom", time=1.0)
        g.data("bout")
        g.chain("src", "bad", "bout")
        for workers in ("thread", "process"):
            with Pipeline(num_nodes=2, algorithm="none",
                          execution="compiled", workers=workers) as p:
                rep = p.run(g.graph(), inputs={"src": 2})
                assert not rep.ok
                s = p.session
                assert s.state_of("bad") is DropState.ERROR
                assert s.state_of("good") is DropState.COMPLETED
                assert s.read("gout") == 4

    def test_unpicklable_app_poisons_only_its_drop(self):
        # a test-local closure pickles by reference and the reference
        # cannot resolve — the canonical "app not shippable" failure
        @register_app("pp/local-closure")
        def _local(inputs, outputs, app):      # pragma: no cover - parent
            for o in outputs:                  # rejects it before dispatch
                o.write("never")

        g = GraphBuilder("pp_unpick")
        g.data("src")
        g.component("good", app="pp/double", time=1.0)
        g.data("gout")
        g.chain("src", "good", "gout")
        g.component("bad", app="pp/local-closure", time=1.0)
        g.data("bout")
        g.chain("src", "bad", "bout")
        with Pipeline(num_nodes=2, algorithm="none", execution="compiled",
                      workers="process") as p:
            rep = p.run(g.graph(), inputs={"src": 2})
            assert not rep.ok
            s = p.session
            assert s.state_of("bad") is DropState.ERROR
            assert "not picklable" in s.error_info.get(
                s.index_of("bad"), "")
            assert s.state_of("good") is DropState.COMPLETED
            assert s.read("gout") == 4


# ---------------------------------------------------------------------------
# satellite: MemoryPayload.nbytes must not serialise buffer values
# ---------------------------------------------------------------------------


class _NoPickle:
    HIGHEST_PROTOCOL = pickle.HIGHEST_PROTOCOL

    @staticmethod
    def dumps(*a, **k):
        raise AssertionError("nbytes serialised a buffer-protocol value")

    loads = staticmethod(pickle.loads)


class TestMemoryPayloadNbytes:
    def test_100mb_buffer_sized_without_pickle(self, monkeypatch):
        monkeypatch.setattr(drop_mod, "pickle", _NoPickle)
        pl = MemoryPayload()
        pl.write(bytearray(100 * 2**20))
        assert pl.nbytes() == 100 * 2**20

    def test_ndarray_and_bytes_sized_without_pickle(self, monkeypatch):
        monkeypatch.setattr(drop_mod, "pickle", _NoPickle)
        pl = MemoryPayload()
        pl.write(np.zeros((256, 256)))
        assert pl.nbytes() == 256 * 256 * 8
        pl.write(b"x" * 4096)
        assert pl.nbytes() == 4096

    def test_opaque_values_still_fall_back_to_pickle(self):
        pl = MemoryPayload()
        val = {"k": list(range(100))}
        pl.write(val)
        assert pl.nbytes() == len(
            pickle.dumps(val, protocol=pickle.HIGHEST_PROTOCOL))
        assert buffer_nbytes(val) is None


# ---------------------------------------------------------------------------
# satellite: NodeDropManager.shutdown drains, then fails open sessions
# ---------------------------------------------------------------------------


class _SessionStub:
    session_id = "s-stub"

    def __init__(self):
        self.reasons = []

    def fail(self, reason):
        self.reasons.append(reason)


class TestShutdownDrain:
    def test_fast_inflight_work_drains_cleanly(self):
        nm = NodeDropManager(NodeInfo(name="nodeX", island="island0"))
        stub = _SessionStub()
        nm._session_refs[stub.session_id] = stub
        fut = nm.executor.submit(time.sleep, 0.05)
        nm.shutdown()
        assert fut.done()
        assert stub.reasons == []

    def test_wedged_work_bounded_and_session_failed(self, monkeypatch):
        monkeypatch.setattr(NodeDropManager, "SHUTDOWN_GRACE_S", 0.2)
        nm = NodeDropManager(NodeInfo(name="nodeX", island="island0"))
        stub = _SessionStub()
        nm._session_refs[stub.session_id] = stub
        release = threading.Event()
        nm.executor.submit(release.wait)
        t0 = time.monotonic()
        nm.shutdown()
        elapsed = time.monotonic() - t0
        release.set()
        assert elapsed < 3.0, "shutdown must not block unboundedly"
        assert len(stub.reasons) == 1
        assert "in-flight" in stub.reasons[0]
        assert "nodeX" in stub.reasons[0]

    def test_compiled_session_fail_is_terminal_and_sticky(self):
        pgt = unroll(chain_lg())
        s = CompiledSession("s-fail", pgt)
        s.fail("boom")
        assert s.state is SessionState.FAILED
        assert s.error_reason == "boom"
        assert s.wait(0.5)                  # fail() releases waiters
        s.fail("later")                     # terminal: no-op
        assert s.error_reason == "boom"

    def test_pipeline_shutdown_marks_real_session_failed(self, monkeypatch):
        monkeypatch.setattr(NodeDropManager, "SHUTDOWN_GRACE_S", 0.2)
        started, release = threading.Event(), threading.Event()

        @register_app("pp/block")
        def _block(inputs, outputs, app):
            started.set()
            release.wait(20)
            for o in outputs:
                o.write(1)

        # two blocking apps spread over two nodes: single-batch waves run
        # inline on the wave-loop thread, so only multi-node waves
        # exercise the executor drain being tested here
        g = GraphBuilder("pp_block")
        g.data("src")
        for i in range(2):
            g.component(f"b{i}", app="pp/block", time=1.0)
            g.data(f"out{i}")
            g.chain("src", f"b{i}", f"out{i}")
        p = Pipeline(num_nodes=2, algorithm="none", execution="compiled")
        p.translate(g.graph())
        p.deploy()

        def _run():
            try:
                p.execute(timeout=20, inputs={"src": 1})
            except Exception:
                pass  # executor torn down under the wave loop

        t = threading.Thread(target=_run, daemon=True)
        t.start()
        try:
            assert started.wait(5), "app never started"
            p.shutdown()
            assert p.session.state is SessionState.FAILED
            assert "in-flight" in (p.session.error_reason or "")
        finally:
            release.set()
            t.join(5)


# ---------------------------------------------------------------------------
# satellite: wedged stream consumers are warned about and fenced
# ---------------------------------------------------------------------------


class TestStreamLaneFence:
    def test_wedged_consumer_warned_and_stale_write_fenced(self):
        wedged, release = threading.Event(), threading.Event()
        aborted = []

        def _fin(inputs, outputs, app):
            for o in outputs:
                o.write("done")

        @register_app("pp/wedge", streaming=True, finish=_fin)
        def _wedge(value, app):
            wedged.set()
            release.wait(20)
            try:
                app.outputs[0].write(("stale", value))
            except StreamAbort as exc:
                aborted.append(str(exc))
                raise

        @register_app("pp/emit")
        def _emit(inputs, outputs, app):
            for i in range(3):
                for o in outputs:
                    o.write((i, i))

        g = GraphBuilder("pp_fence")
        g.data("src")
        g.component("P", app="pp/emit")
        g.data("d")
        g.component("C", app="pp/wedge")
        g.data("out")
        g.chain("src", "P", "d")
        g.connect("d", "C", streaming=True)
        g.chain("C", "out")

        cfg = EngineConfig(execution="compiled", num_nodes=1,
                           stream=StreamConfig(shutdown_grace_s=0.3))
        with Pipeline(cfg) as p:
            with pytest.warns(RuntimeWarning, match="still alive"):
                rep = p.run(g.graph(), timeout=1.0, inputs={"src": 1})
            assert not rep.ok                      # run timed out wedged
            assert wedged.is_set()
            tbl = p.session.stream
            assert tbl is not None and tbl.generation >= 1
            gen_after_fence = tbl.generation
            release.set()
            deadline = time.monotonic() + 5.0
            while not aborted and time.monotonic() < deadline:
                time.sleep(0.01)
            assert aborted, "stale-lane write was not fenced"
            assert "fenced" in aborted[0]
            # the stale write never landed and never bumped the table
            s = p.session
            assert not s.payload_present[s.pgt.index_of("out")]
            assert tbl.generation == gen_after_fence
