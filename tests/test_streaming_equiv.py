"""Chunk-granular streaming on the compiled engine (the PR-9 contract).

The object engine delivers every ``DataDrop.write`` to streaming
consumers synchronously (§4/Fig. 10) and is the semantic oracle; the
compiled chunk lane (ring buffers + per-consumer drain threads in
``exec_compiled._StreamLane``) must agree on final states and payloads
while actually overlapping consumption with production.  Covered here:

* per-edge chunk ordering and payload equivalence on both engines,
* the overlap property itself (consumer handles chunk 0 while its
  producer is still executing — proven with an event handshake),
* producer backpressure on a bounded ring (and its metric),
* recovery: ``invalidate`` resets ring cursors, ``expand_lost`` pulls
  streaming producers back in, and a node death mid-stream replays the
  stream with results equal to the fault-free oracle,
* degraded mode (``stream=False``): batch fallback + counter + one-time
  warning,
* randomized mixed batch/streaming graphs (seeded always; driven by
  hypothesis where installed).
"""
import random
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (EngineConfig, ExecHooks, FailureScript, Pipeline,
                        ResilienceConfig, StreamConfig, execute_frontier,
                        register_app)
from repro.core import exec_compiled
from repro.core.session import ST_COMPLETED
from repro.dsl import GraphBuilder

# ---------------------------------------------------------------------------
# apps
# ---------------------------------------------------------------------------


@register_app("st/emit4")
def _emit4(inputs, outputs, app):
    for i in range(4):
        for o in outputs:
            o.write(("c", i))


def _collect_finish(inputs, outputs, app):
    # seq-keyed accumulation: idempotent under at-least-once re-delivery
    # (recovery replays streams from chunk 0)
    seen = app.scratch.get("seen", {})
    for o in outputs:
        o.write([seen[k] for k in sorted(seen)])


@register_app("st/collect", streaming=True, finish=_collect_finish)
def _collect(value, app):
    seq, v = value
    app.scratch.setdefault("seen", {})[seq] = v


@register_app("st/emit-seq")
def _emit_seq(inputs, outputs, app):
    for i in range(4):
        for o in outputs:
            o.write((i, i * 10))


@register_app("st/last-double")
def _last_double(inputs, outputs, app):
    # batch consumer for (seq, value) chunk tuples: sees the final write
    seq, v = inputs[0].read()
    for o in outputs:
        o.write((seq, v * 2))


@register_app("st/count-ins")
def _count_ins(inputs, outputs, app):
    # probe: how many *batch* inputs does this app see?
    for o in outputs:
        o.write(("n_inputs", len(inputs)))


# ---------------------------------------------------------------------------
# topologies
# ---------------------------------------------------------------------------


def stream_chain_lg():
    g = GraphBuilder("stream-chain")
    g.data("src")
    g.component("P", app="st/emit-seq")
    g.data("d")
    g.component("C", app="st/collect")
    g.data("out")
    g.chain("src", "P", "d")
    g.connect("d", "C", streaming=True)
    g.chain("C", "out")
    return g.graph()


def run_both(lg_factory, inputs=None, stream=None):
    outs = {}
    for mode in ("objects", "compiled"):
        cfg = EngineConfig(execution=mode, num_nodes=2,
                           stream=stream if mode == "compiled" else None)
        with Pipeline(cfg) as p:
            rep = p.run(lg_factory(), inputs=dict(inputs or {"src": 1}))
            assert rep.ok, (mode, rep.state, rep.errors[:3])
            if mode == "objects":
                outs[mode] = {u: d.payload.read()
                              for u, d in p.session.drops.items()
                              if getattr(d, "payload", None) is not None
                              and d.payload.exists()}
            else:
                s = p.session
                outs[mode] = {u: s.read(u) for u in outs["objects"]
                              if s.payload_present[s.pgt.index_of(u)]}
    return outs


# ---------------------------------------------------------------------------
# ordering + equivalence
# ---------------------------------------------------------------------------


class TestChunkOrdering:
    def test_chunks_arrive_in_order_both_engines(self):
        for mode in ("objects", "compiled"):
            seqs = []
            hooks = ExecHooks(
                on_stream_chunk=lambda s, src, dst, seq: seqs.append(seq))
            with Pipeline(EngineConfig(execution=mode, num_nodes=2)) as p:
                rep = p.run(stream_chain_lg(), inputs={"src": 1},
                            hooks=hooks)
                assert rep.ok, (mode, rep.errors[:3])
            assert seqs == [0, 1, 2, 3], mode

    def test_final_payloads_equivalent(self):
        outs = run_both(stream_chain_lg)
        assert outs["objects"]["out"] == [0, 10, 20, 30]
        assert outs["compiled"] == outs["objects"]

    def test_batch_consumer_on_streaming_edge_gets_no_batch_input(self):
        # oracle contract (AppDrop.execute): streaming inputs live in
        # app.streaming_inputs, never app.inputs — a non-streaming func
        # wired on a streaming edge still fires once the producer
        # resolves, but its batch input list is EMPTY.  Both engines
        # must agree, chunk lane on or off.
        def lg():
            g = GraphBuilder("batch-on-stream")
            g.data("src")
            g.component("P", app="st/emit-seq")
            g.data("d")
            g.component("C", app="st/count-ins")
            g.data("out")
            g.chain("src", "P", "d")
            g.connect("d", "C", streaming=True)
            g.chain("C", "out")
            return g.graph()
        for stream in (None, StreamConfig()):
            outs = run_both(lg, stream=stream)
            assert outs["objects"]["out"] == ("n_inputs", 0)
            assert outs["compiled"]["out"] == ("n_inputs", 0)


# ---------------------------------------------------------------------------
# the tentpole property: consumption overlaps production
# ---------------------------------------------------------------------------


class TestOverlap:
    def test_consumer_starts_before_producer_finishes(self):
        """The producer blocks after chunk 0 until the consumer's chunk
        handler has run — only possible if the lane processes chunks
        while the producing wave is still in flight."""
        got_chunk = threading.Event()

        @register_app("st/handshake-prod")
        def prod(inputs, outputs, app):
            for o in outputs:
                o.write((0, "first"))
            assert got_chunk.wait(10.0), \
                "consumer never saw chunk 0 while producer was running"
            for o in outputs:
                o.write((1, "second"))

        def fin(inputs, outputs, app):
            for o in outputs:
                o.write(sorted(app.scratch["seen"]))

        @register_app("st/handshake-cons", streaming=True, finish=fin)
        def cons(value, app):
            app.scratch.setdefault("seen", []).append(value[0])
            got_chunk.set()

        g = GraphBuilder("handshake")
        g.data("src")
        g.component("P", app="st/handshake-prod")
        g.data("d")
        g.component("C", app="st/handshake-cons")
        g.data("out")
        g.chain("src", "P", "d")
        g.connect("d", "C", streaming=True)
        g.chain("C", "out")

        with Pipeline(EngineConfig(execution="compiled",
                                   num_nodes=2)) as p:
            rep = p.run(g.graph(), inputs={"src": 1})
            assert rep.ok, rep.errors[:3]
            assert p.session.read("out") == [0, 1]
        assert got_chunk.is_set()

    def test_chunk_spans_recorded_in_timeline(self):
        from repro.core import TelemetryConfig
        cfg = EngineConfig(
            execution="compiled", num_nodes=2,
            telemetry=TelemetryConfig(timeline=True, metrics=True))
        with Pipeline(cfg) as p:
            rep = p.run(stream_chain_lg(), inputs={"src": 1})
            assert rep.ok
            rows = p.session.timeline.chunk_spans()
            assert rows.shape == (4, 4)
            assert list(rows[:, 1]) == [0.0, 1.0, 2.0, 3.0]   # seqs
            assert (rows[:, 3] >= rows[:, 2]).all()           # t1 >= t0
            c = p.session.pgt.index_of("C")
            assert (rows[:, 0] == c).all()   # spans on the consumer

    def test_chunk_slices_in_perfetto_export(self, tmp_path):
        import json
        from repro.core import TelemetryConfig
        cfg = EngineConfig(
            execution="compiled", num_nodes=2,
            telemetry=TelemetryConfig(timeline=True, metrics=True))
        with Pipeline(cfg) as p:
            rep = p.run(stream_chain_lg(), inputs={"src": 1})
            assert rep.ok
            out = tmp_path / "trace.json"
            p.export_trace(str(out))
        events = json.load(open(out))["traceEvents"]
        chunk_slices = [e for e in events
                        if e.get("ph") == "X" and "chunk" in e["name"]]
        assert len(chunk_slices) == 4
        assert {e["args"]["chunk"] for e in chunk_slices} == {0, 1, 2, 3}


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------


class TestBackpressure:
    def test_full_ring_blocks_producer_and_counts(self):
        bp_events = []

        def slow_fin(inputs, outputs, app):
            for o in outputs:
                o.write(app.scratch.get("n", 0))

        @register_app("st/slow-cons", streaming=True, finish=slow_fin)
        def slow_cons(value, app):
            time.sleep(0.03)
            app.scratch["n"] = app.scratch.get("n", 0) + 1

        @register_app("st/fast-prod")
        def fast_prod(inputs, outputs, app):
            for i in range(8):
                for o in outputs:
                    o.write(i)

        g = GraphBuilder("bp")
        g.data("src")
        g.component("P", app="st/fast-prod")
        g.data("d")
        g.component("C", app="st/slow-cons")
        g.data("out")
        g.chain("src", "P", "d")
        g.connect("d", "C", streaming=True)
        g.chain("C", "out")

        from repro.core import TelemetryConfig
        hooks = ExecHooks(
            on_backpressure=lambda s, src, dst, waited:
                bp_events.append((src, dst)))
        cfg = EngineConfig(
            execution="compiled", num_nodes=1,
            stream=StreamConfig(ring_capacity=2,
                                backpressure_poll_s=0.005),
            telemetry=TelemetryConfig(metrics=True))
        with Pipeline(cfg) as p:
            rep = p.run(g.graph(), inputs={"src": 1}, hooks=hooks)
            assert rep.ok, rep.errors[:3]
            assert p.session.read("out") == 8    # every chunk delivered
            tbl = p.session.stream
            assert tbl.backpressure_waits > 0
            snap = p.metrics.snapshot()["counters"]
            assert snap["exec.stream_backpressure_waits"] > 0
            assert snap["exec.stream_chunks"] == 8
        assert bp_events and bp_events[0] == ("d", "C")


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------


class TestRecovery:
    def _session(self):
        p = Pipeline(EngineConfig(execution="compiled", num_nodes=2))
        p.translate(stream_chain_lg())
        p.deploy()
        return p

    def test_invalidate_resets_cursors(self):
        with self._session() as p:
            s = p.session
            tbl = s.enable_streaming(StreamConfig(ring_capacity=8))
            src = s.pgt.index_of("d")
            s.write("src", 1)
            for i in range(3):
                tbl.push(src, (i, i))
            assert tbl.wcur[0] == 3
            # simulate partial consumption then lose the consumer
            with tbl.cond:
                for _ in range(2):
                    tbl.pop_ready_locked(int(s.pgt.index_of("C")))
            assert tbl.rcur[0] == 2
            lost = np.zeros(len(s.pgt), dtype=bool)
            lost[s.pgt.index_of("C")] = True
            n_reset = tbl.invalidate(lost)
            assert n_reset == 1
            assert tbl.wcur[0] == 0 and tbl.rcur[0] == 0

    def test_expand_lost_pulls_streaming_producer(self):
        with self._session() as p:
            s = p.session
            tbl = s.enable_streaming(StreamConfig())
            s.write("src", 1)
            ok = execute_frontier(s, timeout=30.0)
            assert ok
            # consumer lost after consuming: its producer must re-run
            lost = np.array([s.pgt.index_of("C")], dtype=np.int64)
            grown = set(tbl.expand_lost(lost).tolist())
            assert int(s.pgt.index_of("d")) in grown
            assert int(s.pgt.index_of("P")) in grown

    def test_node_death_mid_stream_matches_oracle(self):
        # oracle: fault-free object run
        with Pipeline(EngineConfig(execution="objects",
                                   num_nodes=2)) as p:
            rep = p.run(stream_chain_lg(), inputs={"src": 1})
            assert rep.ok
            oracle = p.session.drops["out"].payload.read()

        with Pipeline(EngineConfig(execution="compiled",
                                   num_nodes=2)) as p:
            p.translate(stream_chain_lg())
            p.deploy()
            # kill whichever node hosts the streaming consumer, at the
            # first wave boundary — the stream is partially consumed
            nid = int(p.pgt.node_ids[p.pgt.index_of("C")])
            victim = sorted(p.master.node_managers())[nid]
            p.resilience = ResilienceConfig(
                failures=[FailureScript(victim, at_fraction=0.1)])
            rep = p.execute(timeout=60.0, inputs={"src": 1})
            assert rep.ok, (rep.state, rep.errors[:3])
            assert rep.recoveries >= 1
            assert p.session.read("out") == oracle
            assert p.session.drop_state[p.pgt.index_of("C")] \
                == ST_COMPLETED


# ---------------------------------------------------------------------------
# degraded mode
# ---------------------------------------------------------------------------


class TestDegraded:
    def test_stream_false_degrades_with_counter_and_warning(self):
        from repro.core import TelemetryConfig
        exec_compiled._degrade_warned = False
        cfg = EngineConfig(execution="compiled", num_nodes=2,
                           stream=False,
                           telemetry=TelemetryConfig(metrics=True))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with Pipeline(cfg) as p:
                rep = p.run(stream_chain_lg(), inputs={"src": 1})
                assert rep.ok, rep.errors[:3]
                # batch resolution: finish over scratch accumulated by
                # ... nothing — no chunks were delivered
                assert p.session.read("out") == []
                assert p.session.stream is None
                snap = p.metrics.snapshot()["counters"]
                assert snap["exec.streaming_edges_degraded"] == 1
        degraded = [x for x in w
                    if issubclass(x.category, RuntimeWarning)
                    and "degraded" in str(x.message)]
        assert len(degraded) == 1

    def test_degrade_warning_fires_once(self):
        exec_compiled._degrade_warned = False
        cfg = EngineConfig(execution="compiled", num_nodes=2,
                           stream=False)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            for _ in range(2):
                with Pipeline(cfg) as p:
                    rep = p.run(stream_chain_lg(), inputs={"src": 1})
                    assert rep.ok
        degraded = [x for x in w
                    if issubclass(x.category, RuntimeWarning)
                    and "degraded" in str(x.message)]
        assert len(degraded) == 1


# ---------------------------------------------------------------------------
# randomized mixed graphs (seeded always; hypothesis-driven when present)
# ---------------------------------------------------------------------------


def _sum_finish(inputs, outputs, app):
    for o in outputs:
        o.write(sum(app.scratch.get("vals", [])))


@register_app("st/sum-chunks", streaming=True, finish=_sum_finish)
def _sum_chunks(value, app):
    # order-insensitive accumulation: safe for multi-input interleaving
    app.scratch.setdefault("vals", []).append(value[1])


@register_app("st/emit-n")
def _emit_n(inputs, outputs, app):
    n = int(app.meta.get("params", {}).get("n", 3))
    base = sum(i.read() for i in inputs) if inputs else 0
    for i in range(n):
        for o in outputs:
            o.write((i, base + i))


def _mixed_lg(rng: random.Random):
    """A random fan of chains, each independently batch or streaming."""
    width = rng.randint(1, 4)
    g = GraphBuilder(f"mix{width}")
    g.data("src")
    stream_flags = []
    for k in range(width):
        streaming = rng.random() < 0.6
        n_chunks = rng.randint(1, 4)
        stream_flags.append(streaming)
        g.component(f"p{k}", app="st/emit-n", n=n_chunks)
        g.data(f"d{k}")
        g.component(f"c{k}",
                    app="st/sum-chunks" if streaming else "st/last-double")
        g.data(f"o{k}")
        g.chain("src", f"p{k}", f"d{k}")
        g.connect(f"d{k}", f"c{k}", streaming=streaming)
        g.chain(f"c{k}", f"o{k}")
    return g.graph(), width


def _check_mixed_equivalence(seed: int) -> None:
    rng = random.Random(seed)
    lg, width = _mixed_lg(rng)
    finals = {}
    for mode in ("objects", "compiled"):
        with Pipeline(EngineConfig(execution=mode, num_nodes=2)) as p:
            rep = p.run(lg, inputs={"src": 1})
            assert rep.ok, (seed, mode, rep.errors[:3])
            if mode == "objects":
                finals[mode] = {f"o{k}":
                                p.session.drops[f"o{k}"].payload.read()
                                for k in range(width)}
            else:
                finals[mode] = {f"o{k}": p.session.read(f"o{k}")
                                for k in range(width)}
    assert finals["compiled"] == finals["objects"], seed


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17, 42])
def test_mixed_graph_equivalence_seeded(seed):
    _check_mixed_equivalence(seed)


try:
    from hypothesis import given, settings
    import hypothesis.strategies as hyp_st
except ImportError:                                    # pragma: no cover
    pass
else:
    @settings(max_examples=15, deadline=None)
    @given(hyp_st.integers(min_value=0, max_value=10_000))
    def test_mixed_graph_equivalence_hypothesis(seed):
        _check_mixed_equivalence(seed)
