"""The array-native multilevel partition mapper (``core/mapping.py``).

Covers the PR-4 acceptance bars:

* **balance** — a zero-communication uniform graph maps ~evenly across N
  nodes (no node holds more than 2/N of the total weight; historically
  every zero-weight tie-break collapsed the whole graph onto node0),
  including the all-zero-weight case (balance by drop count) and the
  weighted-with-volumes case (the heavy-edge-matching load cap);
* **equivalence** — the CSR mapper agrees with the ``mapping="dict"``
  oracle structurally (same partition keys, every drop placed, dead
  nodes excluded) and produces an assignment whose objective
  ``alpha * imbalance + beta * cut`` is never materially worse, on
  weighted, multi-island and loop (dict-fallback) graphs;
* **validation** — ``refine_iters < 0`` and duplicate node names raise
  instead of silently misbehaving via dict keying.
"""
from collections import Counter
from typing import Dict

import numpy as np
import pytest

from repro.core import NodeInfo, map_partitions, min_time, unroll
from repro.core.mapping import PartitionGraph
from repro.core.unroll import unroll_dict
from repro.dsl import GraphBuilder


def uniform_lg(width: int, t: float = 1.0, v: float = 0.0):
    """Scatter of independent equal-cost chains: zero communication when
    ``v == 0`` (every edge moves zero bytes)."""
    g = GraphBuilder(f"u{width}")
    g.data("src", volume=v)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=t)
        g.data("d", volume=v)
        g.component("w2", app="identity", time=t)
        g.data("d2", volume=v)
    with g.gather("ga", width):
        g.component("r", app="noop", time=t)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def weighted_lg(width: int):
    """Heterogeneous weights + volumes (exercises coarsening + refine)."""
    g = GraphBuilder(f"wt{width}")
    g.data("src", volume=2.0)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=3.0)
        g.data("d", volume=5.0)
        g.component("w2", app="identity", time=1.0)
        g.data("d2", volume=0.5)
    with g.gather("ga", width):
        g.component("r", app="noop", time=2.0)
    g.data("out")
    g.chain("src", "w", "d", "w2", "d2", "r", "out")
    return g.graph()


def multi_island_lg(islands: int = 3, width: int = 12):
    """Disconnected components (nothing ever coarsens across them)."""
    g = GraphBuilder("mi")
    for k in range(islands):
        g.data(f"src{k}", volume=1.0)
        with g.scatter(f"sc{k}", width):
            g.component(f"w{k}", app="noop", time=1.0 + k)
            g.data(f"d{k}", volume=1.0)
        g.chain(f"src{k}", f"w{k}", f"d{k}")
    return g.graph()


def loop_lg(iters: int = 5):
    """Loop-carried graph: unrolls via the dict fallback, so the mapper's
    dict-PGT extraction path is what runs."""
    g = GraphBuilder("lp")
    g.data("init")
    g.component("seed", app="identity", time=0.5)
    with g.loop("lp", iters):
        g.data("x", loop_entry=True)
        g.component("inc", app="identity", time=1.0)
        g.data("y", loop_exit=True, carries="x")
    g.component("out", app="identity", time=0.5)
    g.data("res")
    g.chain("init", "seed", "x", "inc", "y")
    g.chain("y", "out", "res")
    return g.graph()


def assignment_cost(pgt, assign: Dict[int, str],
                    alpha: float = 1.0, beta: float = 1e-9) -> float:
    """The mapper's objective, computed independently from the partition
    graph: ``alpha * sum(node_load^2) + beta * cross_node_volume``."""
    g = PartitionGraph.from_pgt(pgt)
    loads: Counter = Counter()
    for p, w in g.vweights.items():
        loads[assign[p]] += w + 1e-6 * g.vmem[p]
    cut = sum(w for (a, b), w in g.eweights.items()
              if assign[a] != assign[b])
    return alpha * sum(v * v for v in loads.values()) + beta * cut


# ---------------------------------------------------------------------------
# balance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [2, 4, 8])
def test_zero_communication_uniform_graph_spreads(m):
    """No node may hold more than 2/N of the total weight (acceptance)."""
    pgt = unroll(uniform_lg(40 * m, t=1.0, v=0.0))
    min_time(pgt, dop=4)
    nodes = [NodeInfo(f"node{i}") for i in range(m)]
    map_partitions(pgt, nodes)
    w = np.zeros(m)
    np.add.at(w, pgt.node_ids, pgt.weight_arr)
    total = float(w.sum())
    assert total > 0
    assert w.max() <= 2.0 * total / m, w.tolist()
    assert (w > 0).all(), f"idle nodes: {w.tolist()}"


def test_all_zero_weight_graph_spreads_by_count():
    """Even with zero exec times AND volumes (pure bookkeeping graphs)
    the placement balances by drop count, not a node0 pile-up."""
    m = 8
    pgt = unroll(uniform_lg(200, t=0.0, v=0.0))
    min_time(pgt, dop=4)
    nodes = [NodeInfo(f"node{i}") for i in range(m)]
    map_partitions(pgt, nodes)
    counts = np.bincount(pgt.node_ids, minlength=m)
    assert counts.max() <= 2 * len(pgt) / m, counts.tolist()


def test_uniform_weighted_with_volumes_spreads():
    """Positive edge volumes must not coarsen a connected uniform graph
    into one giant super-vertex (the HEM load cap)."""
    m = 8
    pgt = unroll(uniform_lg(300, t=1.0, v=1.0))
    min_time(pgt, dop=64)
    nodes = [NodeInfo(f"node{i}") for i in range(m)]
    map_partitions(pgt, nodes)
    w = np.zeros(m)
    np.add.at(w, pgt.node_ids, pgt.weight_arr)
    assert w.max() <= 2.0 * float(w.sum()) / m, w.tolist()


def test_dead_nodes_excluded_csr():
    pgt = unroll(uniform_lg(16))
    min_time(pgt, dop=4)
    nodes = [NodeInfo("node0"), NodeInfo("node1", alive=False),
             NodeInfo("node2")]
    assign = map_partitions(pgt, nodes)
    assert set(assign.values()) <= {"node0", "node2"}


# ---------------------------------------------------------------------------
# CSR mapper ≡ dict oracle
# ---------------------------------------------------------------------------


def _equivalent(lg, m: int, use_dict_pgt: bool = False):
    pgt_csr = unroll_dict(lg) if use_dict_pgt else unroll(lg)
    pgt_dic = unroll_dict(lg) if use_dict_pgt else unroll(lg)
    min_time(pgt_csr, dop=4)
    min_time(pgt_dic, dop=4)
    nodes = [NodeInfo(f"node{i}") for i in range(m)]
    a_csr = map_partitions(pgt_csr, nodes, mapping="csr")
    a_dic = map_partitions(pgt_dic, nodes, mapping="dict")
    # structural equivalence: identical partition key sets, all placed
    assert set(a_csr) == set(a_dic)
    assert set(a_csr) == {s.partition for s in pgt_csr.drops.values()}
    names = {n.name for n in nodes}
    assert set(a_csr.values()) <= names
    assert all(s.node in names for s in pgt_csr.drops.values())
    # quality equivalence: the CSR objective never materially worse than
    # the oracle's (both refine the same objective to a local optimum)
    c_csr = assignment_cost(pgt_csr, a_csr)
    c_dic = assignment_cost(pgt_dic, a_dic)
    assert c_csr <= c_dic * 1.05 + 1e-12, (c_csr, c_dic)
    return a_csr, a_dic


def test_equivalence_weighted_graph():
    _equivalent(weighted_lg(24), m=4)


def test_equivalence_multi_island_graph():
    _equivalent(multi_island_lg(islands=3, width=12), m=4)


def test_equivalence_loop_graph_dict_fallback():
    # loop graphs unroll into dict PGTs: both mappers must accept them
    _equivalent(loop_lg(6), m=2, use_dict_pgt=True)


def test_csr_mapper_accepts_dict_pgt():
    pgt = unroll_dict(weighted_lg(8))
    min_time(pgt, dop=4)
    nodes = [NodeInfo("n0"), NodeInfo("n1")]
    assign = map_partitions(pgt, nodes, mapping="csr")
    assert set(assign) == {s.partition for s in pgt.drops.values()}
    assert all(s.node in {"n0", "n1"} for s in pgt.drops.values())


# ---------------------------------------------------------------------------
# validation (the silent-misbehaviour fixes)
# ---------------------------------------------------------------------------


def _small_pgt():
    pgt = unroll(uniform_lg(4))
    min_time(pgt, dop=4)
    return pgt


@pytest.mark.parametrize("mapping", ["csr", "dict"])
def test_negative_refine_iters_raises(mapping):
    with pytest.raises(ValueError, match="refine_iters"):
        map_partitions(_small_pgt(), [NodeInfo("n0")], refine_iters=-1,
                       mapping=mapping)


@pytest.mark.parametrize("mapping", ["csr", "dict"])
def test_duplicate_node_names_raise(mapping):
    nodes = [NodeInfo("n0"), NodeInfo("n1"), NodeInfo("n0")]
    with pytest.raises(ValueError, match="duplicate node names.*n0"):
        map_partitions(_small_pgt(), nodes, mapping=mapping)


def test_unknown_mapping_rejected():
    with pytest.raises(ValueError, match="unknown mapping"):
        map_partitions(_small_pgt(), [NodeInfo("n0")], mapping="metis")


def test_zero_refine_iters_allowed():
    pgt = _small_pgt()
    assign = map_partitions(pgt, [NodeInfo("n0"), NodeInfo("n1")],
                            refine_iters=0)
    assert set(assign) == {s.partition for s in pgt.drops.values()}
