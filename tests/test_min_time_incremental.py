"""Incremental prefix evaluation + batched merge for ``min_time`` (PR-5).

Contracts under test:

* :class:`repro.core.schedule.PrefixCP` — the incremental partitioned
  critical-path evaluator — must agree exactly with the from-scratch
  ``_critical_path_arrays`` at *every* step of a label sequence, both
  along monotone merge prefixes and across arbitrary relabelings
  (``min_res`` fold probes);
* along a growing merge prefix the estimator's makespan is monotonically
  non-increasing (merges only internalise edges — the regression guard
  for the delta-update state);
* the vectorized :class:`repro.core.partition._BatchedMerger` respects
  the DoP level-width caps exactly and never regresses the makespan past
  the trivial partitioning (forced onto small graphs by lowering the
  regime threshold).
"""
import numpy as np
import pytest

import repro.core.partition as partition_mod
from repro.core import min_res, min_time, simulate_makespan, unroll
from repro.core.partition import (_BatchedMerger, _dense_labels,
                                  _edge_merge_order, _merge_snapshots,
                                  _partition_dop)
from repro.core.schedule import PrefixCP, _critical_path_arrays, _extract
from repro.dsl import GraphBuilder


# ---------------------------------------------------------------------------
# graph shapes: chain / fan / loop
# ---------------------------------------------------------------------------


def chain_lg(depth=6):
    g = GraphBuilder("chain")
    g.data("src", volume=1e6)
    names = ["src"]
    for i in range(depth):
        g.component(f"a{i}", app="noop", time=0.01 * (i + 1))
        g.data(f"d{i}", volume=1e5 * (i + 1))
        names += [f"a{i}", f"d{i}"]
    g.chain(*names)
    return g.graph()


def fan_lg(width=9, fanin=3):
    g = GraphBuilder("fan")
    g.data("src", volume=2e6)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=0.02)
        g.data("mid", volume=5e5)
    with g.gather("ga", fanin):
        g.component("r", app="noop", time=0.01)
    g.data("out")
    g.chain("src", "w", "mid", "r", "out")
    return g.graph()


def loop_lg(iters=4, width=3):
    g = GraphBuilder("loop")
    g.data("init", volume=1e5)
    g.component("seed", app="identity", time=0.005)
    with g.loop("lp", iters):
        g.data("x", loop_entry=True)
        with g.scatter("sc", width):
            g.component("w", app="noop", time=0.01)
            g.data("part", volume=3e5)
        g.component("cal", app="noop", time=0.02)
        g.data("y", loop_exit=True, carries="x", volume=2e5)
    g.component("fin", app="identity", time=0.005)
    g.data("res")
    g.chain("init", "seed", "x", "w", "part", "cal", "y")
    g.chain("y", "fin", "res")
    return g.graph()


SHAPES = [chain_lg, fan_lg, loop_lg]
IDS = ["chain", "fan", "loop"]


def _prefix_labels(pgt, dop, bandwidth=1e9):
    """Label sequence along geometric prefixes of the cost-sorted order,
    produced by the batched merger (root labels, not densified)."""
    order = _edge_merge_order(pgt, bandwidth)
    ne = int(order.size)
    ks = sorted({0, ne // 8, ne // 4, ne // 2, 3 * ne // 4, ne})
    merger = _BatchedMerger(pgt, dop)
    out = []
    prev = 0
    for k in ks:
        merger.merge_window(order[prev:k])
        prev = k
        out.append(merger.labels().copy())
    return out


# ---------------------------------------------------------------------------
# PrefixCP == full re-evaluation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
@pytest.mark.parametrize("dop", [1, 2, 8])
def test_incremental_equals_full_along_prefixes(factory, dop):
    pgt = unroll(factory())
    a = _extract(pgt)
    pcp = PrefixCP(a, 1e9)
    for labels in _prefix_labels(pgt, dop):
        assert pcp.evaluate(labels) == \
            _critical_path_arrays(a, labels, 1e9)


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
def test_incremental_handles_arbitrary_relabelings(factory):
    """Fold-probe pattern: labels change non-monotonically (edges turn
    crossing again); the evaluator must still match the full pass."""
    pgt = unroll(factory())
    a = _extract(pgt)
    n = pgt.num_drops
    pcp = PrefixCP(a, 1e9)
    rng = np.random.default_rng(42)
    seqs = [np.arange(n), rng.integers(0, 3, n), np.zeros(n, dtype=int),
            rng.integers(0, max(n // 2, 1), n), np.arange(n) % 2]
    for labels in seqs:
        assert pcp.evaluate(labels) == \
            _critical_path_arrays(a, labels, 1e9)
    assert pcp.delta_evals > 0          # the fast path actually ran


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
@pytest.mark.parametrize("dop", [2, 8])
def test_makespan_monotone_along_growing_prefix(factory, dop):
    """Merges only internalise edges, so the estimator's makespan can
    never increase as the prefix grows."""
    pgt = unroll(factory())
    a = _extract(pgt)
    pcp = PrefixCP(a, 1e9)
    values = [pcp.evaluate(labels)
              for labels in _prefix_labels(pgt, dop)]
    for earlier, later in zip(values, values[1:]):
        assert later <= earlier + 1e-12
    assert values[-1] <= values[0]


def test_zero_cost_graph_short_circuits():
    """No costly edges + no weights => every labelling evaluates to 0
    without any propagation (the overhead-benchmark shape)."""
    g = GraphBuilder("z")
    g.data("src")
    with g.scatter("sc", 8):
        g.component("w", app="noop")
        g.data("d")
    g.chain("src", "w", "d")
    pgt = unroll(g.graph())
    a = _extract(pgt)
    pcp = PrefixCP(a, 1e9)
    n = pgt.num_drops
    assert pcp.evaluate(np.arange(n)) == 0.0
    assert pcp.evaluate(np.zeros(n, dtype=int)) == 0.0
    assert pcp.full_evals == 0 and pcp.delta_evals == 0


# ---------------------------------------------------------------------------
# batched merger: cap safety + quality (forced onto small graphs)
# ---------------------------------------------------------------------------


@pytest.fixture
def force_batched(monkeypatch):
    """Push every CompiledPGT through the large-graph (batched) regime."""
    monkeypatch.setattr(partition_mod, "EXACT_EVAL_MAX_DROPS", 0)
    monkeypatch.setattr(partition_mod, "EXACT_FINAL_MAX_DROPS", 0)


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
@pytest.mark.parametrize("dop", [1, 2, 4])
def test_batched_min_time_respects_dop_caps(force_batched, factory, dop):
    pgt = unroll(factory())
    res = min_time(pgt, dop=dop)
    members = {}
    for uid, s in pgt.drops.items():
        members.setdefault(s.partition, set()).add(uid)
    assert res.num_partitions == len(members)
    for ms in members.values():
        assert _partition_dop(pgt, ms) <= dop
    # labels are dense 0..P-1
    labs = np.unique(pgt.partition)
    assert labs[0] == 0 and labs[-1] == len(labs) - 1


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
def test_batched_min_time_never_worse_than_trivial(force_batched, factory):
    lg = factory()
    pgt = unroll(lg)
    dop = 4
    trivial_pgt = unroll(lg)
    trivial_pgt.partition = np.arange(len(trivial_pgt), dtype=np.int32)
    trivial = simulate_makespan(trivial_pgt, dop=dop)
    res = min_time(pgt, dop=dop)
    # the reported makespan is the estimator's; re-check with the exact
    # canonical simulator, which must not regress past trivial either
    assert simulate_makespan(pgt, dop=dop) <= trivial + 1e-9
    assert res.num_partitions >= 1


@pytest.mark.parametrize("factory", SHAPES, ids=IDS)
def test_batched_min_res_meets_loose_deadline(force_batched, factory):
    from repro.core import critical_path
    pgt = unroll(factory())
    loose = critical_path(pgt, partitioned=False) * 10
    res = min_res(pgt, deadline=loose, dop=4)
    assert simulate_makespan(pgt, dop=4) <= loose * (1 + 1e-6)
    assert res.num_partitions >= 1


def test_batched_snapshots_share_sequential_contract(force_batched):
    """_merge_snapshots in the batched regime: k=0 is trivial, labels
    refine monotonically (partitions only ever grow)."""
    pgt = unroll(fan_lg())
    a = _extract(pgt)
    snaps = _merge_snapshots(pgt, a, 4, 1e9)
    assert snaps[0][0] == 0
    first = _dense_labels(snaps[0][2])
    assert np.unique(first).size == pgt.num_drops       # trivial
    for (_, _, la), (_, _, lb) in zip(snaps, snaps[1:]):
        da, db = _dense_labels(la), _dense_labels(lb)
        # every later-snapshot partition is a union of earlier ones:
        # drops sharing a label in `da` still share one in `db`
        for p in np.unique(da):
            ids = np.flatnonzero(da == p)
            assert np.unique(db[ids]).size == 1


def test_sweep_star_matches_sequential_semantics(force_batched):
    """A hub star (one source feeding many one-app branches) must accept
    exactly `dop` branches and retire the rest — what attempting the
    edges one-by-one would do."""
    dop, width = 3, 16
    g = GraphBuilder("star")
    g.data("src", volume=1e6)
    with g.scatter("sc", width):
        g.component("w", app="noop", time=0.01)
        g.data("d", volume=1e5)
    g.chain("src", "w", "d")
    pgt = unroll(g.graph())
    min_time(pgt, dop=dop)
    src_part = pgt.drops["src"].partition
    w_parts = [pgt.drops[f"w#{k}"].partition for k in range(width)]
    assert sum(1 for p in w_parts if p == src_part) == dop
