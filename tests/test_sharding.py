"""Sharding-rule tests + a miniature multi-device dry-run.

Multi-device cases run in a SUBPROCESS with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps its single-device view (jax locks device count at first init).
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_child(code: str) -> dict:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
    """) % SRC + textwrap.dedent(code)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


class TestParamRules:
    def test_tp_and_fallbacks(self):
        out = run_child("""
        from repro.configs import get_smoke_config, abstract_params
        from repro.sharding import param_pspecs
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("nemotron_4_15b"),
                                  num_heads=8, num_kv_heads=2, d_ff=64,
                                  sharding_strategy="fsdp")
        specs, decisions = param_pspecs(cfg, abstract_params(cfg), mesh)
        flat = {jax.tree_util.keystr(p): s for p, s
                in jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]}
        report = {
          "wq": str(flat["['layers']['attn']['wq']"]),
          "wk": str(flat["['layers']['attn']['wk']"]),
          "w1": str(flat["['layers']['mlp']['w1']"]),
          "embed": str(flat["['embed']"]),
          "decisions": decisions,
        }
        print(json.dumps(report))
        """)
        # heads 8 % 4 == 0 -> sharded; kv 2 % 4 != 0 -> replicated + logged
        assert "'model'" in out["wq"]
        assert "'model'" not in out["wk"]
        assert any("kvheads" in d for d in out["decisions"])
        assert "'model'" in out["w1"]       # ffn TP
        assert "'data'" in out["wq"] or "'data'" in out["embed"]  # fsdp

    def test_mini_dryrun_compiles_and_has_collectives(self):
        """Lower + compile a real train step on an 8-device mesh."""
        out = run_child("""
        import dataclasses
        from repro.configs import get_smoke_config, abstract_params
        from repro.sharding import batch_pspecs, param_pspecs
        from repro.sharding.rules import opt_pspecs
        from repro.train.steps import TrainState, make_train_step, \\
            train_state_init
        from repro.roofline import collective_bytes_from_hlo
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("codeqwen15_7b"),
                                  num_heads=4, num_kv_heads=4, d_ff=64,
                                  vocab_size=256)
        from repro.sharding.compat import use_mesh
        step = make_train_step(cfg, num_microbatches=2, remat=True)
        state = jax.eval_shape(
            lambda: train_state_init(cfg, jax.random.PRNGKey(0)))
        pspecs, _ = param_pspecs(cfg, abstract_params(cfg), mesh)
        sspecs = TrainState(pspecs, opt_pspecs(pspecs, state.opt), None)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        bspecs = batch_pspecs(cfg, batch, mesh)
        tos = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        with use_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(tos(sspecs), tos(bspecs)),
                              donate_argnums=(0,)).lower(state, batch)
        compiled = lowered.compile()
        coll = collective_bytes_from_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        print(json.dumps({
            "total_collective_bytes": coll["total"],
            "all_reduce": coll["all-reduce"],
            "arg_bytes": int(mem.argument_size_in_bytes),
        }))
        """)
        # gradient DP sync must produce all-reduce traffic
        assert out["all_reduce"] > 0
        assert out["arg_bytes"] > 0

    def test_decode_cache_specs(self):
        out = run_child("""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.sharding import cache_pspecs
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("command_r_plus_104b"),
                                  num_heads=8, num_kv_heads=2)
        cache = jax.eval_shape(lambda: M.init_cache(cfg, 8, 64))
        specs = cache_pspecs(cfg, cache, mesh)
        print(json.dumps({"k": str(specs["kv"]["k"])}))
        """)
        # kv heads (2) don't divide model (4) -> flash-decoding seq sharding
        assert out["k"].count("'model'") == 1
        assert "None, 'model'" in out["k"] or "'model'," in out["k"]


class TestMeshFactory:
    def test_mesh_shapes(self):
        out = run_child("""
        # 8 host devices cannot back the 256/512-chip production meshes, but
        # the factory's SHAPE logic is what we check here.
        from repro.launch.mesh import make_production_mesh
        try:
            make_production_mesh()
            ok = True
        except Exception as e:
            ok = "requires" in str(e) or "devices" in str(e).lower()
        print(json.dumps({"graceful": bool(ok)}))
        """)
        assert out["graceful"]
