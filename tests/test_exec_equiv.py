"""Compiled vs object execution equivalence (the PR-2 contract).

The object engine (`drop.py` + `session.py`) is the semantic oracle; the
compiled path (`exec_compiled.py` frontier scheduler over `CompiledPGT`)
must agree on final status counts, error propagation, per-drop payload
values for memory drops, and checkpoint/restore round-trips — across
chain / fan-out / fan-in / multi-island topologies.
"""
import pytest

from repro.core import (CompiledSession, DropState, Pipeline,
                        execute_frontier, register_app)
from repro.core.session import ST_COMPLETED
from repro.dsl import GraphBuilder


@register_app("eq_double")
def _double(inputs, outputs, app):
    v = sum(i.read() for i in inputs) if inputs else 1
    for o in outputs:
        o.write(v * 2)


@register_app("eq_sum")
def _sum(inputs, outputs, app):
    v = sum(i.read() for i in inputs)
    for o in outputs:
        o.write(v)


@register_app("eq_fail")
def _fail(inputs, outputs, app):
    raise RuntimeError("intentional failure")


@register_app("eq_slow")
def _slow(inputs, outputs, app):
    import time
    time.sleep(0.02)
    for o in outputs:
        o.write(None)


@register_app("eq_emit_oid")
def _emit_oid(inputs, outputs, app):
    for o in outputs:
        o.write(tuple(app.meta["oid"]))


# ---------------------------------------------------------------------------
# topologies
# ---------------------------------------------------------------------------


def chain_lg():
    g = GraphBuilder("chain")
    g.data("src")
    g.component("a1", app="eq_double")
    g.data("d1")
    g.component("a2", app="eq_double")
    g.data("d2")
    g.component("a3", app="identity")
    g.data("out")
    g.chain("src", "a1", "d1", "a2", "d2", "a3", "out")
    return g.graph()


def fan_lg(width=4):
    """Fan-out (scatter) then fan-in (gather)."""
    g = GraphBuilder("fan")
    g.data("src", volume=100)
    with g.scatter("sc", width):
        g.component("work", app="eq_double", time=0.001)
        g.data("mid", volume=50)
    with g.gather("ga", width):
        g.component("reduce", app="eq_sum", time=0.001)
    g.data("final")
    g.chain("src", "work", "mid", "reduce", "final")
    return g.graph()


def error_lg():
    g = GraphBuilder("err")
    g.data("src")
    g.component("bad", app="eq_fail")
    g.data("mid")
    g.component("next", app="eq_sum")
    g.data("out")
    g.chain("src", "bad", "mid", "next", "out")
    return g.graph()


def threshold_lg():
    """One of two inputs fails; t=50% lets the aggregate still run."""
    g = GraphBuilder("tol")
    g.data("s1")
    g.data("s2")
    g.component("ok", app="identity")
    g.component("bad", app="eq_fail")
    g.data("d1")
    g.data("d2")
    g.component("agg", app="eq_sum", error_threshold=0.5)
    g.data("out")
    g.chain("s1", "ok", "d1", "agg")
    g.chain("s2", "bad", "d2", "agg")
    g.connect("agg", "out")
    return g.graph()


def run_both(lg_factory, inputs=None, num_nodes=2, num_islands=1):
    """Run the same LG through both engines; return (obj report+session,
    compiled report+session)."""
    with Pipeline(num_nodes=num_nodes, num_islands=num_islands,
                  execution="objects") as p:
        rep_o = p.run(lg_factory(), inputs=dict(inputs or {}))
        states_o = {u: d.state for u, d in p.session.drops.items()}
        values_o = {u: _try_read(d) for u, d in p.session.drops.items()
                    if d.state is DropState.COMPLETED}
    with Pipeline(num_nodes=num_nodes, num_islands=num_islands,
                  execution="compiled") as p:
        rep_c = p.run(lg_factory(), inputs=dict(inputs or {}))
        s = p.session
        states_c = {u: s.state_of(u) for u in states_o}
        values_c = {u: _try_read_compiled(s, u) for u in values_o}
    return rep_o, states_o, values_o, rep_c, states_c, values_c


_ABSENT = object()


def _try_read(d):
    try:
        return d.read()
    except Exception:
        return _ABSENT


def _try_read_compiled(s, uid):
    try:
        return s.read(uid)
    except Exception:
        return _ABSENT


# ---------------------------------------------------------------------------
# status / payload equivalence
# ---------------------------------------------------------------------------


class TestStatusEquivalence:
    @pytest.mark.parametrize("factory,inputs", [
        (chain_lg, {"src": 3}),
        (fan_lg, {"src": 3}),
        (error_lg, {"src": 1}),
        (threshold_lg, {"s1": 5, "s2": 7}),
    ])
    def test_counts_states_and_values_agree(self, factory, inputs):
        rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(factory, inputs)
        assert rep_c.status_counts == rep_o.status_counts
        assert st_c == st_o
        assert val_c == val_o

    def test_multi_island(self):
        rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(
            fan_lg, {"src": 2}, num_nodes=4, num_islands=2)
        assert rep_o.ok and rep_c.ok
        assert st_c == st_o
        assert val_c["final"] == val_o["final"] == 16

    def test_fan_in_values(self):
        """Gather consumes inputs in deterministic (oid, uid) order."""
        g = GraphBuilder("oids")
        with g.scatter("sc", 3):
            g.component("emit", app="eq_emit_oid")
            g.data("pt")
        with g.gather("ga", 3):
            g.component("collect", app="identity")
            g.data("grp")
        g.chain("emit", "pt", "collect", "grp")
        rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(lambda: g.graph())
        assert rep_o.ok and rep_c.ok
        assert val_c["grp#0"] == val_o["grp#0"] == [(0,), (1,), (2,)]

    def test_noop_graph_all_completed(self):
        def lg():
            g = GraphBuilder("noops")
            g.data("src")
            with g.scatter("sc", 8):
                g.component("w", app="noop")
                g.data("d")
            with g.gather("ga", 8):
                g.component("r", app="noop")
            g.data("out")
            g.chain("src", "w", "d", "r", "out")
            return g.graph()
        rep_o, st_o, _, rep_c, st_c, _ = run_both(lg)
        assert rep_o.ok and rep_c.ok
        # src + 8 w + 8 d + 1 gather app + out
        assert rep_c.status_counts == rep_o.status_counts == {
            "COMPLETED": 19}

    def test_loop_graph_array_native(self):
        """Loop-carried graphs now unroll straight into CompiledPGT (no
        from_dict_pgt lift); both engines must agree on every
        iteration's payload."""
        def lg():
            g = GraphBuilder("loop")
            g.data("init")
            g.component("seed", app="identity")
            with g.loop("lp", 5):
                g.data("x", loop_entry=True)
                g.component("inc", app="eq_double")
                g.data("y", loop_exit=True, carries="x")
            g.chain("init", "seed", "x", "inc", "y")
            return g.graph()
        rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(
            lg, {"init": 1})
        assert rep_o.ok and rep_c.ok
        assert st_c == st_o
        for t in range(5):
            assert val_c[f"y#{t}"] == val_o[f"y#{t}"] == 2 ** (t + 1)

    def test_loop_with_scatter_inside_array_native(self):
        """Scatter-inside-loop: per-iteration fan-out/fan-in payloads
        agree across engines, and the loop exit consumed outside the
        loop carries the final iteration's value."""
        def lg():
            g = GraphBuilder("loopsc")
            g.data("init")
            g.component("seed", app="identity")
            with g.loop("lp", 3):
                g.data("x", loop_entry=True)
                with g.scatter("sc", 4):
                    g.component("w", app="eq_double")
                    g.data("part")
                g.component("cal", app="eq_sum", error_threshold=0.0)
                g.data("y", loop_exit=True, carries="x")
            g.component("fin", app="identity")
            g.data("res")
            g.chain("init", "seed", "x", "w", "part", "cal", "y")
            g.chain("y", "fin", "res")
            return g.graph()
        rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(lg, {"init": 1})
        assert rep_o.ok and rep_c.ok
        assert st_c == st_o
        assert val_c == val_o
        # each iteration: 4 branches double the carried value, the
        # reducer sums them => x * 8 per iteration
        want = 1
        for t in range(3):
            want *= 8
            assert val_c[f"y#{t}"] == want
        assert val_c["res"] == want


class TestErrorPropagation:
    def test_cascade_states(self):
        _, st_o, _, _, st_c, _ = run_both(error_lg, {"src": 1})
        for uid in ("bad", "mid", "next", "out"):
            assert st_o[uid] is DropState.ERROR
            assert st_c[uid] is DropState.ERROR

    def test_threshold_gate(self):
        _, st_o, val_o, _, st_c, val_c = run_both(
            threshold_lg, {"s1": 5, "s2": 7})
        assert st_c["d2"] is DropState.ERROR
        assert st_c["agg"] is DropState.COMPLETED
        assert val_c["out"] == val_o["out"] == 5   # surviving input only

    def test_unseeded_memory_input_errors_reader(self):
        """identity on an absent memory payload raises in both engines."""
        def lg():
            g = GraphBuilder("absent")
            g.data("src")
            g.component("r", app="identity")
            g.data("out")
            g.chain("src", "r", "out")
            return g.graph()
        _, st_o, _, _, st_c, _ = run_both(lg)   # src never written
        assert st_o["r"] is DropState.ERROR
        assert st_c["r"] is DropState.ERROR


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------


class TestCompiledCheckpoint:
    def test_round_trip(self, tmp_path):
        with Pipeline(num_nodes=2, execution="compiled") as p:
            rep = p.run(fan_lg(), inputs={"src": 3})
            assert rep.ok
            ck = tmp_path / "ck"
            p.session.checkpoint(str(ck))
            want_status = p.session.status()
            want_final = p.session.read("final")

        with Pipeline(num_nodes=2, execution="compiled") as p2:
            p2.translate(fan_lg())
            p2.deploy()
            p2.session.restore(str(ck))
            assert p2.session.status() == want_status
            assert p2.session.read("final") == want_final
            assert p2.session.wait(1)   # all terminal -> finished

    def test_resume_partial_execution(self, tmp_path):
        """Checkpoint a partially-executed state, restore into a fresh
        deployment, and let the frontier scheduler finish the rest."""
        with Pipeline(num_nodes=2, execution="compiled") as p:
            p.translate(fan_lg())
            p.deploy()
            s = p.session
            s.write("src", 3)
            s.drop_state[s.index_of("src")] = ST_COMPLETED
            s.checkpoint(str(tmp_path / "mid"))

        with Pipeline(num_nodes=2, execution="compiled") as p2:
            p2.translate(fan_lg())
            p2.deploy()
            s2 = p2.session
            s2.restore(str(tmp_path / "mid"))
            assert s2.state_of("src") is DropState.COMPLETED
            assert execute_frontier(s2, timeout=10)
            assert s2.read("final") == 24
            assert s2.status() == {"COMPLETED": 11}


# ---------------------------------------------------------------------------
# deploy-layer regressions
# ---------------------------------------------------------------------------


class TestDeploy:
    def test_cross_node_edges_scoped_per_session(self):
        """Regression: island cross-node edge records used to accumulate
        across sessions (and got re-scanned by later deployments)."""
        with Pipeline(num_nodes=4, num_islands=2) as p:
            rep1 = p.run(fan_lg(), inputs={"src": 1})
            assert rep1.ok
            islands = list(p.master.islands.values())
            # deploy a second session of the same shape on the same master
            p.translate(fan_lg())
            p.deploy()
            rep2 = p.execute(inputs={"src": 2})
            assert rep2.ok
            for im in islands:
                for sid, rec in im.cross_node_edges.items():
                    assert rec, f"empty record kept for {sid}"
            assert p.session.drops["final"].read() == 16

    def test_compiled_deploy_slices_cover_all_drops(self):
        with Pipeline(num_nodes=3, execution="compiled") as p:
            p.translate(fan_lg())
            p.deploy()
            s = p.session
            total = sum(len(v) for v in s.node_slices.values())
            assert total == len(p.pgt)
            for node, idx in s.node_slices.items():
                assert (p.pgt.node_ids[idx] ==
                        p.pgt.node_id_for(node)).all()

    def test_compiled_deploy_requires_mapping(self):
        from repro.core import CompiledSession, unroll
        with Pipeline(num_nodes=2, execution="compiled") as p:
            pgt = unroll(fan_lg())
            sess = CompiledSession("s-x", pgt)
            with pytest.raises(ValueError, match="not mapped"):
                p.master.deploy_compiled(sess, pgt)

    def test_compiled_timeout_mid_wave_and_resume(self):
        """A wide wave of slow Python apps must honour the deadline
        mid-wave, report TIMEOUT, and be resumable afterwards."""
        def lg():
            g = GraphBuilder("slow")
            g.data("src")
            with g.scatter("sc", 20):
                g.component("w", app="eq_slow", time=0.0)
                g.data("d")
            return g.graph()
        with Pipeline(num_nodes=2, execution="compiled") as p:
            p.translate(lg())
            p.deploy()
            rep = p.execute(timeout=0.1, inputs={"src": 1})
            assert rep.state == "TIMEOUT"
            assert rep.status_counts.get("INITIALIZED", 0) > 0
            # resume: the scheduler re-derives its counters and finishes
            assert execute_frontier(p.session, timeout=30)
            assert p.session.status() == {"COMPLETED": 41}

    def test_reregistered_builtin_bypasses_fast_path(self):
        """Re-registering 'noop' must reach the compiled engine too (the
        vectorised fast path only applies to the builtin implementation)."""
        from repro.core.managers import _APP_REGISTRY
        original = _APP_REGISTRY["noop"]

        def custom_noop(inputs, outputs, app):
            for o in outputs:
                o.write("sentinel")
        _APP_REGISTRY["noop"] = custom_noop
        try:
            def lg():
                g = GraphBuilder("ovr")
                g.data("src")
                g.component("w", app="noop")
                g.data("out")
                g.chain("src", "w", "out")
                return g.graph()
            with Pipeline(num_nodes=1, execution="compiled") as p:
                rep = p.run(lg(), inputs={"src": 1})
                assert rep.ok
                assert p.session.read("out") == "sentinel"
        finally:
            _APP_REGISTRY["noop"] = original

    def test_compiled_rejects_object_services(self):
        with pytest.raises(ValueError, match="compiled execution"):
            Pipeline(execution="compiled", enable_dlm=True)
        with pytest.raises(ValueError, match="unknown execution"):
            Pipeline(execution="bogus")


# ---------------------------------------------------------------------------
# hypothesis: random layered graphs agree (cheap tier; skipped when the
# optional dev dependency is absent — tier-1 stays green without it)
# ---------------------------------------------------------------------------

def _layered_lg(width, depth, apps, inject_error):
    g = GraphBuilder("rand")
    g.data("src")
    with g.scatter("sc", width):
        for i in range(depth):
            app = "eq_fail" if inject_error and i == depth - 1 \
                else apps[i % len(apps)]
            g.component(f"w{i}", app=app, time=0.0)
            g.data(f"d{i}")
    with g.gather("ga", width):
        g.component("r", app="eq_sum", error_threshold=0.0)
    g.data("out")
    names = ["src"]
    for i in range(depth):
        names += [f"w{i}", f"d{i}"]
    names += ["r", "out"]
    g.chain(*names)
    return g.graph()


def _check_layered_equivalence(width, depth, apps, inject_error):
    rep_o, st_o, val_o, rep_c, st_c, val_c = run_both(
        lambda: _layered_lg(width, depth, apps, inject_error), {"src": 1})
    assert rep_c.status_counts == rep_o.status_counts
    assert st_c == st_o
    assert val_c == val_o


def test_layered_equivalence_examples():
    """Deterministic spot checks of the random-topology property (run
    even without hypothesis)."""
    _check_layered_equivalence(3, 2, ["identity", "eq_double", "noop"],
                               False)
    _check_layered_equivalence(2, 3, ["eq_double", "noop", "identity"],
                               True)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    pass
else:
    @settings(max_examples=15, deadline=None)
    @given(width=st.integers(1, 5), depth=st.integers(1, 3),
           apps=st.lists(st.sampled_from(["identity", "eq_double", "noop"]),
                         min_size=3, max_size=3),
           inject_error=st.booleans())
    def test_random_layered_equivalence(width, depth, apps, inject_error):
        _check_layered_equivalence(width, depth, apps, inject_error)
