"""Unit tests: constructs, validation, unrolling, partitioning, mapping, io."""
import pytest

from repro.core import (Construct, GraphValidationError, Kind,
                        LogicalGraphTemplate, NodeInfo, critical_path,
                        leaf_axes, load_lgt, load_pgt, map_partitions,
                        min_res, min_time, partition_stats, save_lgt,
                        save_pgt, simulate_makespan, unroll)
from repro.dsl import GraphBuilder


def lg_scatter(n=4):
    g = GraphBuilder("t")
    g.data("src")
    with g.scatter("sc", n):
        g.component("w", app="noop", time=1.0)
        g.data("d", volume=1e6)
    with g.gather("ga", n):
        g.component("r", app="noop", time=2.0)
    g.data("out")
    g.chain("src", "w", "d", "r", "out")
    return g.graph()


class TestValidation:
    def test_cycle_rejected(self):
        g = GraphBuilder("c")
        g.data("a")
        g.component("f", app="noop")
        g.data("b")
        g.component("h", app="noop")
        g.chain("a", "f", "b", "h")
        g.connect("h", "a")
        with pytest.raises(GraphValidationError, match="cycle"):
            g.graph()

    def test_linking_rule(self):
        """Data<->Component only (paper §3.2)."""
        g = GraphBuilder("l")
        g.data("a")
        g.data("b")
        g.lgt.edges.append(type(g.lgt.edges)() if False else None)
        from repro.core import LogicalEdge
        g.lgt.edges = [LogicalEdge("a", "b")]
        with pytest.raises(GraphValidationError, match="linking rule"):
            g.lgt.validate()

    def test_gather_fanin_must_divide(self):
        g = GraphBuilder("g")
        g.data("src")
        with g.scatter("sc", 4):
            g.component("w", app="noop")
            g.data("d")
        with g.gather("ga", 3):
            g.component("r", app="noop")
        g.chain("src", "w", "d", "r")
        with pytest.raises(GraphValidationError, match="divide"):
            unroll(g.graph())

    def test_groupby_needs_nested_scatters(self):
        g = GraphBuilder("gb")
        g.data("src")
        with g.scatter("sc", 4):
            g.component("w", app="noop")
            g.data("d")
        with g.group_by("grp"):
            g.component("c", app="noop")
        g.chain("src", "w", "d", "c")
        with pytest.raises(GraphValidationError, match="two incoming axes"):
            unroll(g.graph())

    def test_parametrise_unknown_param(self):
        lgt = LogicalGraphTemplate(name="p", parameters={"n": 2})
        with pytest.raises(GraphValidationError, match="unknown parameters"):
            lgt.parametrise(bogus=1)

    def test_parametrised_scatter_width(self):
        g = GraphBuilder("pw", parameters={"n": 2})
        g.data("src")
        with g.scatter("sc", 2) as sc:
            sc.params["$num_of_copies"] = "n"
            g.component("w", app="noop")
            g.data("d")
        g.chain("src", "w", "d")
        lg = g.lgt.parametrise(n=8)
        pgt = unroll(lg)
        assert sum(1 for u in pgt.drops if u.startswith("w#")) == 8


class TestUnroll:
    def test_instance_counts(self):
        pgt = unroll(lg_scatter(4))
        # src 1, w 4, d 4, r 1, out 1
        assert len(pgt) == 11
        kinds = {u: s.kind for u, s in pgt.drops.items()}
        assert sum(1 for k in kinds.values() if k == "app") == 5

    def test_edge_counts(self):
        pgt = unroll(lg_scatter(4))
        # src->w x4 (broadcast), w->d x4, d->r x4 (fan-in), r->out x1
        assert len(pgt.edges) == 13

    def test_axes_resolution(self):
        lg = lg_scatter(4)
        assert [a.size for a in leaf_axes(lg, "w")] == [4]
        assert [a.size for a in leaf_axes(lg, "r")] == [1]
        assert leaf_axes(lg, "src") == []

    def test_nested_scatter_product(self):
        g = GraphBuilder("n")
        with g.scatter("a", 3):
            with g.scatter("b", 5):
                g.component("w", app="noop")
                g.data("d")
        g.connect("w", "d")
        pgt = unroll(g.graph())
        assert sum(1 for u in pgt.drops if u.startswith("w#")) == 15

    def test_groupby_cornerturn_edges(self):
        g = GraphBuilder("c")
        with g.scatter("t", 3):
            with g.scatter("f", 2):
                g.component("e", app="noop")
                g.data("pt")
        with g.group_by("gb"):
            g.component("col", app="noop")
        g.chain("e", "pt", "col")
        pgt = unroll(g.graph())
        cols = [u for u in pgt.drops if u.startswith("col")]
        assert len(cols) == 2
        for cu in cols:
            assert len(pgt.predecessors(cu)) == 3

    def test_pgt_is_dag(self):
        pgt = unroll(lg_scatter(8))
        order = pgt.topological_order()
        assert len(order) == len(pgt)


class TestPartition:
    def test_min_time_respects_dop(self):
        pgt = unroll(lg_scatter(8))
        res = min_time(pgt, dop=2)
        from repro.core.partition import _partition_dop
        parts = {}
        for uid, s in pgt.drops.items():
            parts.setdefault(s.partition, set()).add(uid)
        for members in parts.values():
            assert _partition_dop(pgt, members) <= 2

    def test_min_time_not_worse_than_trivial(self):
        pgt = unroll(lg_scatter(8))
        for i, s in enumerate(pgt.drops.values()):
            s.partition = i
        trivial = simulate_makespan(pgt, dop=4)
        res = min_time(pgt, dop=4)
        assert res.makespan <= trivial + 1e-9

    def test_min_res_meets_deadline(self):
        pgt = unroll(lg_scatter(8))
        loose = critical_path(pgt, partitioned=False) * 10
        res = min_res(pgt, deadline=loose, dop=4)
        assert res.makespan <= loose * (1 + 1e-6)

    def test_min_res_fewer_partitions_when_loose(self):
        pgt1 = unroll(lg_scatter(8))
        tight = min_res(pgt1, deadline=0.0, dop=2)     # clamped to critical path
        pgt2 = unroll(lg_scatter(8))
        loose = min_res(pgt2, deadline=1e9, dop=2)
        assert loose.num_partitions <= tight.num_partitions

    def test_makespan_at_least_compute_critical_path(self):
        # lower bound: zero-communication critical path (pure compute)
        pgt = unroll(lg_scatter(8))
        min_time(pgt, dop=4)
        cp = critical_path(pgt, bandwidth=1e30, partitioned=False)
        assert simulate_makespan(pgt, dop=4) >= cp - 1e-9


class TestMapping:
    def test_all_partitions_assigned(self):
        pgt = unroll(lg_scatter(8))
        min_time(pgt, dop=4)
        nodes = [NodeInfo(f"n{i}") for i in range(3)]
        assign = map_partitions(pgt, nodes)
        assert set(assign) == {s.partition for s in pgt.drops.values()}
        assert all(s.node is not None for s in pgt.drops.values())

    def test_dead_nodes_excluded(self):
        pgt = unroll(lg_scatter(8))
        min_time(pgt, dop=4)
        nodes = [NodeInfo("n0"), NodeInfo("n1", alive=False)]
        assign = map_partitions(pgt, nodes)
        assert set(assign.values()) == {"n0"}

    def test_balanced_load(self):
        g = GraphBuilder("bal")
        g.data("src")
        with g.scatter("sc", 16):
            g.component("w", app="noop", time=1.0)
            g.data("d")
        g.chain("src", "w", "d")
        pgt = unroll(g.graph())
        min_time(pgt, dop=1)
        nodes = [NodeInfo(f"n{i}") for i in range(4)]
        map_partitions(pgt, nodes)
        loads = {}
        for s in pgt.drops.values():
            loads[s.node] = loads.get(s.node, 0.0) + s.weight()
        assert max(loads.values()) <= 2 * min(loads.values()) + 1.0


class TestGraphIO:
    def test_lgt_roundtrip(self, tmp_path):
        lg = lg_scatter(4)
        path = str(tmp_path / "g.json.gz")
        save_lgt(lg, path)
        back = load_lgt(path)
        assert set(back.constructs) == set(lg.constructs)
        assert len(back.edges) == len(lg.edges)

    def test_pgt_roundtrip_streaming(self, tmp_path):
        pgt = unroll(lg_scatter(8))
        min_time(pgt, dop=4)
        path = str(tmp_path / "p.jsonl.gz")
        save_pgt(pgt, path, chunk=3)
        back = load_pgt(path)
        assert len(back) == len(pgt)
        assert len(back.edges) == len(pgt.edges)
        assert back.drops["w#0"].partition == pgt.drops["w#0"].partition
        assert back.topological_order()
